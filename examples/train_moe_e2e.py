"""End-to-end training driver: a ~100M-parameter expert-choice MoE trained
for a few hundred steps on the synthetic corpus, with checkpointing, fault
supervision and (on a real cluster) the full sharding stack.

  PYTHONPATH=src python examples/train_moe_e2e.py [--steps 300] [--tiny]

`--tiny` shrinks the model for CI-speed validation of the same driver.
"""
import argparse

from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.launch.train import run


def build_config(tiny: bool = False) -> ModelConfig:
    if tiny:
        return ModelConfig(
            name="e2e-tiny", family="moe", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=512,
            dtype="float32",
            moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                          routing="expert_choice", group_size=2,
                          go_cache=True))
    # ~100M params: 12 layers, d=512, 8 experts of d_expert=768 + embeddings
    return ModelConfig(
        name="e2e-100m", family="moe", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=8, d_ff=768, vocab_size=8192,
        dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=768,
                      routing="expert_choice", group_size=2,
                      grouping="sorted", go_cache=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/e2e_moe_ckpt")
    args = ap.parse_args()

    cfg = build_config(args.tiny)
    from repro.configs.base import ModelConfig as _MC
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k} "
          f"(expert-choice, grouped x{cfg.moe.group_size})")
    tc = TrainConfig(steps=args.steps, seq_len=args.seq_len,
                     global_batch=args.global_batch, lr=1e-3,
                     warmup_steps=20, ckpt_dir=args.ckpt_dir,
                     ckpt_every=100, log_every=10)
    out = run(cfg, tc)
    first = sum(out["losses"][:10]) / max(1, len(out["losses"][:10]))
    last = sum(out["losses"][-10:]) / max(1, len(out["losses"][-10:]))
    print(f"loss {first:.3f} -> {last:.3f} over {out['steps']} steps "
          f"({out['retries']} retries, {len(out['stragglers'])} stragglers)")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
