"""Quickstart: the paper's full technique stack on a small expert-choice MoE.

  1. build a Llama-MoE-style model (expert-choice routing, grouped experts);
  2. trace a workload and derive the C2 load-aware grouping;
  3. prefill -> GO-cache decode (C4), showing the O(1) state;
  4. run the PIM simulator (C5) for the same configuration.

Runs on CPU in ~a minute:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.grouping import group_loads, imbalance, sorted_grouping, trace_workload
from repro.launch.serve import generate
from repro.models.model import model_init
from repro.pim.simulator import S2O_KVGO, SimConfig, simulate

# 1. model --------------------------------------------------------------
cfg = get_config("llama_moe_4_16", smoke=True)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg)
e = cfg.moe
print(f"model: {cfg.name}  E={e.num_experts} top-{e.top_k} "
      f"routing={e.routing} group_size={e.group_size}")

# 2. C2 grouping from a traced workload ---------------------------------
prompts = jax.random.randint(key, (4, 24), 0, cfg.vocab_size, dtype=jnp.int32)
x = params["embed"][prompts.reshape(-1)]
# trace through the first layer's gate
gate0 = jax.tree.map(lambda a: a[0], params["layers"])["moe"]["gate"]
scores = x.astype(jnp.float32) @ gate0
choices = np.zeros((x.shape[0], e.num_experts), bool)
top = np.asarray(jax.lax.top_k(scores, e.top_k)[1])
for t in range(x.shape[0]):
    choices[t, top[t]] = True
loads = trace_workload(choices, e.num_experts)
groups = sorted_grouping(loads, e.group_size)
print(f"traced loads: {loads.astype(int)}  "
      f"imbalance before {imbalance(loads):.2f} -> grouped "
      f"{imbalance(group_loads(loads, groups)):.2f}")

# 3. GO-cache generation -------------------------------------------------
res = generate(params, cfg, prompts, gen_tokens=12)
go = res["state"]["go"]
print(f"generated {res['tokens'].shape[1]} tokens/seq at "
      f"{res['tok_per_s']:.1f} tok/s; GO cache is static: "
      f"scores{tuple(go.scores.shape)} outputs{tuple(go.outputs.shape)}")

# 4. PIM simulation of the same stack ------------------------------------
base = simulate(SimConfig())
ours = simulate(S2O_KVGO)
print(f"PIM sim: baseline {base.latency_ns:,.0f} ns / {base.energy_nj:,.0f} nJ"
      f"  ->  S2O+KVGO {ours.latency_ns:,.0f} ns / {ours.energy_nj:,.0f} nJ"
      f"  ({base.latency_ns/ours.latency_ns:.1f}x / "
      f"{base.energy_nj/ours.energy_nj:.1f}x)")
