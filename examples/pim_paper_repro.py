"""Reproduce the paper's headline numbers end-to-end with the calibrated
operator-level PIM simulator (C5): Fig. 4, Fig. 5 and Table I in one run.

  PYTHONPATH=src python examples/pim_paper_repro.py
"""
from benchmarks import paper_fig4, paper_fig5, paper_table1


def main():
    paper_table1.main()
    print()
    paper_fig4.main()
    print()
    paper_fig5.main()


if __name__ == "__main__":
    main()
