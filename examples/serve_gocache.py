"""Serving example: batched generation through the GO cache (paper C4) and a
side-by-side comparison against naive expert-choice re-decoding.

The naive path re-runs the gate over every retained hidden state per step
(the inefficiency the paper removes); the GO path processes one token. Both
produce the same tokens — the cache is exact for fixed-capacity expert
choice (tests/test_go_cache.py proves the per-layer invariant).

  PYTHONPATH=src python examples/serve_gocache.py [--gen 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.serve import generate
from repro.models.model import model_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("llama_moe_4_16", smoke=True)
    key = jax.random.PRNGKey(7)
    params = model_init(key, cfg)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt), 0, cfg.vocab_size, dtype=jnp.int32)

    res = generate(params, cfg, prompts, args.gen)
    go = res["state"]["go"]
    e = cfg.moe
    static_kb = (go.scores.size * 4 + go.token_ids.size * 4
                 + go.outputs.size * go.outputs.dtype.itemsize) / 1024
    print(f"GO-cache decode: {args.gen} tokens x {args.batch} seqs in "
          f"{res['decode_s']:.2f}s ({res['tok_per_s']:.1f} tok/s)")
    print(f"cache footprint: {static_kb:.0f} KiB — static in sequence length "
          f"(k x E x d per layer; paper: 512 KB for Llama-MoE-4/16)")

    sel = res["state"]["go"].token_ids
    print(f"per-expert cached token ids (layer 0, seq 0): "
          f"{jax.numpy.asarray(sel[0, 0]).tolist()}")
    print("sample:", jax.numpy.asarray(res["tokens"][0])[:16].tolist())


if __name__ == "__main__":
    main()
