"""Serving example: continuous batching through the GO cache (paper C4).

Requests with staggered arrivals stream through a slot pool that owns the
per-request KV + GO cache state. Each admission prefills into a free slot
mid-flight (writing that slot's per-layer GO entries in place); each engine
tick advances every occupied slot one token through the jitted masked decode
step; slots retire on length and are immediately reused. The GO cache keeps
the per-token decode cost O(1): one gate row + TopKUpdate + only the
selecting experts' FFNs, with a cache footprint static in sequence length.

Greedy outputs are bit-identical to static-batch generation per request
(tests/test_serving.py proves it) — the example prints the check.

  PYTHONPATH=src python examples/serve_gocache.py [--gen 24] [--slots 2]
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.launch.serve import generate, serve_continuous
from repro.models.model import model_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("llama_moe_4_16", smoke=True)
    key = jax.random.PRNGKey(7)
    params = model_init(key, cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt,
                            dtype=np.int32) for _ in range(args.requests)]
    arrivals = [3 * i for i in range(args.requests)]
    max_tokens = args.prompt + args.gen + 1

    res = serve_continuous(
        params, cfg, prompts, args.gen, num_slots=args.slots,
        max_tokens=max_tokens, arrival_steps=arrivals)
    s = res["stats"]
    print(f"continuous batching: {s['finished']} requests x {args.gen} tokens "
          f"over {s['steps']} ticks on {args.slots} slots "
          f"({res['tok_per_s']:.1f} tok/s)")

    go = res["engine"].pool.state["go"]
    static_kb = (go.scores.size * 4 + go.token_ids.size * 4
                 + go.outputs.size * go.outputs.dtype.itemsize) / 1024
    print(f"pool GO-cache footprint: {static_kb:.0f} KiB — static in sequence "
          f"length (k x E x d per layer per slot; paper: 512 KB for "
          f"Llama-MoE-4/16)")

    # the engine's streams match running each request alone, bit for bit
    rid0 = min(res["tokens"])
    ref = generate(params, cfg, jax.numpy.asarray(prompts[0])[None, :],
                   args.gen, max_len=max_tokens)
    same = bool((np.asarray(ref["tokens"][0]) == res["tokens"][rid0]).all())
    print(f"request 0 == static-batch generate(): {same}")
    print("sample:", res["tokens"][rid0][:16].tolist())


if __name__ == "__main__":
    main()
