"""Mesh-parity suite: sharded-xla vs sharded-pallas vs unsharded-pallas on
all three routing paths — token-choice (EP shard_map, ample AND tight
capacity), grouped C1 with capacity drops, and the expert-choice / GO-cache
decode — plus the continuous-batching engine with slot rows sharded across
data-parallel replicas.

Runs IN-PROCESS when the host already exposes >= 4 devices (the CI mesh job
sets XLA_FLAGS=--xla_force_host_platform_device_count=4 before pytest);
otherwise a single subprocess re-runs this file under that flag, so the
tier-1 suite keeps the coverage on single-device hosts (conftest must not
set XLA_FLAGS globally — the smoke tests need the real device)."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import moe as MOE
from repro.core.grouping import default_groups, group_of_expert_from_groups

MULTI = jax.device_count() >= 4

needs_mesh = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 host devices (mesh CI job / subprocess)")

# model-axis sizes 2 and 4 (E=8 divides both); data axis takes the rest
MESHES = [(2, 2), (1, 4)]


def _mesh(shape):
    return jax.make_mesh(shape, ("data", "model"))


def _pallas(e: MoEConfig, **kw) -> MoEConfig:
    return dataclasses.replace(e, backend="pallas", gmm_block_rows=8, **kw)


@pytest.fixture(scope="module")
def setup():
    e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), 64, e, jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64)) * 0.3
    return e, p, h


# ------------------------------------------------- token-choice (EP shard_map)

@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
def test_ep_token_choice_three_way_parity(setup, shape):
    """Sharded xla == sharded pallas == unsharded pallas (ample capacity:
    nothing drops, so the dropless unsharded plan is comparable too)."""
    e, p, h = setup
    ep = _pallas(e)
    y_uns = jnp.stack(
        [MOE.dispatch_forward(p, h[b], ep)[0] for b in range(h.shape[0])])
    with _mesh(shape):
        y_x, a_x = jax.jit(lambda p, h: MOE.moe_forward_ep(p, h, e))(p, h)
        y_p, a_p = jax.jit(lambda p, h: MOE.moe_forward_ep(p, h, ep))(p, h)
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_uns),
                               rtol=1e-4, atol=1e-5)
    assert int(a_x["dropped"]) == int(a_p["dropped"]) == 0
    np.testing.assert_array_equal(np.asarray(a_x["counts"]),
                                  np.asarray(a_p["counts"]))
    assert int(a_p["counts"].sum()) == h.shape[0] * h.shape[1] * e.top_k


@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
def test_ep_capacity_drop_parity(setup, shape):
    """Tight per-shard capacity: both backends must evict the SAME pairs
    (pallas realizes a drop as a zero combine weight) and agree on outputs."""
    e, p, h = setup
    et = dataclasses.replace(e, capacity_factor=0.5)
    with _mesh(shape):
        y_x, a_x = jax.jit(lambda p, h: MOE.moe_forward_ep(p, h, et))(p, h)
        y_p, a_p = jax.jit(
            lambda p, h: MOE.moe_forward_ep(p, h, _pallas(et)))(p, h)
    assert int(a_x["dropped"]) == int(a_p["dropped"]) > 0
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------- grouped C1 (capacity drops)

@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
def test_group_forward_under_mesh_drop_parity(shape):
    """C1 pooled-capacity path under the mesh (GSPMD over row-sharded
    tokens): xla and pallas drop the same pairs and agree with the
    unsharded run."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=1.25,
                  group_size=2)
    p = MOE.moe_init(jax.random.PRNGKey(0), 64, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 0.3
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    y_uns, a_uns = MOE.group_forward(p, x, _pallas(e), goe, pool_factor=0.7)
    mesh = _mesh(shape)
    with mesh:
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        y_x, a_x = jax.jit(
            lambda p, x: MOE.group_forward(p, x, e, goe, pool_factor=0.7)
        )(p, xs)
        y_p, a_p = jax.jit(
            lambda p, x: MOE.group_forward(p, x, _pallas(e), goe,
                                           pool_factor=0.7))(p, xs)
    assert int(a_x["dropped"]) == int(a_p["dropped"]) == int(a_uns["dropped"])
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_uns),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------- expert-choice / GO-cache decode

@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
def test_go_decode_selected_under_mesh(shape):
    """C4 decode under the mesh with batch rows sharded across the data
    axis: the selected-experts grouped GEMM equals the dense fallback and
    the unsharded run, step for step."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.go_cache import go_cache_init, go_cache_step
    from repro.kernels.ops import go_selected_ffn
    e = MoEConfig(num_experts=8, top_k=2, d_expert=32)
    p = MOE.moe_init(jax.random.PRNGKey(0), 64, e, jnp.float32)
    B, E, k, d = 4, e.num_experts, e.top_k, 64
    gate = p["gate"]
    dense_fn = lambda xt: MOE.expert_ffn_all(p, xt)
    sel_fn = lambda xt, sel, g: go_selected_ffn(
        xt, sel, g, p["experts"], E, bn=8)[0]
    mesh = _mesh(shape)

    cache_u = cache_d = cache_s = go_cache_init(B, E, k, d, jnp.float32)
    key = jax.random.PRNGKey(7)
    step_d = jax.jit(lambda c, x, t: go_cache_step(c, x, t, gate, dense_fn))
    step_s = jax.jit(
        lambda c, x, t: go_cache_step(c, x, t, gate, contrib_fn=sel_fn))
    for t in range(k + 4):
        key, sub = jax.random.split(key)
        xt = jax.random.normal(sub, (B, d)) * 0.3
        r_u = step_s(cache_u, xt, t)                       # unsharded ref
        with mesh:
            xs = jax.device_put(xt, NamedSharding(mesh, P("data", None)))
            r_d = step_d(cache_d, xs, t)
            r_s = step_s(cache_s, xs, t)
        np.testing.assert_array_equal(np.asarray(r_d.selected),
                                      np.asarray(r_s.selected))
        for a, b in ((r_d, r_s), (r_u, r_s)):
            np.testing.assert_allclose(np.asarray(a.y), np.asarray(b.y),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(a.cache.outputs),
                                       np.asarray(b.cache.outputs),
                                       rtol=1e-5, atol=1e-6)
        cache_u, cache_d, cache_s = r_u.cache, r_d.cache, r_s.cache


# ------------------------------------------------- sharded serving engine

@needs_mesh
@pytest.mark.parametrize("backend,paged", [("auto", False), ("pallas", False),
                                           ("auto", True)])
def test_sharded_engine_bit_identical(backend, paged):
    """Continuous-batching engine with slot rows sharded across DP replicas:
    every stream equals the unsharded engine bit for bit, on both the dense
    (auto->xla) and the selected-experts pallas decode — and on the PAGED
    pool, whose page dim shards over data-parallel with the page interior
    over "model" (launch/sharding.py page-dim rules)."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    cfg = get_config("llama_moe_4_16", smoke=True)
    if backend != "auto":
        cfg = cfg.with_overrides(moe=dataclasses.replace(
            cfg.moe, backend=backend, gmm_block_rows=8))
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(3)]
    kw = dict(num_slots=2, max_tokens=32, arrival_steps=[0, 1, 3],
              paged=paged, page_size=8)
    res0 = serve_continuous(params, cfg, prompts, 5, **kw)
    res1 = serve_continuous(params, cfg, prompts, 5, mesh=_mesh((2, 2)), **kw)
    assert res1["stats"]["mesh"] == {"data": 2, "model": 2}
    assert res1["stats"]["paged"] == paged
    for rid in res0["tokens"]:
        np.testing.assert_array_equal(res0["tokens"][rid],
                                      res1["tokens"][rid])


@needs_mesh
def test_sharded_engine_preemption_bit_identical():
    """Page-pressure eviction + block-table-surgery resume with the pool
    sharded over the mesh: snapshots cross host<->device through SHARDED
    page stores, and the preempted-then-resumed streams must still equal
    the unsharded engine bit for bit, with the same preemption schedule."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    cfg = get_config("llama_moe_4_16", smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(3)]
    # two low-priority streams fill the 8 usable pages; the high-priority
    # arrival at step 6 must evict one and finish first
    kw = dict(num_slots=3, max_tokens=48, paged=True, page_size=8,
              num_pages=9, priorities=[5, 5, 0], arrival_steps=[0, 0, 6],
              preemption=True)
    res0 = serve_continuous(params, cfg, prompts, 24, **kw)
    res1 = serve_continuous(params, cfg, prompts, 24, mesh=_mesh((2, 2)),
                            **kw)
    assert res1["stats"]["mesh"] == {"data": 2, "model": 2}
    assert res1["stats"]["preemptions"] >= 1
    assert res0["stats"]["preemptions"] == res1["stats"]["preemptions"]
    assert res0["stats"]["statuses"] == res1["stats"]["statuses"] \
        == {"DONE": 3}
    for rid in res0["tokens"]:
        np.testing.assert_array_equal(res0["tokens"][rid],
                                      res1["tokens"][rid])


@needs_mesh
def test_sharded_prefix_share_bit_identical():
    """Prefix page sharing + expert-aware admission under the mesh: the
    shared-system-prompt workload (one donor prefill, cache-hit admissions
    mapping refcounted pages copy-on-write through SHARDED page stores,
    first tokens replayed from cached prefill logits) must equal the plain
    unsharded FIFO engine bit for bit, with the prefix index drained."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    cfg = get_config("llama_moe_4_16", smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, size=16, dtype=np.int32)
    kw = dict(num_slots=2, max_tokens=48, paged=True, page_size=8,
              arrival_steps=[0, 0, 3, 5])
    res0 = serve_continuous(params, cfg, [prompt] * 4, 8,
                            prefix_share=False, expert_aware=False, **kw)
    res1 = serve_continuous(params, cfg, [prompt] * 4, 8,
                            mesh=_mesh((2, 2)), prefix_share=True,
                            expert_aware=True, **kw)
    assert res1["stats"]["mesh"] == {"data": 2, "model": 2}
    assert res1["stats"]["prefix_hits"] == 3
    assert res1["stats"]["prefill_tokens_skipped"] == 3 * 16
    assert res1["stats"]["pages_in_use"] == 0
    assert res1["stats"]["statuses"] == {"DONE": 4}
    for rid in res0["tokens"]:
        np.testing.assert_array_equal(res0["tokens"][rid],
                                      res1["tokens"][rid])


@needs_mesh
def test_sharded_engine_crash_recovery_bit_identical(tmp_path):
    """Journal + snapshot + recover with the pool sharded over the mesh:
    SlotPool.snapshot crosses SHARDED page stores to host pickles and back,
    and the recovered engine (same mesh) must finish every stream exactly
    as the unsharded, uninterrupted engine would."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    from repro.serving import RequestStatus, ServingEngine
    cfg = get_config("llama_moe_4_16", smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    mesh = _mesh((2, 2))
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(3)]
    ref = serve_continuous(params, cfg, prompts, 10, num_slots=2,
                           max_tokens=48, paged=True, page_size=8)

    eng = ServingEngine(params, cfg, mesh=mesh, num_slots=2, max_tokens=48,
                        paged=True, page_size=8,
                        journal_dir=str(tmp_path), snapshot_every=4)
    rids = [eng.submit(p, 10) for p in prompts]
    for _ in range(6):
        eng.step()                       # crash point: live sharded slots
    assert eng.pool.num_active() > 0

    rec = ServingEngine.recover(str(tmp_path), params, cfg, mesh=mesh)
    fin = rec.run()
    assert rec.stats()["mesh"] == {"data": 2, "model": 2}
    assert rec.stats()["recoveries"] == 1
    for rid, ref_rid in zip(rids, sorted(ref["tokens"])):
        assert fin[rid].status is RequestStatus.DONE
        np.testing.assert_array_equal(np.asarray(fin[rid].tokens),
                                      ref["tokens"][ref_rid])


# ------------------------------------------------- single-device fallback

def test_mesh_suite_subprocess():
    """Tier-1 fallback: on a single-device host, re-run this file in a
    subprocess with 4 forced host devices so the mesh paths stay covered."""
    if MULTI:
        pytest.skip("mesh suite already ran in-process")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "not subprocess"],
        env=env, capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
