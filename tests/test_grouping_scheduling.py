"""C2 grouping + C3 scheduling invariants (hypothesis property tests)."""
import numpy as np
from conftest import given, settings, st   # hypothesis, or skip shim

from repro.core import grouping as G
from repro.core import scheduling as S


# ------------------------------------------------------------------ grouping

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([8, 16, 32]),
       st.sampled_from([2, 4]))
def test_sorted_grouping_beats_uniform_on_average(seed, E, g):
    rng = np.random.default_rng(seed)
    loads = rng.zipf(1.5, size=E).astype(np.float64)
    s = G.imbalance(G.group_loads(loads, G.sorted_grouping(loads, g)))
    u = np.mean([G.imbalance(G.group_loads(
        loads, G.uniform_grouping(E, g, seed=i))) for i in range(8)])
    assert s <= u + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([16, 64]), st.sampled_from([2, 4]))
def test_grouping_is_partition(seed, E, g):
    rng = np.random.default_rng(seed)
    loads = rng.random(E)
    groups = G.sorted_grouping(loads, g)
    assert sorted(groups.reshape(-1).tolist()) == list(range(E))
    goe = G.group_of_expert_from_groups(groups)
    for gid, members in enumerate(groups):
        assert all(goe[m] == gid for m in members)


def test_shard_placement_balances_contiguous_blocks():
    rng = np.random.default_rng(0)
    loads = rng.zipf(1.5, size=64).astype(np.float64)
    perm = G.shard_placement(loads, 16)
    assert sorted(perm.tolist()) == list(range(64))
    shard_loads = loads[perm].reshape(16, 4).sum(axis=1)
    naive = loads.reshape(16, 4).sum(axis=1)
    assert G.imbalance(shard_loads) <= G.imbalance(naive)


def test_expert_permutation_roundtrip():
    rng = np.random.default_rng(1)
    perm = rng.permutation(8)
    bank = {"wi": rng.normal(size=(8, 4, 4))}
    out = G.apply_expert_permutation(bank, perm)
    inv = G.inverse_permutation(perm)
    np.testing.assert_array_equal(out["wi"][inv[3]], bank["wi"][3])


# ---------------------------------------------------------------- scheduling

def _rand_choices(rng, T, E, k):
    ch = np.zeros((T, E), bool)
    for t in range(T):
        ch[t, rng.choice(E, size=k, replace=False)] = True
    return ch


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(4, 24), st.sampled_from([8, 16]),
       st.integers(1, 4), st.sampled_from([2, 4]))
def test_schedule_invariants(seed, T, E, k, g):
    rng = np.random.default_rng(seed)
    choices = _rand_choices(rng, T, E, min(k, E))
    groups = G.sorted_grouping(choices.sum(0).astype(float), g)

    tw = S.token_wise_schedule(choices, groups)
    c = S.compact_schedule(choices, groups)
    o = S.reschedule_idle(choices, groups)

    # every (token, expert-hit) is scheduled exactly once per schedule
    total_pairs = int(choices.sum())
    for sch in (tw, c, o):
        assert int((sch.timeline != S.IDLE).sum()) == total_pairs

    # compact achieves the lower bound: max group queue length
    queues = S.choices_to_group_queues(choices, groups)
    assert c.makespan == max(len(q) for q in queues)
    # paper: compact is no slower than token-wise; reschedule keeps compact's
    # makespan but never more transfers
    assert c.makespan <= tw.makespan
    assert o.makespan == c.makespan
    assert o.transfers <= c.transfers

    # group order within each group's timeline is token-monotone for compact
    for i, q in enumerate(queues):
        got = [t for t in c.timeline[i] if t != S.IDLE]
        assert got == q


def test_reschedule_example_reduces_transfers():
    """A constructed case with slack where idle insertion aligns reuse (the
    paper's Fig. 2 shows 16 -> 12 on its example)."""
    rng = np.random.default_rng(7)
    choices = _rand_choices(rng, 16, 8, 3)
    groups = G.sorted_grouping(choices.sum(0).astype(float), 2)
    c = S.compact_schedule(choices, groups)
    o = S.reschedule_idle(choices, groups)
    assert o.transfers <= c.transfers
