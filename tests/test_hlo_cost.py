"""Loop-aware HLO accountant vs XLA cost_analysis ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze, xla_cost_dict


def test_unrolled_matches_cost_analysis_exactly():
    def f(x, w):
        for _ in range(5):
            x = jnp.tanh(x @ w)
        return x
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(s, s).compile()
    got = analyze(c.as_text())
    ca = xla_cost_dict(c)
    np.testing.assert_allclose(got["flops"], ca["flops"], rtol=1e-6)
    np.testing.assert_allclose(got["bytes"], ca["bytes accessed"], rtol=1e-6)


def test_scan_trip_counts_multiplied():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=7)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(s, s).compile()
    got = analyze(c.as_text())
    expect = 21 * 2 * 64 ** 3
    np.testing.assert_allclose(got["flops"], expect, rtol=1e-6)
    # XLA's own counter sees the body once — the bug we correct
    assert xla_cost_dict(c)["flops"] < got["flops"]


def test_grad_accum_structure():
    def step(w, xs):
        def body(acc, x):
            loss_g = jax.grad(lambda w: jnp.sum(jnp.tanh(x @ w)))(w)
            return jax.tree.map(jnp.add, acc, loss_g), None
        acc0 = jnp.zeros_like(w)
        g, _ = jax.lax.scan(body, acc0, xs)
        return g
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    xs = jax.ShapeDtypeStruct((4, 8, 64), jnp.float32)
    c = jax.jit(step).lower(w, xs).compile()
    got = analyze(c.as_text())
    # fwd (8x64x64) + two bwd matmuls per microbatch, 4 microbatches
    expect_min = 4 * 2 * (8 * 64 * 64) * 2
    assert got["flops"] >= expect_min
