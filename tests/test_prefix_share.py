"""Prefix page sharing + expert-aware admission: correctness-neutral by
construction, and these tests pin it.

Sharing maps a consumer's leading block-table entries onto the donor's
physical pages (copy-on-write, refcounted) and skips the shared prefill;
expert-aware admission only REORDERS equal-priority admissions. Neither may
change a single token: every batched decode op is row-wise independent, a
full-prompt cache hit replays the donor's own prefill logits, and the dense
prefix-extension path re-runs exactly the non-shared tail through the same
chunked-prefill kernel. So each test runs the SAME workload through a plain
FIFO paged engine and a sharing/expert-aware engine and asserts bit
identity — plus the stats counters proving the fast paths actually fired
and the allocator invariant that every shared page is returned on drain."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import serve_continuous
from repro.models.model import model_init
from repro.serving import ExpertAwareScheduler, ServingEngine
from repro.serving.scheduler import Request

MOE_ARCHS = ["llama_moe_4_16", "deepseek-moe-16b", "granite-moe-3b-a800m"]


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


# ------------------------------------------------------- full-prompt sharing

@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_full_match_bit_identical_to_fifo(arch, monkeypatch):
    """Six requests with the SAME prompt (the shared-system-prompt shape):
    the first admission prefills and deposits, the other five admit from
    the cache — no prefill, pages shared copy-on-write, first token from
    the donor's cached logits — and every stream equals the plain FIFO
    engine bit for bit. Runs under REPRO_AUDIT=1 so the allocator refcount
    sweep checks every tick; also the serving smoke for the paper's MoE
    target configs (deepseek-moe-16b / granite-moe-3b-a800m)."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=16, dtype=np.int32)
    prompts = [prompt] * 6
    kw = dict(num_slots=3, max_tokens=48, paged=True, page_size=8,
              arrival_steps=[0, 0, 2, 4, 6, 8])
    base = serve_continuous(params, cfg, prompts, 8,
                            prefix_share=False, expert_aware=False, **kw)
    shared = serve_continuous(params, cfg, prompts, 8,
                              prefix_share=True, expert_aware=True, **kw)

    assert shared["stats"]["prefix_share"] and shared["stats"]["expert_aware"]
    assert shared["stats"]["prefix_hits"] == 5
    assert shared["stats"]["prefill_tokens_skipped"] == 5 * 16
    assert shared["stats"]["pages_shared"] == 5 * 2     # both full pages
    assert shared["stats"]["statuses"] == {"DONE": 6}
    # run() drained the prefix index: every shared page back in the free list
    assert shared["stats"]["pages_in_use"] == 0
    for rid in base["tokens"]:
        np.testing.assert_array_equal(base["tokens"][rid],
                                      shared["tokens"][rid])


def test_dense_prefix_extension_bit_identical(monkeypatch):
    """Dense arch (starcoder2-3b): requests share a page-aligned 16-token
    prefix but diverge after it. Consumers map the two shared pages and
    prefill ONLY their 6-token tail (chunked-prefill kernel from the shared
    boundary) — bit-identical to cold full prefill because dense attention
    over the prefix is position-wise reusable (no whole-sequence routing
    competition, unlike MoE — which is why MoE gets full-prompt dedup
    only)."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup("starcoder2-3b")
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, size=16, dtype=np.int32)
    prompts = [np.concatenate([
        prefix, rng.integers(0, cfg.vocab_size, size=6, dtype=np.int32)])
        for _ in range(5)]
    kw = dict(num_slots=2, max_tokens=48, paged=True, page_size=8,
              arrival_steps=[0, 2, 4, 6, 8])
    base = serve_continuous(params, cfg, prompts, 6,
                            prefix_share=False, **kw)
    shared = serve_continuous(params, cfg, prompts, 6,
                              prefix_share=True, **kw)

    assert shared["stats"]["prefix_hits"] == 4
    assert shared["stats"]["prefill_tokens_skipped"] == 4 * 16
    assert shared["stats"]["pages_shared"] == 4 * 2
    assert shared["stats"]["pages_in_use"] == 0
    assert shared["stats"]["statuses"] == {"DONE": 5}
    for rid in base["tokens"]:
        np.testing.assert_array_equal(base["tokens"][rid],
                                      shared["tokens"][rid])


def test_moe_divergent_prefix_exact_repeat_bit_identical(monkeypatch):
    """Two MoE prompts share a page-aligned leading page but diverge after
    it; an exact repeat of the SECOND prompt then admits from the cache.
    The second deposit must NOT chain through the first prompt's radix node
    (same tokens, DIFFERENT physical page — MoE whole-sequence routing
    makes that page another prompt's KV), or the repeat would COW-map the
    first prompt's prefix and its stream would silently fork. This is the
    shared-system-prompt trace shape with non-identical continuations."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(23)
    lead = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    pa, pb = (np.concatenate([
        lead, rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)])
        for _ in range(2))
    assert not np.array_equal(pa, pb)
    prompts = [pa, pb, pb]                    # exact repeat of B last
    kw = dict(num_slots=3, max_tokens=48, paged=True, page_size=8,
              arrival_steps=[0, 2, 6])
    base = serve_continuous(params, cfg, prompts, 8,
                            prefix_share=False, **kw)
    shared = serve_continuous(params, cfg, prompts, 8,
                              prefix_share=True, **kw)
    assert shared["stats"]["prefix_hits"] == 1        # the repeat of B only
    assert shared["stats"]["statuses"] == {"DONE": 3}
    assert shared["stats"]["pages_in_use"] == 0
    for rid in base["tokens"]:
        np.testing.assert_array_equal(base["tokens"][rid],
                                      shared["tokens"][rid])


def test_prefix_index_deposit_is_page_strict():
    """Unit-level pin of the same invariant: depositing a prompt whose
    leading page TOKENS match an existing node but whose physical page
    differs pins the depositor's own page under a private node — its entry
    never returns another prompt's page."""
    from repro.serving.paging import PageAllocator, PrefixIndex
    alloc = PageAllocator(num_pages=8, page_size=4)
    idx = PrefixIndex(alloc, page_size=4)
    alloc.reserve(1, 2)
    a_pages = alloc.alloc(1, 2)
    alloc.reserve(2, 2)
    b_pages = alloc.alloc(2, 2)
    pa = list(range(8))                       # two full pages
    pb = pa[:4] + [9] * 4                     # same leading page tokens
    idx.deposit(pa, a_pages, tail_k=None, tail_v=None, go=None, logits=None)
    idx.deposit(pb, b_pages, tail_k=None, tail_v=None, go=None, logits=None)
    assert idx.entry_pages(idx.lookup_full(pa)) == a_pages
    assert idx.entry_pages(idx.lookup_full(pb)) == b_pages
    # B's own leading page is pinned (privately), A's node untouched
    assert alloc.refcount(b_pages[0]) == 2
    assert alloc.refcount(a_pages[0]) == 2
    idx.flush()                               # private nodes evict cleanly
    assert alloc.refcount(a_pages[0]) == 1
    assert alloc.refcount(b_pages[0]) == 1
    alloc.check()


def test_sharing_survives_preemption(monkeypatch):
    """A consumer admitted from the cache is evicted under page pressure
    and resumed via snapshot/restore: the shared pages were snapshotted
    like any others (host copy), the resume re-reserves private pages, and
    the stream still equals the non-shared FIFO run bit for bit."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    prompts = [prompt, prompt, prompt]
    kw = dict(num_slots=3, max_tokens=48, paged=True, page_size=8,
              num_pages=9, priorities=[5, 5, 0], arrival_steps=[0, 2, 6],
              preemption=True)
    base = serve_continuous(params, cfg, prompts, 24,
                            prefix_share=False, **kw)
    shared = serve_continuous(params, cfg, prompts, 24,
                              prefix_share=True, **kw)
    assert base["stats"]["preemptions"] >= 1
    assert shared["stats"]["prefix_hits"] >= 1
    assert shared["stats"]["statuses"] == {"DONE": 3}
    assert shared["stats"]["pages_in_use"] == 0
    for rid in base["tokens"]:
        np.testing.assert_array_equal(base["tokens"][rid],
                                      shared["tokens"][rid])


def test_index_pins_yield_to_blocked_admissions(monkeypatch):
    """Distinct prompts on a pool barely big enough for two streams: every
    deposit pins node pages, so without pressure reclaim the fourth
    admission could NEVER reserve and the engine would spin forever. The
    engine must evict LRU prefix-cache entries for a blocked head — cache
    pins are opportunistic, admissions are not — and still finish every
    stream identically to the non-shared run."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size, size=16, dtype=np.int32)
               for _ in range(4)]
    kw = dict(num_slots=2, max_tokens=24, paged=True, page_size=8,
              arrival_steps=[0, 2, 4, 6])
    base = serve_continuous(params, cfg, prompts, 6,
                            prefix_share=False, **kw)
    shared = serve_continuous(params, cfg, prompts, 6,
                              prefix_share=True, **kw)
    assert shared["stats"]["statuses"] == {"DONE": 4}
    assert shared["stats"]["pages_in_use"] == 0
    for rid in base["tokens"]:
        np.testing.assert_array_equal(base["tokens"][rid],
                                      shared["tokens"][rid])


# --------------------------------------------------------------- gate probe

def test_gate_probe_fixed_length_no_per_prompt_retrace():
    """The submit-time gate probe runs over a fixed-length leading slice:
    distinct prompt lengths must NOT each retrace/recompile it (submit
    latency would spike on varied-length workloads), and a long prompt's
    signature equals its probe-window head's."""
    from repro.serving.engine import (_PROBE_TOKENS, _gate_probe,
                                      expert_signature)
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(2)
    long = rng.integers(0, cfg.vocab_size, size=_PROBE_TOKENS + 33,
                        dtype=np.int32)
    before = _gate_probe._cache_size()
    sigs = [expert_signature(params, long[:n], cfg)
            for n in (3, 7, 20, _PROBE_TOKENS, len(long))]
    assert _gate_probe._cache_size() - before <= 1
    for s in sigs:
        assert s.shape == (cfg.moe.num_experts,) and s.any()
    np.testing.assert_array_equal(sigs[-1], sigs[-2])


# ----------------------------------------------------------- explicit errors

def test_explicit_flags_validate_config():
    """Explicit kwargs on unsupported shapes are hard errors (the env knobs
    silently no-op instead — that asymmetry is what makes the CI lanes
    semantics-preserving)."""
    cfg, params = _setup("llama_moe_4_16")
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(params, cfg, num_slots=2, max_tokens=48,
                      prefix_share=True)          # dense pool: nothing to map
    dense_cfg, dense_params = _setup("starcoder2-3b")
    with pytest.raises(ValueError):
        ServingEngine(dense_params, dense_cfg, num_slots=2, max_tokens=48,
                      expert_aware=True)          # no MoE: nothing to score


# ------------------------------------------------- expert-aware scheduler

def _req(rid, sig=None, priority=0):
    return Request(request_id=rid, prompt=np.zeros(4, np.int32),
                   max_new_tokens=4, priority=priority, expert_sig=sig)


def test_expert_aware_degenerates_to_fifo_without_signatures():
    """All-None signatures score 0, so admission order — including the
    blocked-head semantics the page gate relies on — is exactly FIFO.
    This is the property that keeps the whole existing serving test matrix
    valid under ExpertAwareScheduler."""
    sched = ExpertAwareScheduler(2, 64, num_experts=4)
    for i in range(4):
        sched.submit(_req(i))
    assert sched.next_admission(0).request_id == 0
    assert sched.next_admission(1).request_id == 1
    assert sched.next_admission(2) is None          # max_slots
    # a blocked head blocks everything behind it (no overtaking)
    assert sched.next_admission(1, can_admit=lambda r: False) is None
    assert sched.last_blocked.request_id == 2
    assert sched.next_admission(1).request_id == 2


def test_expert_aware_groups_overlapping_requests():
    """With slot owners routing to experts {0,1}, the scheduler admits the
    overlapping candidate ahead of an earlier-arrived disjoint one — but
    never across a priority class, and the EWMA load term steers between
    otherwise-equal candidates toward cold experts."""
    sched = ExpertAwareScheduler(4, 64, num_experts=4)
    A = np.array([1, 1, 0, 0], bool)      # overlaps the active batch
    B = np.array([0, 0, 1, 1], bool)      # disjoint
    sched.note_active([A])
    sched.submit(_req(0, sig=B))
    sched.submit(_req(1, sig=A))
    assert sched.next_admission(1).request_id == 1    # overlap wins
    assert sched.next_admission(1).request_id == 0

    # strict priority is never traded for overlap
    sched.submit(_req(2, sig=B, priority=0))
    sched.submit(_req(3, sig=A, priority=1))
    assert sched.next_admission(1).request_id == 2
    assert sched.next_admission(1).request_id == 3

    # equal overlap: EWMA load breaks the tie toward the colder experts
    sched.note_active([])
    C = np.array([1, 0, 0, 0], bool)
    D = np.array([0, 0, 0, 1], bool)
    for _ in range(4):
        sched.observe(C)                  # expert 0 is hot
    sched.submit(_req(4, sig=C))
    sched.submit(_req(5, sig=D))
    assert sched.next_admission(0).request_id == 5
    assert sched.next_admission(1).request_id == 4

    # victim cost model: the request with the most unique experts
    assert sched.victim_bonus(B, [A, C]) == 2
    assert sched.victim_bonus(A, [A, C]) == 0
    assert sched.victim_bonus(None, [A]) == 0


def test_expert_aware_starvation_bounded_by_aging_cap():
    """An old request with a signature disjoint from the active batch must
    not be skipped forever while overlapping same-priority requests keep
    arriving: after max_skips pass-overs it is force-admitted regardless of
    score (the window bounds the SCAN, the aging cap bounds the WAIT)."""
    sched = ExpertAwareScheduler(8, 64, num_experts=4, max_skips=3)
    A = np.array([1, 1, 0, 0], bool)          # matches the active batch
    B = np.array([0, 0, 1, 1], bool)          # disjoint
    sched.note_active([A])
    sched.submit(_req(0, sig=B))              # the would-be starvee
    picked = []
    for rid in range(1, 12):                  # adversarial arrival stream
        sched.submit(_req(rid, sig=A))
        picked.append(sched.next_admission(0).request_id)
        if picked[-1] == 0:
            break
    assert 0 in picked, "disjoint request starved"
    assert len(picked) <= sched.max_skips + 1
    # a blocked tick ages nobody: nothing was admitted past the candidate
    sched.submit(_req(99, sig=B))
    skips_before = [e[2].times_skipped for e in sched.queue]
    assert sched.next_admission(0, can_admit=lambda r: False) is None
    assert [e[2].times_skipped for e in sched.queue] == skips_before


def test_expert_aware_engine_reorders_without_changing_streams():
    """End-to-end: expert-aware admission on a 1-slot pool may reorder the
    queue, but every request's stream still equals the FIFO run — admission
    order is correctness-neutral because decode rows are independent."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(4)]
    kw = dict(num_slots=1, max_tokens=32, paged=True, page_size=8)
    base = serve_continuous(params, cfg, prompts, 6,
                            expert_aware=False, **kw)
    aware = serve_continuous(params, cfg, prompts, 6,
                             expert_aware=True, **kw)
    assert aware["stats"]["expert_aware"]
    assert aware["stats"]["statuses"] == {"DONE": 4}
    for rid in base["tokens"]:
        np.testing.assert_array_equal(base["tokens"][rid],
                                      aware["tokens"][rid])
