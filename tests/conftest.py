# NOTE: no XLA_FLAGS here — smoke tests must see the real (single) device.
# Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Optional-hypothesis shim: property-test modules do
# `from conftest import given, settings, st` so they collect (and their
# non-property tests run) without the dev extra; @given tests skip.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    import pytest

    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")
