"""Kill–recover–resume: the crash-tolerance contract end to end.

A journaled engine must come back from ANY crash point with every live
stream bit-identical to the uninterrupted run — greedy and sampled, across
all three crash classes (clean SIGKILL, torn journal write, snapshot
interrupted before its COMMITTED marker). The in-process tests simulate the
crash by ABANDONING the engine object mid-run (everything durable is
already fsync'd, exactly as after a SIGKILL) and recovering into a second
engine in the same process; the REPRO_CRASH=1 lane adds real SIGKILLs — a
child process chaos-killed mid-decode, and the full supervisor loop
(launch/serve.py --supervise) restarting through recover().

The journal byte format and torn-tail property live in tests/test_journal.py;
the checkpoint-file analogue (CorruptCheckpoint) in tests/test_substrate.py."""
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import generate, serve_continuous
from repro.models.model import model_init
from repro.runtime.fault import ProcessSupervisor, RestartRequired
from repro.serving import (Chaos, EngineJournal, JournalError, RequestStatus,
                           ServingEngine)

MAX_TOKENS = 48

_CRASH_LANE = os.environ.get("REPRO_CRASH", "") not in ("", "0")
needs_crash_lane = pytest.mark.skipif(
    not _CRASH_LANE, reason="real-SIGKILL lane (set REPRO_CRASH=1)")


def _setup(arch="llama_moe_4_16"):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


def _static_tokens(params, cfg, prompt, gen):
    res = generate(params, cfg, jnp.asarray(prompt)[None, :], gen,
                   max_len=MAX_TOKENS)
    return np.asarray(res["tokens"][0]).tolist()


def _engine(params, cfg, jdir, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_tokens", MAX_TOKENS)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    kw.setdefault("snapshot_every", 4)
    return ServingEngine(params, cfg, journal_dir=str(jdir), **kw)


def _prompts(seed, n, size=12):
    rng = np.random.default_rng(seed)
    cfg = get_config("llama_moe_4_16", smoke=True)
    return [rng.integers(0, cfg.vocab_size, size=size, dtype=np.int32)
            for _ in range(n)]


# ------------------------------------------------- in-process crash classes


def test_recover_greedy_bit_identical(tmp_path):
    """Abandon a journaled engine mid-decode (slots live, requests queued,
    events past the last snapshot); recover() must finish every stream
    exactly as the solo static-batch oracle would."""
    cfg, params = _setup()
    prompts = _prompts(0, 4)
    eng = _engine(params, cfg, tmp_path)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(6):
        eng.step()
    assert eng.pool.num_active() > 0, "crash point must have live slots"

    rec = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec.recovered_info is not None
    assert rec.recovered_info["events"] == rec.replayed_events
    fin = rec.run()
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 12), \
            f"request {rid} diverged across the crash"
    s = rec.stats()
    assert s["recoveries"] == 1
    assert s["journal_bytes"] > 0 and s["snapshots"] >= 1
    assert s["snapshot_age_ticks"] is not None
    assert rec.pool.alloc.pages_in_use == 0


def test_recover_sampled_streams_bit_identical(tmp_path):
    """Sampled streams resume from the journaled per-slot PRNG keys: the
    recovered run must equal an uninterrupted engine token for token even
    at temperature > 0 (where one resampled token would cascade)."""
    cfg, params = _setup()
    prompts = _prompts(1, 4)
    kw = dict(temperature=0.8, top_p=0.9)
    ref_eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                            paged=True, page_size=8)
    ref_rids = [ref_eng.submit(p, 12, seed=100 + i, **kw)
                for i, p in enumerate(prompts)]
    ref = ref_eng.run()

    eng = _engine(params, cfg, tmp_path)
    rids = [eng.submit(p, 12, seed=100 + i, **kw)
            for i, p in enumerate(prompts)]
    for _ in range(6):
        eng.step()
    fin = ServingEngine.recover(str(tmp_path), params, cfg).run()
    for rid, ref_rid in zip(rids, ref_rids):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == ref[ref_rid].tokens, \
            f"sampled request {rid} diverged across the crash"


def test_recover_from_torn_journal_tail(tmp_path):
    """The torn-write crash class: the last journal record is cut mid-write
    before the kill. Replay drops the torn record (a watermark the dead
    process never durably emitted) and the streams still finish exactly."""
    cfg, params = _setup()
    prompts = _prompts(2, 3)
    eng = _engine(params, cfg, tmp_path, snapshot_every=64)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(5):
        eng.step()
    intact = eng.journal.events_written
    eng.journal.tear_tail(eng.journal._last_record_bytes)

    rec = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec.replayed_events == intact - 1
    fin = rec.run()
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 12)


def test_uncommitted_snapshot_skipped_at_recovery(tmp_path):
    """The snapshot-interrupted crash class: state.pkl fully written but no
    COMMITTED marker. Recovery must fall back to the PREVIOUS committed
    snapshot + its journal tail — and still resume bit-identically."""
    cfg, params = _setup()
    prompts = _prompts(3, 3)
    eng = _engine(params, cfg, tmp_path, snapshot_every=4)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(6):
        eng.step()
    committed = eng.journal._seq
    eng.journal.write_uncommitted_snapshot(eng._snapshot_payload())

    rec = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec.recovered_info["snapshot_seq"] == committed
    fin = rec.run()
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 12)


def test_replay_oracle_trips_on_divergence(tmp_path):
    """The prefix-assertion oracle is live: recovery that would re-emit a
    DIFFERENT token than the dead process journaled must fail loudly, not
    silently serve a forked stream."""
    cfg, params = _setup()
    eng = _engine(params, cfg, tmp_path, snapshot_every=64)
    eng.submit(_prompts(4, 1)[0], 12)
    for _ in range(4):
        eng.step()

    rec = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec._replay_expect, "crash point left no watermarks to check"
    rid = next(iter(rec._replay_expect))
    rec._replay_expect[rid][-1] ^= 1          # forge a wrong watermark
    with pytest.raises(AssertionError, match="recovery divergence"):
        rec.run()


def test_repeated_crashes_are_idempotent(tmp_path):
    """Crashing AGAIN right after recovery (before any new tick) re-runs
    from the fresh post-recovery snapshot — recover(recover(x)) == recover(x)
    all the way to completion."""
    cfg, params = _setup()
    prompts = _prompts(5, 3)
    eng = _engine(params, cfg, tmp_path)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(5):
        eng.step()
    rec1 = ServingEngine.recover(str(tmp_path), params, cfg)
    for _ in range(2):
        rec1.step()                            # advance, then die again
    rec2 = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec2.recoveries == 2
    fin = rec2.run()
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 12)


def test_cancel_replays_but_outcomes_recompute(tmp_path):
    """Terminal-event replay policy: CANCELLED is an external decision and
    must survive the crash; DONE outcomes are recomputed by resuming."""
    cfg, params = _setup()
    prompts = _prompts(6, 3)
    eng = _engine(params, cfg, tmp_path, snapshot_every=64)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(2):
        eng.step()
    eng.cancel(rids[2])

    fin = ServingEngine.recover(str(tmp_path), params, cfg).run()
    assert fin[rids[2]].status is RequestStatus.CANCELLED
    for rid, p in zip(rids[:2], prompts[:2]):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 12)


def test_recover_preserves_prefix_cache(tmp_path):
    """The prefix index is part of the snapshot: requests admitted AFTER
    recovery still hit the cache warmed BEFORE the crash (shared pages were
    re-materialized from the snapshot's page contents)."""
    cfg, params = _setup()
    prompt = _prompts(7, 1, size=16)[0]
    eng = _engine(params, cfg, tmp_path, prefix_share=True,
                  snapshot_every=64)
    eng.submit(prompt, 8)
    while eng.has_work():           # run() would flush the cache at drain
        eng.step()
    assert eng.prefix_index.node_pages()
    eng.journal.commit_snapshot(eng._snapshot_payload(), eng.step_count)

    rec = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec.prefix_share and rec.prefix_index.node_pages()
    rid = rec.submit(prompt, 8)
    fin = rec.run()
    assert rec.prefix_hits == 1
    assert rec.prefill_tokens_skipped == 16
    assert fin[rid].tokens == _static_tokens(params, cfg, prompt, 8)


# ------------------------------------------------------- contract refusals


def test_recover_without_snapshot_raises(tmp_path):
    cfg, params = _setup()
    with pytest.raises(JournalError, match="no committed snapshot"):
        ServingEngine.recover(str(tmp_path / "absent"), params, cfg)


def test_journal_requires_paged_pool_and_rejects_extras(tmp_path):
    cfg, params = _setup()
    # max_tokens with no page-size divisor >= 4 stays dense even under the
    # REPRO_FORCE_PAGED lane, so the refusal is observable everywhere
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(params, cfg, num_slots=1, max_tokens=45,
                      journal_dir=str(tmp_path))
    eng = _engine(params, cfg, tmp_path)
    with pytest.raises(ValueError, match="extras"):
        eng.submit(_prompts(8, 1)[0], 4, extras={"memory": None})


def test_env_journal_lane(tmp_path, monkeypatch):
    """REPRO_JOURNAL_DIR attaches a journal to engines that can support it
    and silently no-ops on those that can't (the CI-lane pattern)."""
    cfg, params = _setup()
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    dense = ServingEngine(params, cfg, num_slots=1, max_tokens=45)
    assert dense.journal is None
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8)
    assert eng.journal is not None
    assert os.path.dirname(eng.journal.dir) == str(tmp_path)
    p = _prompts(9, 1)[0]
    rid = eng.submit(p, 6)
    fin = eng.run()
    assert fin[rid].tokens == _static_tokens(params, cfg, p, 6)
    assert eng.stats()["journal_bytes"] > 0


# ------------------------------------------------------- process supervisor


def _gen_script(body0, body1):
    """A child that branches on its supervision generation."""
    return ("import os, sys, time\n"
            "gen = int(os.environ.get('REPRO_SUPERVISE_GENERATION', '0'))\n"
            f"if gen == 0:\n    {body0}\nelse:\n    {body1}\n")


def test_supervisor_restarts_until_clean_exit():
    sup = ProcessSupervisor(
        [sys.executable, "-c", _gen_script("os._exit(3)", "os._exit(0)")],
        backoff_s=0.01)
    assert sup.run() == 0
    assert sup.stats.restarts == 1
    assert sup.stats.exit_codes == [3, 0]


def test_supervisor_budget_exhausted_raises():
    sup = ProcessSupervisor(
        [sys.executable, "-c", "import os; os._exit(2)"],
        max_restarts=1, backoff_s=0.01)
    with pytest.raises(RestartRequired, match="restart budget"):
        sup.run()
    assert sup.stats.exit_codes == [2, 2]


def test_supervisor_kills_on_stale_heartbeat(tmp_path):
    """A hung child (alive but never ticking) is SIGKILLed on heartbeat
    staleness and restarted through the same path as a crash."""
    hb = str(tmp_path / "hb")
    sup = ProcessSupervisor(
        [sys.executable, "-c",
         _gen_script("time.sleep(120)", "os._exit(0)")],
        heartbeat_file=hb, heartbeat_timeout_s=0.5, poll_s=0.05,
        backoff_s=0.01)
    assert sup.run() == 0
    assert sup.stats.heartbeat_kills == 1
    assert sup.stats.exit_codes == [-9, 0]


# --------------------------------------------------- real-SIGKILL CI lane


def _serve_cmd(jdir, *extra):
    return [sys.executable, "-m", "repro.launch.serve",
            "--arch", "llama_moe_4_16", "--smoke", "--requests", "4",
            "--slots", "2", "--prompt", "12", "--gen", "12",
            "--paged", "--page-size", "8",
            "--journal-dir", str(jdir), "--snapshot-every", "4",
            *extra]


def _serve_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.pop("REPRO_SUPERVISE_GENERATION", None)
    env.pop("REPRO_JOURNAL_DIR", None)
    return env


@needs_crash_lane
def test_sigkill_mid_decode_then_recover(tmp_path):
    """A real `kill -9` at a chaos-chosen decode tick: the child dies with
    SIGKILL (no cleanup, no atexit), and recovering in THIS process finishes
    every stream exactly as an uninterrupted engine would."""
    jdir = tmp_path / "jnl"
    out = subprocess.run(_serve_cmd(jdir, "--crash-step", "6"),
                         env=_serve_env(), capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == -signal.SIGKILL, \
        f"expected SIGKILL, got {out.returncode}: {out.stderr[-2000:]}"
    assert EngineJournal.recoverable(str(jdir))

    # the CLI's workload, reproduced in-process (serve.py uses PRNGKey(0)
    # and default_rng(0) prompts with staggered arrivals)
    cfg = get_config("llama_moe_4_16", smoke=True)
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(4)]
    ref = serve_continuous(params, cfg, prompts, 12, num_slots=2,
                           arrival_steps=[0, 2, 4, 6], paged=True,
                           page_size=8)
    rec = ServingEngine.recover(str(jdir), params, cfg)
    fin = rec.run()
    assert rec.stats()["statuses"] == {"DONE": 4}
    for rid in ref["tokens"]:
        assert fin[rid].tokens == ref["tokens"][rid].tolist(), \
            f"request {rid} diverged across the SIGKILL"


@needs_crash_lane
def test_supervised_serve_survives_crash(tmp_path):
    """The full loop: --supervise re-execs the CLI as a watched child,
    chaos SIGKILLs generation 0 mid-decode, the supervisor restarts it, and
    generation 1 recovers from the journal and drains to exit 0."""
    jdir = tmp_path / "jnl"
    out = subprocess.run(
        _serve_cmd(jdir, "--supervise", "--crash-step", "6"),
        env=_serve_env(), capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
    assert "supervised serve exited 0 after 1 restart(s)" in out.stdout
    assert "recovered from" in out.stdout
    assert "'DONE': 4" in out.stdout
