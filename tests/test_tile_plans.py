"""Packed-plan parity and invariants.

The packed planner (drop-lane elision + pairwise lane fusion + counting-sort
ranks) must keep the EXACT (token, expert) pair sets — and the exact drop
sets — of the pre-packing planner and of the xla capacity buffers, on every
routing path. Unsharded coverage lives here; the 2/4-device-mesh parity of
the same plans (EP shard_map windows, grouped C1 under GSPMD, GO decode,
sharded engine) is pinned by tests/test_moe_mesh.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st
from repro.configs.base import MoEConfig
from repro.core import moe as MOE
from repro.kernels import ops as OPS


def _rank_ref(lane, L):
    """Numpy oracle: stable rank within lane + counts."""
    lane = np.asarray(lane)
    pos = np.zeros(len(lane), np.int32)
    counts = np.zeros(L, np.int64)
    for i, l in enumerate(lane):
        if l < L:
            pos[i] = counts[l]
            counts[l] += 1
    return pos, counts


@pytest.mark.parametrize("N,L", [(40, 8), (200, 8), (9001, 8)])
def test_lane_rank_counting_and_argsort_agree(N, L):
    """Both ranking realizations (one-hot counting sort for decode-sized
    inputs, argsort for large N — the switch is N*(L+1) vs 2^16) must
    produce the SAME stable order as the numpy oracle, so capacity parity
    cannot depend on which one a path hits."""
    lane = jax.random.randint(jax.random.PRNGKey(N), (N,), 0, L + 1,
                              dtype=jnp.int32)     # includes drop sentinel L
    pos, counts = OPS._lane_rank(lane, L)
    ref_pos, ref_counts = _rank_ref(lane, L)
    planned = np.asarray(lane) < L
    np.testing.assert_array_equal(np.asarray(pos)[planned], ref_pos[planned])
    np.testing.assert_array_equal(np.asarray(counts), ref_counts)


def _fused_plan_invariants(ef, E, bn, fuse):
    plan = OPS.plan_tile_dispatch(ef, E, bn, fuse=fuse)
    ef_np = np.asarray(ef)
    N = len(ef_np)
    dest = np.asarray(plan.dest)
    te, te2 = np.asarray(plan.tile_expert), np.asarray(plan.tile_expert2)
    tv = np.asarray(plan.tile_valid)
    sel = np.asarray(plan.row_sel)[:, 0]
    rp = np.asarray(plan.row_pair)
    # every pair gets a unique packed row; row_pair inverts dest
    assert len(np.unique(dest)) == N and dest.max() < plan.n_pad
    np.testing.assert_array_equal(rp[dest], np.arange(N))
    # each row's lane is the tile's primary (row_sel=1) or secondary lane
    for r in range(N):
        t = dest[r] // bn
        assert tv[t]
        lane = te[t] if sel[dest[r]] == 1.0 else te2[t]
        assert lane == ef_np[r], (r, t, te[t], te2[t], sel[dest[r]])
    # a tile never carries more than two lanes, and only fused pairs do
    fuse_np = np.asarray(fuse)
    for t in np.nonzero(tv)[0]:
        assert fuse_np[te[t]] == fuse_np[te2[t]]
    # rank within lane is layout-independent (capacity-eviction order)
    ref_pos, ref_counts = _rank_ref(ef_np, E)
    np.testing.assert_array_equal(np.asarray(plan.pos), ref_pos)
    np.testing.assert_array_equal(np.asarray(plan.counts), ref_counts)
    # the fused static grid undercuts the unfused one
    unfused = OPS.plan_tile_dispatch(ef, E, bn)
    assert plan.n_tiles < unfused.n_tiles
    assert int(plan.occupied) <= int(unfused.occupied)
    return plan


@pytest.mark.parametrize("case", ["uniform", "skewed", "one_lane_empty",
                                  "pair_fits_one_tile"])
def test_fused_plan_invariants(case):
    E, bn = 8, 8
    fuse = tuple(i // 2 for i in range(E))
    if case == "uniform":
        ef = jax.random.randint(jax.random.PRNGKey(0), (96,), 0, E)
    elif case == "skewed":
        ef = jnp.asarray(np.concatenate([np.full(50, 2), np.full(3, 3),
                                         np.full(5, 6), np.full(2, 7)]))
    elif case == "one_lane_empty":
        ef = jnp.asarray(np.repeat([0, 2, 4, 6], 7))   # odd lanes empty
    else:                                              # both runs < one tile
        ef = jnp.asarray(np.array([0, 0, 1, 1, 1, 5, 4]))
    plan = _fused_plan_invariants(ef.astype(jnp.int32), E, bn, fuse)
    if case == "pair_fits_one_tile":
        # lanes 0+1 (5 rows) share ONE tile; 4+5 share one; => 2 occupied
        assert int(plan.occupied) == 2


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8, 16]))
def test_fused_plan_property(seed, bn):
    E = 8
    rng = np.random.default_rng(seed)
    N = int(rng.integers(1, 120))
    ef = jnp.asarray(rng.integers(0, E, size=N), jnp.int32)
    fuse = tuple(i // 2 for i in range(E))
    _fused_plan_invariants(ef, E, bn, fuse)


def test_fused_ffn_matches_unfused_exactly():
    """Lane fusion is a LAYOUT change only: masked straddle-tile dots add
    exact zeros, so fused and unfused moe_ffn_fused agree bit-for-bit on
    the per-row outputs."""
    E, T, d, de, k, bn = 8, 24, 16, 24, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    bank = {
        "wg": jax.random.normal(ks[0], (E, d, de)) * 0.1,
        "wi": jax.random.normal(ks[1], (E, d, de)) * 0.1,
        "wo": jax.random.normal(ks[2], (E, de, d)) * 0.1,
    }
    x = jax.random.normal(ks[3], (T, d)) * 0.3
    ef = jax.random.randint(ks[4], (T * k,), 0, E).astype(jnp.int32)
    wf = jnp.abs(jax.random.normal(ks[4], (T * k,)))
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    y0, rows0, plan0 = OPS.moe_ffn_fused(x, tok, ef, wf, bank, E, T, bn=bn)
    y1, rows1, plan1 = OPS.moe_ffn_fused(x, tok, ef, wf, bank, E, T, bn=bn,
                                         fuse=tuple(i // 2 for i in range(E)))
    np.testing.assert_array_equal(np.asarray(OPS.gather_rows(rows0, plan0)),
                                  np.asarray(OPS.gather_rows(rows1, plan1)))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-6, atol=1e-7)


def test_capacity_drop_set_matches_xla_buffer():
    """The packed plan's `pos < C` kept-set equals the xla dispatch buffer's
    eviction set pair for pair (ONE capacity rule, two realizations)."""
    E, T, k, C = 8, 32, 2, 3
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (T, 16)) * 0.3
    ef = jax.random.randint(key, (T * k,), 0, E).astype(jnp.int32)
    wf = jnp.ones((T * k,))
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    xla_plan = MOE._plan_dispatch(x, ef, wf, tok, E, C)
    kept_xla = np.asarray(xla_plan.dest) != E * C
    packed = OPS.plan_tile_dispatch(ef, E, 8)
    kept_packed = np.asarray(packed.pos) < C
    np.testing.assert_array_equal(kept_packed, kept_xla)


@pytest.mark.parametrize("executor", ["xla", "pallas"])
def test_go_decode_budget_fast_equals_full(executor):
    """The budgeted decode plan (lax.cond fast path) must equal the full
    B-row plan exactly, on BOTH executors, including a tick that overflows
    the budget (the fallback branch)."""
    B, E, d, de, bn = 8, 8, 16, 24, 4
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    bank = {
        "wg": jax.random.normal(ks[0], (E, d, de)) * 0.1,
        "wi": jax.random.normal(ks[1], (E, d, de)) * 0.1,
        "wo": jax.random.normal(ks[2], (E, de, d)) * 0.1,
    }
    x = jax.random.normal(ks[3], (B, d)) * 0.3
    g = jax.nn.softmax(jax.random.normal(ks[4], (B, E)), axis=-1)
    sparse = np.zeros((B, E), bool)
    sparse[np.arange(B), np.arange(B) % E] = True       # within budget
    overflow = np.zeros((B, E), bool)
    overflow[:, 0] = True                               # one hot expert: B rows
    overflow[0, 1] = True
    for sel in (sparse, overflow):
        sel = jnp.asarray(sel)
        full, pf = OPS.go_selected_ffn(x, sel, g, bank, E, bn=bn,
                                       executor=executor)
        fast, pb = OPS.go_selected_ffn(x, sel, g, bank, E, bn=bn,
                                       topk_hint=1, executor=executor)
        assert pb.C_fast < pb.C_full
        np.testing.assert_allclose(np.asarray(fast), np.asarray(full),
                                   rtol=1e-6, atol=1e-7)
    # the engineered overflow really took the fallback branch
    _, pb = OPS.go_selected_ffn(x, jnp.asarray(overflow), g, bank, E, bn=bn,
                                topk_hint=1, executor=executor)
    assert bool(pb.fallback)
    _, pb = OPS.go_selected_ffn(x, jnp.asarray(sparse), g, bank, E, bn=bn,
                                topk_hint=1, executor=executor)
    assert not bool(pb.fallback)


def test_go_decode_executors_agree():
    """The per-lane einsum executor (interpret hosts) and the pallas tile
    executor run the SAME static-capacity plan — outputs agree."""
    B, E, d, de, bn = 5, 4, 16, 24, 4
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    bank = {
        "wg": jax.random.normal(ks[0], (E, d, de)) * 0.1,
        "wi": jax.random.normal(ks[1], (E, d, de)) * 0.1,
        "wo": jax.random.normal(ks[2], (E, de, d)) * 0.1,
    }
    x = jax.random.normal(ks[3], (B, d)) * 0.3
    g = jax.nn.softmax(jax.random.normal(ks[4], (B, E)), axis=-1)
    sel = jax.random.bernoulli(ks[4], 0.4, (B, E))
    a, _ = OPS.go_selected_ffn(x, sel, g, bank, E, bn=bn, executor="xla")
    b, _ = OPS.go_selected_ffn(x, sel, g, bank, E, bn=bn, executor="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_plan_cache_reuses_concrete_plans():
    """Eager planning over the same concrete routing output is served from
    the host-side PlanCache; traced planning bypasses it."""
    OPS._PLAN_CACHE.clear()
    ef = jnp.asarray(np.array([0, 1, 1, 3, 2, 0], np.int32))
    p1 = OPS.plan_tile_dispatch(ef, 4, 4)
    s0 = OPS.plan_cache_stats()
    p2 = OPS.plan_tile_dispatch(ef, 4, 4)
    s1 = OPS.plan_cache_stats()
    assert s1["hits"] == s0["hits"] + 1
    assert p2 is p1                          # the SAME finished plan object
    # a different bn is a different plan
    OPS.plan_tile_dispatch(ef, 4, 8)
    assert OPS.plan_cache_stats()["misses"] > s1["misses"] - 1
    # traced calls never touch the cache
    before = OPS.plan_cache_stats()
    jax.jit(lambda e: OPS.plan_tile_dispatch(e, 4, 4).dest)(ef)
    after = OPS.plan_cache_stats()
    assert after["hits"] == before["hits"]


def test_group_forward_fused_drop_parity_all_pool_factors():
    """C1 pooled-capacity drops with the FUSED group plan: same drop set
    and outputs as the xla realization across pool pressures."""
    e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=1.25,
                  group_size=2)
    ep = dataclasses.replace(e, backend="pallas", gmm_block_rows=8)
    from repro.core.grouping import default_groups, group_of_expert_from_groups
    p = MOE.moe_init(jax.random.PRNGKey(0), 64, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 0.3
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    for pool in (0.4, 0.7, 2.0):
        y_x, a_x = MOE.group_forward(p, x, e, goe, pool_factor=pool)
        y_p, a_p = MOE.group_forward(p, x, ep, goe, pool_factor=pool)
        assert int(a_x["dropped"]) == int(a_p["dropped"])
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-4, atol=1e-5)
