"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.models.model import loss_fn, model_forward, model_init

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)).astype(cfg.dtype)
    if cfg.encoder_layers:
        batch["audio_frames"] = jax.random.normal(
            key, (B, cfg.num_audio_frames, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg)
    batch = _batch(cfg, key)
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    x, bal = model_forward(params, batch["tokens"], cfg, extras)
    assert x.shape == (2, 16, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(bal))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_direction(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = model_init(key, cfg)
    batch = _batch(cfg, key)

    (loss0, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss0))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # SGD step in the gradient direction lowers the loss on the same batch
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - 0.02 * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
    loss1, _ = loss_fn(params2, batch, cfg)
    assert float(loss1) < float(loss0)
