"""PIM simulator (C5): reproduction anchors and structural invariants."""
import dataclasses

import numpy as np
import pytest

from repro.pim.hermes import HERMES, LLAMA_MOE_4_16, moe_area_mm2
from repro.pim.simulator import (BASELINE, S2O_KVGO, S4O_KVGO, SimConfig,
                                 TABLE1_ANCHORS, simulate)
from repro.pim import workload as W


def test_crossbar_count_matches_paper():
    """16 experts x 96 crossbars = 1536 HERMES cores per layer (paper IV.A)."""
    assert LLAMA_MOE_4_16.crossbars_per_expert(HERMES) == 96
    assert LLAMA_MOE_4_16.total_crossbars(HERMES) == 1536


def test_area_model():
    a1 = moe_area_mm2(LLAMA_MOE_4_16, HERMES, 1)
    a2 = moe_area_mm2(LLAMA_MOE_4_16, HERMES, 2)
    a4 = moe_area_mm2(LLAMA_MOE_4_16, HERMES, 4)
    np.testing.assert_allclose(a1, 1536 * 0.635)
    np.testing.assert_allclose(a2 / a1, 0.70)   # 0.4 + 0.6/2
    np.testing.assert_allclose(a4 / a1, 0.55)


def test_table1_anchors_within_tolerance():
    for cfg, anchor in [(BASELINE, TABLE1_ANCHORS["baseline"]),
                        (S2O_KVGO, TABLE1_ANCHORS["s2o_kvgo"])]:
        r = simulate(cfg)
        assert abs(r.latency_ns / anchor["latency_ns"] - 1) < 0.15
        assert abs(r.energy_nj / anchor["energy_nj"] - 1) < 0.15


def test_s4o_prediction():
    """S4O is NOT an anchor — a genuine prediction of the calibrated model
    (paper: 743,078 ns / 1,100,548 nJ)."""
    r = simulate(S4O_KVGO)
    assert abs(r.latency_ns / 743_078 - 1) < 0.15
    assert abs(r.energy_nj / 1_100_548 - 1) < 0.15


def test_go_cache_improves_generation():
    base = simulate(dataclasses.replace(BASELINE, gen=8))
    kvgo = simulate(dataclasses.replace(BASELINE, kv_cache=True,
                                        go_cache=True, gen=8))
    assert base.latency_ns / kvgo.latency_ns > 2.0
    assert base.energy_nj / kvgo.energy_nj > 3.0


def test_improvement_grows_with_length():
    """Paper Fig 4b: the KVGO advantage grows with generated tokens."""
    def ratio(gen):
        b = simulate(dataclasses.replace(BASELINE, gen=gen))
        k = simulate(dataclasses.replace(BASELINE, kv_cache=True,
                                         go_cache=True, gen=gen))
        return b.latency_ns / k.latency_ns
    assert ratio(64) > ratio(8)


def test_kvgo_latency_linear_in_length():
    cfgs = [dataclasses.replace(BASELINE, kv_cache=True, go_cache=True, gen=g)
            for g in (8, 16, 32, 64)]
    l8, l16, l32, l64 = [simulate(c).latency_ns for c in cfgs]
    # per-token slope nearly constant (Fig 4b: linear growth; the no-cache
    # baseline's slope would grow ~4x over the same span)
    s_early = (l16 - l8) / 8
    s_late = (l64 - l32) / 32
    assert s_late / s_early < 1.5


def test_sharing_reduces_area_sorted_beats_uniform():
    base = simulate(SimConfig(routing="token_choice", kv_cache=True,
                              go_cache=True))
    s2 = simulate(SimConfig(group_size=2, grouping="sorted",
                            schedule="reschedule", routing="token_choice",
                            kv_cache=True, go_cache=True))
    u2 = simulate(SimConfig(group_size=2, grouping="uniform",
                            schedule="reschedule", routing="token_choice",
                            kv_cache=True, go_cache=True))
    assert s2.area_mm2 < base.area_mm2
    assert s2.moe_gops_per_mm2 > base.moe_gops_per_mm2      # the 2.2x claim's direction
    assert s2.moe_latency_ns <= u2.moe_latency_ns           # load-aware helps


def test_reschedule_saves_transfer_energy():
    c = simulate(SimConfig(group_size=2, grouping="sorted", schedule="compact",
                           routing="token_choice", kv_cache=True, go_cache=True))
    o = simulate(SimConfig(group_size=2, grouping="sorted",
                           schedule="reschedule", routing="token_choice",
                           kv_cache=True, go_cache=True))
    assert o.moe_latency_ns == c.moe_latency_ns
    assert o.buckets.pim_transfers <= c.buckets.pim_transfers


def test_gen_trace_selection_counts():
    sc = W.synth_gate_scores(32, 16, seed=0)
    tr = W.GenTrace(sc, k=4, seed=1)
    for _ in range(20):
        sel = tr.step()
        assert 0 <= sel.sum() <= 16
