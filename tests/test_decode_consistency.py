"""Serving == training-forward consistency per family:
  * causal attention archs: prefill + one serve_step == full forward;
  * recurrent archs (xlstm/zamba2): stepwise decode == chunked-parallel;
  * whisper: stepwise decode (with encoder memory) == teacher-forced forward;
  * expert-choice + GO cache: validated against the incremental oracle in
    test_go_cache (full forward differs BY DESIGN — expert-choice routing is
    non-causal; the paper's GO cache is the causal-incremental semantics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import blocks as B
from repro.models.layers import rmsnorm
from repro.models.model import (init_decode_state, logits_from_hidden,
                                model_forward, model_init, prefill,
                                serve_step)

CAUSAL = ["starcoder2-3b", "granite-8b", "qwen2-7b", "gemma3-27b",
          "llama-3.2-vision-90b"]
RECURRENT = ["xlstm-1.3b", "zamba2-1.2b"]


def _setup(arch, dropless=False):
    cfg = get_config(arch, smoke=True)
    if dropless and cfg.moe is not None:
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(2)
    params = model_init(key, cfg)
    B_, S = 2, 12
    tokens = jax.random.randint(key, (B_, S), 0, cfg.vocab_size)
    extras = {}
    if cfg.cross_attn_every:
        im = jax.random.normal(key, (B_, cfg.num_image_tokens, cfg.d_model))
        extras = {"image_embeds": im, "memory": im}
    return cfg, params, tokens, extras


@pytest.mark.parametrize("arch", CAUSAL + RECURRENT)
def test_prefill_decode_matches_forward(arch):
    cfg, params, tokens, extras = _setup(arch)
    x, _ = model_forward(params, tokens, cfg, extras)
    ref = logits_from_hidden(params, x[:, -1, :], cfg)
    st, _ = prefill(params, tokens[:, :-1], cfg, extras, max_len=16)
    logits, st = serve_step(params, st, tokens[:, -1], cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-2, atol=5e-3)


def test_token_choice_moe_decode_matches_forward_dropless():
    cfg, params, tokens, extras = _setup("deepseek-moe-16b", dropless=True)
    x, _ = model_forward(params, tokens, cfg, extras)
    ref = logits_from_hidden(params, x[:, -1, :], cfg)
    st, _ = prefill(params, tokens[:, :-1], cfg, extras, max_len=16)
    logits, _ = serve_step(params, st, tokens[:, -1], cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-2, atol=5e-3)


def test_whisper_decode_matches_forward():
    cfg, params, tokens, _ = _setup("whisper-base")
    key = jax.random.PRNGKey(3)
    frames = jax.random.normal(key, (2, cfg.num_audio_frames, cfg.d_model))
    x, _ = model_forward(params, tokens, cfg, {"audio_frames": frames})
    ref = logits_from_hidden(params, x[:, -1, :], cfg)
    # encode once, then step-by-step prefill + decode
    enc_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def enc_body(h, lp):
        h, _ = B.attn_block(lp, h, cfg=cfg, positions=enc_pos, causal=False,
                            use_rope=False)
        return h, None

    h, _ = jax.lax.scan(enc_body, frames.astype(jnp.dtype(cfg.dtype)),
                        params["encoder"])
    memory = rmsnorm(params["enc_norm"], h, cfg.norm_eps)
    st, _ = prefill(params, tokens[:, :-1], cfg, {"memory": memory},
                    max_len=16)
    logits, _ = serve_step(params, st, tokens[:, -1], cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-2, atol=5e-3)


def test_go_cache_decode_runs_and_selects():
    """Expert-choice serving: GO cache fields update, at most one slot per
    expert per step, state sizes static."""
    cfg, params, tokens, _ = _setup("llama_moe_4_16")
    st, _ = prefill(params, tokens, cfg, {}, max_len=24)
    sizes0 = jax.tree.map(lambda a: a.shape, st)
    tok = tokens[:, -1]
    for _ in range(4):
        before = st["go"].scores
        logits, st = serve_step(params, st, tok, cfg)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        changed = (st["go"].scores != before).sum(axis=-1)
        assert int(changed.max()) <= 1
    assert jax.tree.map(lambda a: a.shape, st) == sizes0
    assert bool(jnp.isfinite(logits).all())
