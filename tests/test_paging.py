"""Property tests for the paged-pool machinery (serving/paging.py + the
paged SlotPool): arbitrary admit / grow / retire sequences must never leak
a page, never alias one page to two live requests, and must leave freed
slots' GO rows at score -inf (the allocator-free-path reset)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.serving.paging import PageAllocator, pages_for_tokens


# ------------------------------------------------------------- pure allocator

@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "grow", "retire"]),
                          st.integers(0, 5), st.integers(1, 4)),
                max_size=60),
       st.integers(4, 24), st.integers(1, 16))
def test_allocator_never_leaks_or_aliases(ops, num_pages, page_size):
    """Drive the allocator with an arbitrary op sequence (invalid ops are
    skipped the way the engine's admission gate would skip them). After
    EVERY op: each page is free or owned by exactly one request, page 0 is
    never handed out, and the page count balances. After freeing everything
    the full pool is back."""
    alloc = PageAllocator(num_pages, page_size)
    live: set[int] = set()
    for op, rid, n in ops:
        if op == "admit" and rid not in live:
            if alloc.can_reserve(n):
                alloc.reserve(rid, n)
                # the engine allocates the prompt's pages up front, lazily
                # grows the rest — model both by allocating a prefix
                alloc.alloc(rid, max(1, n // 2))
                live.add(rid)
        elif op == "grow" and rid in live:
            if alloc.can_grow(rid):
                # within the reservation, growth is INFALLIBLE — free >=
                # outstanding promises is the reserve-time invariant
                page = alloc.grow(rid)
                assert page != 0, "null page handed out"
            else:
                with pytest.raises(RuntimeError):
                    alloc.grow(rid)     # cap enforced: no page stealing
        elif op == "retire" and rid in live:
            freed = alloc.free(rid)
            assert 0 not in freed
            live.remove(rid)
        alloc.check()                      # no alias, no leak, no page 0
    for rid in list(live):
        alloc.free(rid)
    alloc.check()
    assert alloc.pages_in_use == 0
    assert alloc.free_pages == num_pages - 1


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(
           st.sampled_from(["admit", "share", "fork", "grow", "free",
                            "scrub"]),
           st.integers(0, 5), st.integers(1, 4)),
       max_size=60),
       st.integers(4, 24), st.integers(1, 16))
def test_refcounted_sharing_never_leaks_or_cross_aliases(ops, num_pages,
                                                         page_size):
    """Drive the REFCOUNTED allocator with arbitrary share / fork / grow /
    free interleavings (invalid ops skipped the way the engine's gates
    would skip them). After every op `check()` holds: refcounts equal the
    owner count per page, no page is freed while referenced, no page
    leaks. On top: a page is never shared into a request that didn't ask
    (no alias across UNRELATED rids — only explicit share() creates
    overlap), fork never mutates the DONOR's page list, and a scrub mark
    survives until the page's LAST free — never past it."""
    alloc = PageAllocator(num_pages, page_size)
    live: set[int] = set()
    expect: dict[int, set[int]] = {}        # rid -> expected owned pages
    for op, rid, n in ops:
        other = (rid + 1) % 6
        if op == "admit" and rid not in live:
            if alloc.can_reserve(n):
                alloc.reserve(rid, n)
                alloc.alloc(rid, max(1, n // 2))
                live.add(rid)
                expect[rid] = set(alloc.owned(rid))
        elif op == "share" and rid in live and other in live:
            # map ONE of `other`'s pages that `rid` doesn't hold yet —
            # the engine's COW prefix mapping (share before reserve)
            cand = [p for p in alloc.owned(other)
                    if p not in expect[rid]]
            if cand:
                alloc.share(rid, [cand[0]])
                expect[rid].add(cand[0])
                assert alloc.refcount(cand[0]) >= 2
        elif op == "fork" and rid in live:
            shared = [p for p in expect[rid] if alloc.refcount(p) > 1]
            if shared and alloc.free_pages:
                donor_before = {r: set(alloc.owned(r))
                                for r in live if r != rid}
                new = alloc.fork(rid, shared[0])
                expect[rid].discard(shared[0])
                expect[rid].add(new)
                assert alloc.refcount(new) == 1
                # COW contract: no other owner's mapping moved
                for r, pages in donor_before.items():
                    assert set(alloc.owned(r)) == pages
        elif op == "grow" and rid in live and alloc.can_grow(rid):
            expect[rid].add(alloc.grow(rid))
        elif op == "scrub" and rid in live:
            alloc.mark_scrub(rid)
        elif op == "free" and rid in live:
            released = alloc.free(rid)
            live.remove(rid)
            mine = expect.pop(rid)
            # released = exactly the pages whose LAST reference this was
            still_held = set().union(*(expect[r] for r in live), set())
            assert set(released) == {p for p in mine
                                     if p not in still_held}
            for p in released:
                assert alloc.refcount(p) == 0
            # the pool's release path: consume the scrub marks among the
            # released pages (and zero them on device) — a mark must never
            # outlive the page's last reference
            dirty = alloc.pop_dirty(released)
            assert set(dirty) <= set(released)
        alloc.check()
        # no alias across unrelated rids: every page overlap is one we
        # created via share() (tracked in `expect`)
        for r in live:
            assert set(alloc.owned(r)) == expect[r]
    for rid in list(live):
        dirty = alloc.pop_dirty(alloc.free(rid))
        assert not set(dirty) & {p for r in live if r != rid
                                 for p in expect[r]}
        live.remove(rid)
    alloc.check()
    assert alloc.pages_in_use == 0
    assert alloc.free_pages == num_pages - 1
    assert alloc.pop_dirty(list(range(num_pages))) == [], \
        "scrub marks survived past the last free"


def test_allocator_reservations_prevent_deadlock():
    """A reserved-but-unallocated page cannot be promised twice: with 6
    usable pages, reserving 4 leaves room for 2 — a request needing 3 must
    be refused even though 5 pages are physically free."""
    alloc = PageAllocator(7, 8)
    alloc.reserve(0, 4)
    alloc.alloc(0, 1)                      # 6 free, 3 still promised to 0
    assert alloc.can_reserve(2)
    assert not alloc.can_reserve(4)
    alloc.reserve(1, 2)
    # request 0 can always reach its reserved maximum — and not one page more
    for _ in range(3):
        alloc.grow(0)
    assert len(alloc.owned(0)) == 4
    assert not alloc.can_grow(0)
    with pytest.raises(RuntimeError):
        alloc.grow(0)                    # cap: can't steal request 1's pages
    with pytest.raises(RuntimeError):
        alloc.reserve(2, 3)
    alloc.free(0)
    assert alloc.can_reserve(3)


def test_pages_for_tokens():
    assert pages_for_tokens(1, 8) == 1
    assert pages_for_tokens(8, 8) == 1
    assert pages_for_tokens(9, 8) == 2
    assert pages_for_tokens(24, 8) == 3


def test_allocator_rejects_ragged_max_tokens():
    """Regression: max_tokens not a multiple of page_size must fail FAST at
    construction — a ragged last page would make every worst-case
    reservation silently over- or under-count, and deadlock freedom rests
    on those counts. The pool and engine surface the same error."""
    with pytest.raises(ValueError, match="multiple of"):
        PageAllocator(8, 8, max_tokens=20)
    PageAllocator(8, 8, max_tokens=24)        # exact multiple is fine
    PageAllocator(8, 8)                       # legacy: no capacity given

    from repro.configs.registry import get_config
    from repro.serving.pool import SlotPool
    cfg = get_config("llama_moe_4_16", smoke=True)
    with pytest.raises(ValueError, match="multiple of"):
        SlotPool(cfg, 2, 20, paged=True, page_size=8)
    from repro.serving import ServingEngine
    from repro.models.model import model_init
    params = model_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="multiple of"):
        ServingEngine(params, cfg, num_slots=1, max_tokens=20,
                      paged=True, page_size=8)


# --------------------------------------------- pool-level GO-row reset on free

@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=10),
       st.integers(0, 2 ** 31 - 1))
def test_freed_go_rows_always_return_neg_inf(slots, seed):
    """Admit/retire a paged pool in an arbitrary slot order (no model — the
    splatted states are synthetic with FINITE GO scores) and check the free
    path: after every retire, the slot's GO rows are back at -inf and its
    block table at the null page; live slots keep their finite scores."""
    from repro.configs.registry import get_config
    from repro.models.model import init_decode_state
    from repro.serving.pool import SlotPool
    from repro.serving.scheduler import Request

    cfg = get_config("llama_moe_4_16", smoke=True)
    pool = SlotPool(cfg, 3, 16, paged=True, page_size=8)
    rng = np.random.default_rng(seed)
    rid = 0
    for slot in slots:
        if pool.owner[slot] is None:               # admit a synthetic request
            req = Request(
                request_id=rid,
                prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=4)
            rid += 1
            src = init_decode_state(cfg, 1, 16)
            src["t"] = jnp.asarray(6, jnp.int32)
            src["go"] = jax.tree.map(
                lambda a: jnp.ones_like(a) if a.dtype != jnp.int32
                else jnp.zeros_like(a), src["go"])
            pool.admit(slot, req, src, first_token=1)
            assert not bool(
                jnp.isneginf(pool.state["go"].scores[:, slot]).any())
        else:                                      # retire = allocator free
            pool.retire(slot)
            assert bool(jnp.isneginf(pool.state["go"].scores[:, slot]).all())
            assert (np.asarray(pool.state["block_table"][slot]) == 0).all()
        pool.alloc.check()
    for slot in range(3):                          # drain
        if pool.owner[slot] is not None:
            pool.retire(slot)
    assert pool.alloc.pages_in_use == 0
    assert bool(jnp.isneginf(pool.state["go"].scores).all())


# ------------------------- pool-level preempt / cancel / resume interleaving

@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(
           st.sampled_from(["admit", "tick", "preempt", "resume", "cancel"]),
           st.integers(0, 2)),
       min_size=1, max_size=24),
       st.integers(0, 2 ** 31 - 1))
def test_pool_survives_preempt_cancel_interleavings(ops, seed):
    """Fault-domain sweep over the paged pool: arbitrary interleavings of
    admit / decode-tick / preempt (snapshot + free) / resume (block-table
    surgery) / cancel must never leak or alias a page, must reset freed GO
    rows to -inf, must hand a restored slot back EXACTLY its snapshotted
    pages — and the full invariant audit() passes after every op."""
    from repro.configs.registry import get_config
    from repro.models.model import init_decode_state
    from repro.serving.pool import SlotPool
    from repro.serving.scheduler import Request

    cfg = get_config("llama_moe_4_16", smoke=True)
    pool = SlotPool(cfg, 3, 16, paged=True, page_size=8)
    rng = np.random.default_rng(seed)
    parked: dict = {}                           # rid -> (req, snapshot)
    rid = 0
    for op, slot in ops:
        req = pool.owner[slot]
        if op == "admit" and req is None:
            nreq = Request(
                request_id=rid,
                prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=4)
            if pool.can_admit(nreq):            # the engine's admission gate
                rid += 1
                src = init_decode_state(cfg, 1, 16)
                src["t"] = jnp.asarray(6, jnp.int32)
                src["go"] = jax.tree.map(
                    lambda a: jnp.ones_like(a) if a.dtype != jnp.int32
                    else jnp.zeros_like(a), src["go"])
                pool.admit(slot, nreq, src, first_token=1)
        elif op == "tick" and pool.any_active():
            # one decode token for every active slot, the engine's order:
            # pre-grow the write page, bump device t, mirror it host-side
            pool.grow_active()
            bump = jnp.asarray([1 if o is not None else 0
                                for o in pool.owner], jnp.int32)
            pool.state["t"] = pool.state["t"] + bump
            pool.note_decoded()
            for s, o in enumerate(pool.owner):
                if o is not None:
                    pool.remaining[s] -= 1
                    if pool.remaining[s] <= 0:
                        pool.retire(s)
        elif op == "preempt" and req is not None:
            snap = pool.snapshot(slot)
            pool.retire(slot)
            parked[req.request_id] = (req, snap)
            assert bool(jnp.isneginf(pool.state["go"].scores[:, slot]).all())
        elif op == "resume" and parked and pool.owner[slot] is None:
            prid = min(parked)
            preq, snap = parked[prid]
            if pool.can_resume(snap):
                del parked[prid]
                pool.restore(slot, preq, snap)
                ids = pool.block_table[slot][:snap["n_pages"]]
                # the restored slot reads back EXACTLY its snapshotted pages
                np.testing.assert_array_equal(
                    np.asarray(pool.state["k_pages"][:, ids]), snap["k"])
                np.testing.assert_array_equal(
                    np.asarray(pool.state["v_pages"][:, ids]), snap["v"])
        elif op == "cancel":
            if req is not None:                 # cancel an active stream
                pool.retire(slot)
                assert bool(
                    jnp.isneginf(pool.state["go"].scores[:, slot]).all())
            elif parked:                        # cancel a parked snapshot
                parked.pop(min(parked))         # pages were freed at preempt
        pool.audit()
        pool.alloc.check()
    for s, o in enumerate(pool.owner):          # drain
        if o is not None:
            pool.retire(s)
    pool.audit()
    assert pool.alloc.pages_in_use == 0
    assert bool(jnp.isneginf(pool.state["go"].scores).all())
