"""The chaos lane: seeded fault injection proves the serving fault domain.

Chaos (serving/chaos.py) forces exactly the faults the engine claims to
survive — transient tick failures (the StepSupervisor must retry with the
same inputs), admission pressure (delay, never reorder), forced preemptions
(the snapshot/restore path must stay bit-identical), and NaN poisoning (the
quarantine must fail ONE slot without touching cohabitants). Everything is
driven by one seeded generator, so every test here replays exactly.

CI runs the whole serving suite under `REPRO_CHAOS=1 REPRO_FORCE_PAGED=1
REPRO_AUDIT=1` — the env-driven lane is semantics-preserving, so the
bit-identity pins in test_serving.py double as chaos assertions. This file
pins the injector itself and the non-preserving faults (NaN, supervisor
exhaustion) the env lane keeps off by default."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import generate
from repro.models.model import model_init
from repro.runtime.fault import RestartRequired
from repro.serving import Chaos, ChaosError, RequestStatus, ServingEngine

MAX_TOKENS = 48


def _setup(arch="llama_moe_4_16"):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


def _static_tokens(params, cfg, prompt, gen):
    res = generate(params, cfg, jnp.asarray(prompt)[None, :], gen,
                   max_len=MAX_TOKENS)
    return np.asarray(res["tokens"][0]).tolist()


def test_chaos_churn_preserves_streams_and_pages():
    """The full storm on a paged pool — tick failures, admission pressure,
    forced evictions — is invisible in the OUTPUT: every stream equals
    running alone bit for bit, every preempted stream resumed, no page
    leaks, and the per-tick invariant sweep stays green."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(6)]
    chaos = Chaos(seed=3, tick_fail=0.3, pressure=0.2, preempt=0.4)
    eng = ServingEngine(params, cfg, num_slots=3, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, chaos=chaos)
    assert eng.preemption          # chaos preempt > 0 arms the resume path
    eng.audit_every_tick = True
    rids = [eng.submit(p, 16) for p in prompts]
    fin = eng.run()

    s = eng.stats()
    assert s["chaos"]["tick_faults"] >= 1 and s["tick_retries"] >= 1
    assert s["chaos"]["pressure"] >= 1
    assert s["preemptions"] >= 1 and s["resumes"] == s["preemptions"]
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 16), \
            f"request {rid} diverged under chaos"
    assert eng.pool.alloc.pages_in_use == 0
    eng.pool.audit()


def test_chaos_tick_faults_retried_bit_identical_dense():
    """Transient decode-tick failures on a dense pool: the supervisor
    retries with the SAME inputs, so heavy fault rates change nothing but
    wall time — streams stay bit-identical and all requests finish DONE."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(3)]
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        chaos=Chaos(seed=1, tick_fail=0.5))
    rids = [eng.submit(p, 12) for p in prompts]
    fin = eng.run()
    assert eng.stats()["tick_retries"] >= 1
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, 12)


def test_supervisor_exhaustion_raises_restart_required():
    """A fault that never clears must NOT spin forever: past the
    supervisor's retry budget the tick raises RestartRequired (the same
    give-up signal the training loop uses), with the chaos error chained."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    chaos = Chaos(seed=0, tick_fail=1.0, max_consecutive_faults=10 ** 6)
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                        chaos=chaos)
    eng.submit(p, 4)
    with pytest.raises(RestartRequired) as ei:
        eng.run()
    assert isinstance(ei.value.__cause__, ChaosError)
    assert eng.stats()["tick_retries"] >= 3


def test_chaos_nan_injection_quarantines_without_cross_contamination():
    """Random NaN poisoning (the one non-semantics-preserving fault) fails
    the poisoned streams — partial tokens are a true prefix of the solo
    stream — while every surviving stream stays bit-identical, and the pool
    drains clean."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(4)]
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        chaos=Chaos(seed=2, nan=0.12))   # 2 FAILED / 2 DONE
    rids = [eng.submit(p, 12) for p in prompts]
    fin = eng.run()

    statuses = eng.stats()["statuses"]
    assert statuses.get("FAILED", 0) >= 1, "seeded NaN never landed"
    assert statuses.get("DONE", 0) >= 1, "no survivors to check isolation"
    assert eng.stats()["chaos"]["nans"] >= 1
    for rid, p in zip(rids, prompts):
        ref = _static_tokens(params, cfg, p, 12)
        if fin[rid].status is RequestStatus.DONE:
            assert fin[rid].tokens == ref
        else:
            assert fin[rid].status is RequestStatus.FAILED
            assert fin[rid].fail_reason == "non-finite logits"
            assert fin[rid].tokens == ref[:len(fin[rid].tokens)]
    assert not eng.pool.any_active()


def test_chaos_from_env(monkeypatch):
    """`REPRO_CHAOS` wires the injector into every engine by default; off
    (or falsy) means no injector and no overhead."""
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    assert Chaos.from_env() is None
    monkeypatch.setenv("REPRO_CHAOS", "0")
    assert Chaos.from_env() is None
    monkeypatch.setenv("REPRO_CHAOS", "1")
    monkeypatch.setenv("REPRO_CHAOS_SEED", "7")
    monkeypatch.setenv("REPRO_CHAOS_TICK", "0.5")
    c = Chaos.from_env()
    assert c is not None and c.seed == 7
    assert c.tick_fail == 0.5 and c.pressure == 0.05 and c.nan == 0.0
    assert c.crash == 0.0 and c.crash_step == -1 and c.crash_class == "kill"


def test_chaos_from_env_fails_fast_on_malformed_knobs(monkeypatch):
    """A typo'd numeric knob must not silently run the lane at a default
    rate: from_env raises naming the offending variable AND value."""
    monkeypatch.setenv("REPRO_CHAOS", "1")
    monkeypatch.setenv("REPRO_CHAOS_TICK", "0.5x")
    with pytest.raises(ValueError, match="REPRO_CHAOS_TICK='0.5x'"):
        Chaos.from_env()
    monkeypatch.delenv("REPRO_CHAOS_TICK")
    monkeypatch.setenv("REPRO_CHAOS_CRASH_STEP", "six")
    with pytest.raises(ValueError, match="REPRO_CHAOS_CRASH_STEP='six'"):
        Chaos.from_env()
    monkeypatch.delenv("REPRO_CHAOS_CRASH_STEP")
    monkeypatch.setenv("REPRO_CHAOS_CRASH_CLASS", "explode")
    with pytest.raises(ValueError, match="crash_class"):
        Chaos.from_env()


def test_chaos_crash_knobs():
    """crash_step fires exactly once per process (the recovered generation
    runs past the same tick); the class picker is seeded; torn_cut always
    lands inside the last record."""
    c = Chaos(seed=0, crash_step=4, crash_class="torn")
    assert c.crash_event(3) is None
    assert c.crash_event(4) == "torn"
    assert c.crash_event(4) is None, "pinned crash must fire once"
    assert c.injected["crashes"] == 1
    for n in (1, 2, 37):
        assert 1 <= c.torn_cut(n) <= n
    mix = Chaos(seed=1, crash_step=0, crash_class="mix")
    assert mix.crash_event(0) in ("kill", "torn", "snap")
    with pytest.raises(ValueError, match="crash_class"):
        Chaos(crash_class="explode")


def test_audit_catches_page_accounting_corruption():
    """REPRO_AUDIT's sweep is a real tripwire: freeing a live slot's pages
    behind the pool's back (block table still mapping them) must fail the
    next audit — ownership and block tables must agree EXACTLY."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8)
    rid = eng.submit(p, 8)
    for _ in range(3):
        eng.step()
    eng.pool.audit()                           # clean while consistent
    eng.pool.alloc.free(rid)                   # corrupt: pages freed, table live
    with pytest.raises(AssertionError, match="block table"):
        eng.pool.audit()
