"""Quantized decode state (cfg.kv_quant="int8"): int8 KV pages + per-page
per-kv-head amax scales, int8 GO rows + per-row scales (src/repro/core/quant.py).

Layers pinned here:
  round-trip bound    |dequant(quant(x)) - x| <= amax / (2 * QMAX) per page
                      per head — property-tested over magnitudes, all-zero
                      pages and outlier-dominated pages;
  write determinism   a page's int8 contents are a pure function of the
                      tokens written to it: scrubbed-then-reused pages equal
                      fresh pages bit for bit (rescale-on-write + zeroed
                      scales on free);
  fp32 divergence     quantized attention output sits a BOUNDED distance
                      from fp32 (scale-derived tolerance), never bit-equal
                      by accident of tiny inputs;
  engine lifecycle    solo-vs-pooled bit-identity, prefix-share hits,
                      preemption + resume, NaN-poison quarantine and
                      journal crash recovery — all quant-vs-quant exact,
                      with the per-tick invariant audit on;
  meshes              quantized streams under 2x2 / 1x4 GSPMD meshes equal
                      the unsharded quantized engine (scales follow the
                      page-axis sharding rules in launch/sharding.py).

The kernel-vs-gather parity of the quantized Pallas kernel lives in
tests/test_paged_attn.py; the end-to-end CI lane is
REPRO_KV_QUANT=1 REPRO_FORCE_PAGED=1 over tests/test_serving.py."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st
from repro.configs.registry import get_config
from repro.core import quant as Q
from repro.launch.serve import generate
from repro.models.model import model_init
from repro.serving import RequestStatus, ServingEngine

MAX_TOKENS = 48

MULTI = jax.device_count() >= 4
needs_mesh = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 host devices (mesh CI job / subprocess)")
MESHES = [(2, 2), (1, 4)]


def _setup(arch="llama_moe_4_16"):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


def _solo_tokens(params, cfg, prompt, gen, **kw):
    """The request alone on a 1-slot QUANTIZED engine: decode is row-wise
    independent, so this is the bit-identity oracle for pooled quantized
    streams (fp32 generate() is only boundedly close — near-tied greedy
    argmaxes flip on smoke weights)."""
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    kw.setdefault("kv_quant", "int8")
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS, **kw)
    rid = eng.submit(np.asarray(prompt, np.int32), gen)
    return eng.run()[rid].tokens


# ------------------------------------------------------ round-trip properties

def _assert_page_roundtrip_bound(x):
    q, s = Q.quantize_pages(jnp.asarray(x))
    back = np.asarray(Q.dequantize_pages(q, s))
    amax = np.abs(x).max(axis=(-3, -1))                   # [..., Hkv]
    bound = amax / (2 * Q.QMAX)
    err = np.abs(back - x).max(axis=(-3, -1))
    # (1 + 1e-6) absorbs f32 rounding in the quotient/product themselves
    assert (err <= bound * (1 + 1e-6) + 1e-30).all(), \
        f"round-trip error {err.max()} above amax/(2*QMAX) bound"
    return q, s, back


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.integers(-6, 6), st.booleans(),
       st.booleans())
def test_page_roundtrip_error_bound_property(seed, expo, zero_page, outlier):
    """quantize_pages/dequantize_pages: per-(page, head) error is bounded by
    amax / (2 * QMAX) across magnitudes 1e-6..1e6, including all-zero pages
    (exact zeros, scale 0) and pages whose amax is set by a single outlier
    1e3 above the rest (the bound scales with amax — outliers widen it,
    they never break it)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(3, 8, 2, 4)).astype(np.float32) * (10.0 ** expo)
    if outlier:
        x[2, 3, 1, 2] *= 1e3
    if zero_page:
        x[1] = 0.0
    q, s, back = _assert_page_roundtrip_bound(x)
    if zero_page:
        assert (np.asarray(q[1]) == 0).all()
        assert (np.asarray(s[1]) == 0).all()
        assert (back[1] == 0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.integers(-6, 6))
def test_row_roundtrip_error_bound_property(seed, expo):
    """quantize_rows/dequantize_rows (the GO-cache layout): per-row error is
    bounded by the row amax / (2 * QMAX)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, 3, 4, 8)).astype(np.float32) * (10.0 ** expo)
    x[0, 0, 1] = 0.0                                      # all-zero row
    q, s = Q.quantize_rows(jnp.asarray(x))
    back = np.asarray(Q.dequantize_rows(q, s))
    bound = np.abs(x).max(axis=-1) / (2 * Q.QMAX)
    err = np.abs(back - x).max(axis=-1)
    assert (err <= bound * (1 + 1e-6) + 1e-30).all()
    assert (back[0, 0, 1] == 0).all()


def test_page_roundtrip_bound_cases():
    """Deterministic pin of the property's named edge cases (runs even
    without the hypothesis dev extra): all-zero page and outlier page."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(3, 8, 2, 4)).astype(np.float32)
    x[1] = 0.0
    x[2, 0, 0, 0] = 1e4                                   # outlier
    q, s, back = _assert_page_roundtrip_bound(x)
    assert (back[1] == 0).all() and (np.asarray(s)[1] == 0).all()
    # the outlier element itself survives to within half a quantum
    assert abs(back[2, 0, 0, 0] - 1e4) <= 1e4 / (2 * Q.QMAX) * (1 + 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_chunk_scatter_roundtrip_bound_property(seed):
    """scatter_chunk into empty pages: written positions round-trip within
    the final page scales' half-quantum; untouched positions stay zero."""
    rng = np.random.default_rng(seed)
    NP, ps, Hkv, hd, Cs = 4, 8, 2, 4, 8
    cache = jnp.zeros((NP, ps, Hkv, hd), jnp.int8)
    scales = jnp.zeros((NP, Hkv), jnp.float32)
    vals = rng.normal(size=(1, Cs, Hkv, hd)).astype(np.float32)
    pages = jnp.asarray([[1] * ps], jnp.int32)            # one full page
    offs = jnp.asarray([list(range(ps))], jnp.int32)
    cache, scales = Q.scatter_chunk(cache, scales, pages, offs,
                                    jnp.asarray(vals))
    back = np.asarray(Q.dequantize_pages(cache, scales))
    bound = np.asarray(scales)[1] / 2                     # [Hkv]
    err = np.abs(back[1] - vals[0]).max(axis=(0, 2))
    assert (err <= bound * (1 + 1e-6) + 1e-30).all()
    assert (back[[0, 2, 3]] == 0).all()


def test_scatter_reused_page_equals_fresh_page():
    """Rescale-on-write determinism: scattering a token stream into a page
    whose previous tenant left int8 garbage behind (scale scrubbed to 0 on
    free, contents NOT) produces bit-identical contents to a fresh zero
    page — the first write's factor-0 rescale wipes the garbage."""
    rng = np.random.default_rng(0)
    NP, ps, Hkv, hd = 3, 8, 2, 4
    fresh_c = jnp.zeros((NP, ps, Hkv, hd), jnp.int8)
    dirty_c = jnp.asarray(
        rng.integers(-127, 128, size=(NP, ps, Hkv, hd)), jnp.int8)
    fresh_s = dirty_s = jnp.zeros((NP, Hkv), jnp.float32)
    for i in range(ps):
        # growing magnitudes force a scale-growth rescale on every write
        val = jnp.asarray(rng.normal(size=(1, Hkv, hd)) * (i + 1),
                          jnp.float32)
        page, off = jnp.asarray([1], jnp.int32), jnp.asarray([i], jnp.int32)
        fresh_c, fresh_s = Q.scatter_token(fresh_c, fresh_s, page, off, val)
        dirty_c, dirty_s = Q.scatter_token(dirty_c, dirty_s, page, off, val)
    np.testing.assert_array_equal(np.asarray(fresh_c[1]),
                                  np.asarray(dirty_c[1]))
    np.testing.assert_array_equal(np.asarray(fresh_s), np.asarray(dirty_s))


def test_quantized_attention_bounded_divergence_from_fp32():
    """Gather-path attention over int8 pages vs the same pages in fp32:
    outputs diverge (quantization is real) but stay within a scale-derived
    tolerance — the V half-quantum plus the softmax shift the K error can
    induce."""
    from repro.configs.base import ModelConfig
    from repro.models import attention as ATT
    cfg = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=0, vocab_size=64,
                      dtype="float32", paged_attn="gather")
    hd = cfg.resolved_head_dim()
    params = ATT.attn_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    NP, ps, B = 9, 8, 2
    kp = jnp.asarray(rng.normal(size=(NP, ps, 2, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NP, ps, 2, hd)), jnp.float32)
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    t = jnp.asarray([17, 25], jnp.int32)
    x_t = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    qk, ks = Q.quantize_pages(kp)
    qv, vs = Q.quantize_pages(vp)

    ref, _, _ = ATT.attn_decode(params, x_t, kp, vp, t, cfg=cfg,
                                block_table=bt)
    got, _, _ = ATT.attn_decode(params, x_t, (qk, ks), (qv, vs), t, cfg=cfg,
                                block_table=bt)
    diff = np.abs(np.asarray(got) - np.asarray(ref)).max()
    # V dequant error alone is <= max scale / 2 ~ 0.016 for N(0,1) pages;
    # K error perturbs the softmax weights on top. 10x the V half-quantum
    # is a loose but honest ceiling for these magnitudes.
    tol = 10 * float(np.asarray(vs).max()) / 2
    assert 0 < diff <= tol, f"divergence {diff} outside (0, {tol}]"


# ------------------------------------------------------- validation + stats

def test_typed_validation_fail_fast(monkeypatch):
    """kv_quant="int8" is an API contract: impossible shapes raise typed
    errors NAMING the knob at engine construction, not mid-decode.
    Exercised unforced: the CI force-paged lane would silently upgrade the
    dense-pool case into a valid paged engine."""
    monkeypatch.delenv("REPRO_FORCE_PAGED", raising=False)
    monkeypatch.delenv("REPRO_FORCE_PAGED_KERNEL", raising=False)
    cfg, params = _setup()
    with pytest.raises(ValueError, match="kv_quant"):
        ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                      kv_quant="int8")                    # dense pool
    with pytest.raises(ValueError, match="kv_quant"):
        ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                      paged=True, page_size=4, kv_quant="int8")  # untileable
    with pytest.raises(ValueError, match="kv_quant"):
        ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                      paged=True, page_size=8, kv_quant="fp4")   # unknown
    xl = get_config("xlstm-1.3b", smoke=True)
    xp = model_init(jax.random.PRNGKey(5), xl)
    with pytest.raises(ValueError):                       # recurrent arch
        ServingEngine(xp, xl, num_slots=1, max_tokens=16, paged=True,
                      page_size=8, kv_quant="int8")


def test_env_lane_noops_where_unsupported(monkeypatch):
    """REPRO_KV_QUANT is a CI lane, not a contract: it silently no-ops on
    dense pools and untileable page sizes instead of failing engines that
    are valid unforced."""
    monkeypatch.delenv("REPRO_FORCE_PAGED", raising=False)
    monkeypatch.delenv("REPRO_FORCE_PAGED_KERNEL", raising=False)
    cfg, params = _setup()
    monkeypatch.setenv("REPRO_KV_QUANT", "1")
    dense = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    assert dense.cfg.kv_quant == "none"
    odd = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                        paged=True, page_size=4)
    assert odd.cfg.kv_quant == "none"
    ok = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                       paged=True, page_size=8)
    assert ok.cfg.kv_quant == "int8" and ok.pool.quant


def test_stats_surface_quant_fields():
    cfg, params = _setup()
    rng = np.random.default_rng(30)
    p = rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, kv_quant="int8")
    rid = eng.submit(p, 6)
    eng.run()
    s = eng.stats()
    assert s["kv_quant_dtype"] == "int8"
    assert s["kv_bytes_per_token"] == Q.kv_bytes_per_token(eng.cfg, 8)
    # int8 pages must actually be smaller than the fp32 pool's ("none"
    # pinned explicitly so the REPRO_KV_QUANT lane can't quantize the
    # control engine)
    fp32 = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                         paged=True, page_size=8, kv_quant="none")
    assert s["kv_bytes_per_token"] < fp32.stats()["kv_bytes_per_token"] / 3
    assert fp32.stats()["kv_quant_dtype"] is None
    assert fp32.stats()["dequant_max_abs_err"] is None
    # observed dequant error: nonzero once pages were written, finite, and
    # small at these magnitudes (the exact bound is pinned by the property
    # tests above against each admission's own amax)
    assert 0 < s["dequant_max_abs_err"] < 1.0


# ------------------------------------------------------- engine lifecycle

def test_pooled_streams_equal_solo_quantized(monkeypatch):
    """Staggered arrivals + slot reuse on a 2-slot quantized pool: every
    stream equals the same request alone on a 1-slot quantized engine, and
    reruns are bit-identical (int8 decode is deterministic). The per-tick
    audit checks scale finiteness and freed-page scrubbing throughout."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup()
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (12, 12, 16, 12)]
    gens = [8, 5, 7, 6]

    def run():
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                            paged=True, page_size=8, kv_quant="int8")
        rids = [eng.submit(p, g, arrival_step=a)
                for p, g, a in zip(prompts, gens, [0, 3, 7, 7])]
        fin = eng.run()
        return [fin[r].tokens for r in rids], eng

    got, eng = run()
    got2, _ = run()
    assert got == got2, "quantized decode is not deterministic"
    for t, p, g in zip(got, prompts, gens):
        assert t == _solo_tokens(params, cfg, p, g), \
            "pooled quantized stream diverged from solo"
    assert eng.pool.alloc.pages_in_use == 0
    eng.pool.alloc.check()
    eng.pool.audit()


def test_prefix_share_hit_stays_quantized(monkeypatch):
    """COW prefix sharing on a quantized pool: a full-prefix hit reuses the
    depositor's int8 pages AND their scales — the hit stream equals both the
    cold quantized stream and the solo oracle, bit for bit."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup()
    rng = np.random.default_rng(32)
    p = rng.integers(0, cfg.vocab_size, size=16, dtype=np.int32)

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, kv_quant="int8",
                        prefix_share=True)
    r0 = eng.submit(p, 6)
    r1 = eng.submit(p, 6, arrival_step=4)   # same prompt -> cache hit while
    fin = eng.run()                         # the deposit is still pinned
    assert eng.stats()["prefix_hits"] >= 1
    assert eng.stats()["pages_shared"] >= 2           # both full int8 pages
    assert fin[r1].tokens == fin[r0].tokens
    assert fin[r1].tokens == _solo_tokens(params, cfg, p, 6)
    eng.pool.audit()


def test_preemption_resume_bit_identical_quantized(monkeypatch):
    """Preemption snapshot/restore round-trips int8 pages + scales + GO row
    scales: the evicted-then-resumed quantized stream equals running alone."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup()
    rng = np.random.default_rng(33)
    lo = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
          for _ in range(2)]
    hi = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    eng = ServingEngine(params, cfg, num_slots=3, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, num_pages=9,
                        preemption=True, kv_quant="int8")
    r_lo = [eng.submit(p, 24, priority=5) for p in lo]
    r_hi = eng.submit(hi, 8, priority=0, arrival_step=6)
    fin = eng.run()
    s = eng.stats()
    assert s["preemptions"] >= 1 and s["resumes"] >= 1
    for rid, p, g in [(r_lo[0], lo[0], 24), (r_lo[1], lo[1], 24),
                      (r_hi, hi, 8)]:
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _solo_tokens(params, cfg, p, g), \
            "quantized stream diverged across preemption churn"
    assert eng.pool.alloc.pages_in_use == 0
    eng.pool.audit()


def test_nan_poison_quarantines_quantized_slot(monkeypatch):
    """NaN cannot live in an int8 page, so poison lands on the page's SCALE
    — the poisoned stream still retires FAILED ("non-finite logits") with
    its pre-poison prefix kept, and the cohabitant is untouched. The audit
    tolerates the in-flight NaN scale on a LIVE page and asserts it is
    scrubbed once the page is freed."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup()
    rng = np.random.default_rng(34)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, kv_quant="int8")
    r0 = eng.submit(p0, 16)
    r1 = eng.submit(p1, 16)
    for _ in range(40):
        eng.step()
        slot0 = next((s for s, o in enumerate(eng.pool.owner)
                      if o is not None and o.request_id == r0), None)
        if slot0 is not None and len(eng.pool.owner[slot0].tokens) >= 4:
            break
    eng.pool.poison_slot(slot0)
    fin = eng.run()
    assert fin[r0].status is RequestStatus.FAILED
    assert fin[r0].fail_reason == "non-finite logits"
    ref0 = _solo_tokens(params, cfg, p0, 16)
    assert fin[r0].tokens == ref0[:len(fin[r0].tokens)]
    assert fin[r1].tokens == _solo_tokens(params, cfg, p1, 16)
    # quarantine scrubbed the poisoned scale: no NaN survives on free pages
    eng.pool.audit()
    assert np.isfinite(np.asarray(eng.pool.state["k_scales"])).all()


def test_crash_recovery_rebuilds_quantized_engine(tmp_path, monkeypatch):
    """Journal + snapshot durability: abandon a journaled QUANTIZED engine
    mid-decode and recover() — the rebuilt engine is quantized (kv_quant
    rides engine_kw through the snapshot) and every stream finishes exactly
    as the uninterrupted solo quantized run."""
    monkeypatch.setenv("REPRO_AUDIT", "1")
    cfg, params = _setup()
    rng = np.random.default_rng(35)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(3)]
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, kv_quant="int8",
                        journal_dir=str(tmp_path), snapshot_every=4)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(6):
        eng.step()
    assert eng.pool.num_active() > 0, "crash point must have live slots"

    rec = ServingEngine.recover(str(tmp_path), params, cfg)
    assert rec.cfg.kv_quant == "int8" and rec.pool.quant
    fin = rec.run()
    for rid, p in zip(rids, prompts):
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _solo_tokens(params, cfg, p, 12), \
            "quantized stream diverged across the crash"
    assert rec.stats()["recoveries"] == 1
    assert rec.pool.alloc.pages_in_use == 0
    rec.pool.audit()


# ------------------------------------------------------------------- meshes

@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
def test_quantized_engine_mesh_stream_parity(shape):
    """Quantized engine under a GSPMD mesh (int8 pages + scales shard along
    the page axis, GO scales along slots/experts — launch/sharding.py):
    every stream equals the unsharded quantized engine's."""
    from repro.launch.serve import serve_continuous
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(3)]
    kw = dict(num_slots=2, max_tokens=32, arrival_steps=[0, 1, 3],
              paged=True, page_size=8, kv_quant="int8")
    ref = serve_continuous(params, cfg, prompts, 5, **kw)
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = serve_continuous(params, cfg, prompts, 5, mesh=mesh, **kw)
    assert got["stats"]["kv_quant_dtype"] == "int8"
    for rid in ref["tokens"]:
        np.testing.assert_array_equal(ref["tokens"][rid], got["tokens"][rid])


def test_mesh_cases_subprocess():
    """Tier-1 fallback: on a single-device host, re-run this file's mesh
    cases in a subprocess with 4 forced host devices."""
    if MULTI:
        pytest.skip("mesh cases already ran in-process")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "mesh and not subprocess"],
        env=env, capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
