"""Continuous-batching engine == static-batch generate(), bit for bit.

The engine and generate() share the same compiled decode kernels (per-slot
positions broadcast from the scalar form), and every batched op in the decode
path is row-wise independent — so a request served from a busy slot pool must
produce EXACTLY the token stream it produces running alone. These tests pin
that, plus the slot lifecycle: mid-flight admission, retirement on
length/EOS, slot reuse, and the per-slot state ops the engine is built on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import generate
from repro.models.model import (init_decode_slot, init_decode_state,
                                model_init, prefill, write_decode_slot)
from repro.serving import ServingEngine
from repro.serving.scheduler import FIFOScheduler, Request

MAX_TOKENS = 48


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


def _static_tokens(params, cfg, prompt, gen):
    """Reference: the request alone through static-batch generate(), with the
    same cache capacity as the pool."""
    res = generate(params, cfg, jnp.asarray(prompt)[None, :], gen,
                   max_len=MAX_TOKENS)
    return np.asarray(res["tokens"][0]).tolist()


@pytest.mark.parametrize("arch", ["llama_moe_4_16", "starcoder2-3b"])
def test_staggered_arrivals_bit_identical_with_slot_reuse(arch):
    """Requests arriving at steps {0, 3, 7} with mixed gen lengths on a
    2-slot pool: every stream equals running alone, and a retired slot is
    reused by a later request."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (12, 12, 16, 12)]
    gens = [8, 5, 7, 6]
    arrivals = [0, 3, 7, 7]

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS)
    rids = [eng.submit(p, g, arrival_step=a)
            for p, g, a in zip(prompts, gens, arrivals)]
    fin = eng.run()

    for rid, p, g in zip(rids, prompts, gens):
        assert fin[rid].tokens == _static_tokens(params, cfg, p, g), \
            f"request {rid} diverged from static-batch generate()"

    # 4 requests over 2 slots: at least one slot served multiple requests
    slots = [fin[rid].slot for rid in rids]
    assert len(slots) == 4 and max(np.bincount(slots)) >= 2
    assert eng.stats()["finished"] == 4
    assert not eng.pool.any_active()


def test_eos_retires_early_and_slot_is_reacquired():
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(1)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    ref0 = _static_tokens(params, cfg, p0, 8)
    eos = ref0[2]                       # force retirement after 3 tokens

    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    r0 = eng.submit(p0, 8, eos_id=eos)
    r1 = eng.submit(p1, 4)              # queued behind the only slot
    fin = eng.run()

    stop = ref0.index(eos) + 1
    assert fin[r0].tokens == ref0[:stop]
    assert fin[r1].tokens == _static_tokens(params, cfg, p1, 4)
    assert fin[r0].slot == fin[r1].slot == 0


def test_slot_ops_write_then_reset_roundtrip():
    """write_decode_slot installs a single-request prefill into one row and
    leaves the others untouched; init_decode_slot restores the empty state."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32))[None, :]

    pool = init_decode_state(cfg, 3, MAX_TOKENS, per_slot_t=True)
    empty = jax.tree.map(lambda a: np.asarray(a), pool)
    src, _ = prefill(params, prompt, cfg, max_len=MAX_TOKENS)

    filled = write_decode_slot(pool, 1, src)
    assert int(filled["t"][1]) == 10 and int(filled["t"][0]) == 0
    np.testing.assert_array_equal(
        np.asarray(filled["k"][:, 1]), np.asarray(src["k"][:, 0]))
    np.testing.assert_array_equal(
        np.asarray(filled["go"].scores[:, 1]),
        np.asarray(src["go"].scores[:, 0]))
    # neighbours untouched
    np.testing.assert_array_equal(np.asarray(filled["k"][:, 0]),
                                  empty["k"][:, 0])
    np.testing.assert_array_equal(np.asarray(filled["go"].scores[:, 2]),
                                  empty["go"].scores[:, 2])

    reset = init_decode_slot(filled, 1)
    assert int(reset["t"][1]) == 0
    assert bool(jnp.isneginf(reset["go"].scores[:, 1]).all())
    assert bool((reset["go"].token_ids[:, 1] == -1).all())
    assert bool((reset["k"][:, 1] == 0).all())


def test_scheduler_policy():
    sched = FIFOScheduler(max_slots=2, max_tokens=32, max_queue=2)

    def req(i, plen=8, gen=8, step=0):
        return Request(request_id=i, prompt=np.zeros(plen, np.int32),
                       max_new_tokens=gen, arrival_step=step)

    with pytest.raises(ValueError):    # prompt + gen exceeds max_tokens
        sched.submit(req(0, plen=30, gen=8))

    sched.submit(req(1))
    sched.submit(req(2))
    with pytest.raises(RuntimeError):  # backlog bound
        sched.submit(req(3))
    with pytest.raises(RuntimeError):  # deferred arrivals count too
        sched.submit(req(3, step=9))

    assert sched.next_admission(num_active=2) is None   # pool full
    assert sched.next_admission(num_active=0).request_id == 1   # FIFO
    assert sched.next_admission(num_active=1).request_id == 2

    sched.submit(req(4, step=5))       # trace-replay arrival
    assert not sched.queue and sched.has_pending()
    assert sched.poll(4) == []
    assert [r.request_id for r in sched.poll(5)] == [4]


def test_engine_pallas_backend_bit_identical():
    """Continuous batching on the Pallas grouped-GEMM engine: the GO-decode
    selected-experts GEMM and the flattened prefill plan must stream the
    exact same greedy tokens as the static generate() path."""
    import dataclasses
    cfg = get_config("llama_moe_4_16", smoke=True)
    cfg = cfg.with_overrides(
        moe=dataclasses.replace(cfg.moe, backend="pallas", gmm_block_rows=8))
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(3)]

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=24)
    rids = [eng.submit(p, 4, arrival_step=a)
            for p, a in zip(prompts, [0, 0, 2])]
    fin = eng.run()
    assert eng.stats()["moe_backend"] == "pallas"

    for rid, p in zip(rids, prompts):
        ref = generate(params, cfg, jnp.asarray(p)[None, :], 4, max_len=24)
        assert fin[rid].tokens == np.asarray(ref["tokens"][0]).tolist(), \
            f"request {rid} diverged from static generate() on pallas"


def test_engine_rejects_oversized_request():
    cfg, params = _setup("llama_moe_4_16")
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=16)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), 8)


# ------------------------------------------------- temperature/top-p sampling

def test_sampling_top_p_epsilon_equals_greedy():
    """top_p -> 0 keeps only the argmax in the nucleus, so a sampling
    request must emit the EXACT greedy stream — pinning the top-p filter
    end to end through the sampled decode step and the sampled first
    token."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32)
    ref = _static_tokens(params, cfg, p, 6)

    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    rid = eng.submit(p, 6, temperature=1.0, top_p=1e-9, seed=123)
    fin = eng.run()
    assert fin[rid].tokens == ref


def test_sampling_deterministic_and_mixed_pool():
    """Sampled requests are reproducible given a seed, and a greedy request
    sharing the pool with a sampled one still emits its exact greedy
    stream (row-wise independence of the sampled step)."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(5)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32)
              for _ in range(2))
    ref_greedy = _static_tokens(params, cfg, p0, 6)

    def run_once():
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS)
        r_g = eng.submit(p0, 6)                                   # greedy
        r_s = eng.submit(p1, 6, temperature=0.8, top_p=0.9, seed=7)
        fin = eng.run()
        return fin[r_g].tokens, fin[r_s].tokens

    g1, s1 = run_once()
    g2, s2 = run_once()
    assert g1 == g2 == ref_greedy
    assert s1 == s2                        # same seed -> same stream
    assert all(0 <= t < cfg.vocab_size for t in s1)


def test_sampling_rejects_bad_top_p():
    cfg, params = _setup("llama_moe_4_16")
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(4, np.int32), 2, temperature=1.0, top_p=0.0)


# ------------------------------------------------- prompt-length bucketing

def test_bucketed_prefill_matches_unpadded_dense():
    """Dense arch (causal attention + rowwise MLP): a right-padded prefill
    with valid_len must reproduce the unpadded prefill — same last-token
    logits, same KV rows for the real positions, decode position at the
    true length."""
    from repro.models.model import prefill
    cfg, params = _setup("starcoder2-3b")
    rng = np.random.default_rng(6)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=11,
                                      dtype=np.int32))[None, :]
    padded = jnp.pad(prompt, ((0, 0), (0, 5)))            # 11 -> 16 bucket
    st_ref, lg_ref = prefill(params, prompt, cfg, max_len=MAX_TOKENS)
    st_b, lg_b = prefill(params, padded, cfg, max_len=MAX_TOKENS,
                         valid_len=jnp.asarray(11, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(st_b["t"]) == 11
    np.testing.assert_allclose(
        np.asarray(st_b["k"][:, :, :11], np.float32),
        np.asarray(st_ref["k"][:, :, :11], np.float32), rtol=1e-4, atol=1e-5)


def test_bucketed_prefill_keeps_pads_out_of_go_cache():
    """Expert-choice MoE: with valid_len the routing mask must keep padded
    positions out of the GO cache — every cached token id is a real
    position (or an empty -1 slot)."""
    from repro.models.model import prefill
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(7)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=9,
                                      dtype=np.int32))[None, :]
    padded = jnp.pad(prompt, ((0, 0), (0, 7)))            # 9 -> 16 bucket
    st, _ = prefill(params, padded, cfg, max_len=MAX_TOKENS,
                    valid_len=jnp.asarray(9, jnp.int32))
    tok_ids = np.asarray(st["go"].token_ids)              # [L, B, E, k]
    scores = np.asarray(st["go"].scores)
    real = tok_ids[tok_ids >= 0]
    assert real.size and (real < 9).all(), \
        f"padded positions leaked into the GO cache: {np.unique(real)}"
    # pad slots that exist only because C > valid_len carry zero weight
    assert (scores[(tok_ids >= 9)] <= 0).all()


def test_engine_bucketing_caps_prefill_compiles_and_streams():
    """Engine-level bucketing: mixed prompt lengths collapse onto
    power-of-two buckets (bounded prefill compile count) and, on a dense
    arch, every stream still equals the unbucketed engine's."""
    cfg, params = _setup("starcoder2-3b")
    rng = np.random.default_rng(8)
    lens = [5, 6, 7, 9, 12, 13]
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in lens]

    def run(buckets):
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                            prompt_buckets=buckets)
        ids = [eng.submit(p, 5) for p in prompts]
        fin = eng.run()
        return [fin[i].tokens for i in ids], eng

    ref, eng_ref = run(False)
    got, eng_b = run(True)
    assert got == ref
    assert eng_b.stats()["prefill_lengths"] == [8, 16]    # 6 lengths -> 2
    assert len(eng_ref.stats()["prefill_lengths"]) == len(set(lens))
