"""Continuous-batching engine == static-batch generate(), bit for bit.

The engine and generate() share the same compiled decode kernels (per-slot
positions broadcast from the scalar form), and every batched op in the decode
path is row-wise independent — so a request served from a busy slot pool must
produce EXACTLY the token stream it produces running alone. These tests pin
that, plus the slot lifecycle: mid-flight admission, retirement on
length/EOS, slot reuse, and the per-slot state ops the engine is built on."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import generate
from repro.models.model import (init_decode_slot, init_decode_state,
                                model_init, prefill, write_decode_slot)
from repro.serving import (QueueFull, RequestStatus, RequestTooLarge,
                           ServingEngine)
from repro.serving.scheduler import FIFOScheduler, Request

MAX_TOKENS = 48


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


def _quant_lane() -> bool:
    return os.environ.get("REPRO_KV_QUANT", "").strip().lower() \
        not in ("", "0", "false", "no")


def _quant_active(eng) -> bool:
    return eng.cfg.kv_quant != "none"


def _static_tokens(params, cfg, prompt, gen, max_tokens=MAX_TOKENS,
                   **pool_kw):
    """Reference: the request alone through static-batch generate(), with the
    same cache capacity as the pool.

    Under the REPRO_KV_QUANT lane the engine under test serves from int8
    pages, whose logits sit a bounded — not zero — distance from fp32, so
    near-tied greedy argmaxes can flip on smoke weights. The invariant these
    tests pin is solo-vs-pooled bit-identity, so the lane reference is the
    same request served ALONE on a 1-slot engine with the same page geometry
    (pool_kw; kv_quant resolves identically from the env). Outside the lane
    pool_kw is ignored and the fp32 static path pins exact equality."""
    if _quant_lane():
        eng = ServingEngine(params, cfg, num_slots=1, max_tokens=max_tokens,
                            **pool_kw)
        if _quant_active(eng):
            rid = eng.submit(np.asarray(prompt, np.int32), gen)
            return eng.run()[rid].tokens
    res = generate(params, cfg, jnp.asarray(prompt)[None, :], gen,
                   max_len=max_tokens)
    return np.asarray(res["tokens"][0]).tolist()


@pytest.mark.parametrize("arch", ["llama_moe_4_16", "starcoder2-3b"])
def test_staggered_arrivals_bit_identical_with_slot_reuse(arch):
    """Requests arriving at steps {0, 3, 7} with mixed gen lengths on a
    2-slot pool: every stream equals running alone, and a retired slot is
    reused by a later request."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (12, 12, 16, 12)]
    gens = [8, 5, 7, 6]
    arrivals = [0, 3, 7, 7]

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS)
    rids = [eng.submit(p, g, arrival_step=a)
            for p, g, a in zip(prompts, gens, arrivals)]
    fin = eng.run()

    for rid, p, g in zip(rids, prompts, gens):
        assert fin[rid].tokens == _static_tokens(params, cfg, p, g), \
            f"request {rid} diverged from static-batch generate()"

    # 4 requests over 2 slots: at least one slot served multiple requests
    slots = [fin[rid].slot for rid in rids]
    assert len(slots) == 4 and max(np.bincount(slots)) >= 2
    assert eng.stats()["finished"] == 4
    assert not eng.pool.any_active()


def test_eos_retires_early_and_slot_is_reacquired():
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(1)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    ref0 = _static_tokens(params, cfg, p0, 8)
    eos = ref0[2]                       # force retirement after 3 tokens

    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    r0 = eng.submit(p0, 8, eos_id=eos)
    r1 = eng.submit(p1, 4)              # queued behind the only slot
    fin = eng.run()

    stop = ref0.index(eos) + 1
    assert fin[r0].tokens == ref0[:stop]
    assert fin[r1].tokens == _static_tokens(params, cfg, p1, 4)
    assert fin[r0].slot == fin[r1].slot == 0


def test_slot_ops_write_then_reset_roundtrip():
    """write_decode_slot installs a single-request prefill into one row and
    leaves the others untouched; init_decode_slot restores the empty state."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32))[None, :]

    pool = init_decode_state(cfg, 3, MAX_TOKENS, per_slot_t=True)
    empty = jax.tree.map(lambda a: np.asarray(a), pool)
    src, _ = prefill(params, prompt, cfg, max_len=MAX_TOKENS)

    filled = write_decode_slot(pool, 1, src)
    assert int(filled["t"][1]) == 10 and int(filled["t"][0]) == 0
    np.testing.assert_array_equal(
        np.asarray(filled["k"][:, 1]), np.asarray(src["k"][:, 0]))
    np.testing.assert_array_equal(
        np.asarray(filled["go"].scores[:, 1]),
        np.asarray(src["go"].scores[:, 0]))
    # neighbours untouched
    np.testing.assert_array_equal(np.asarray(filled["k"][:, 0]),
                                  empty["k"][:, 0])
    np.testing.assert_array_equal(np.asarray(filled["go"].scores[:, 2]),
                                  empty["go"].scores[:, 2])

    reset = init_decode_slot(filled, 1)
    assert int(reset["t"][1]) == 0
    assert bool(jnp.isneginf(reset["go"].scores[:, 1]).all())
    assert bool((reset["go"].token_ids[:, 1] == -1).all())
    assert bool((reset["k"][:, 1] == 0).all())


# ------------------------------------------------------------ paged KV pool

@pytest.mark.parametrize("arch", ["llama_moe_4_16", "starcoder2-3b"])
def test_paged_engine_bit_identical_to_dense(arch):
    """The block-table paged pool must stream EXACTLY what the dense pool
    streams for greedy decode — same staggered arrivals, same slot reuse —
    and hand every page back to the allocator when the trace drains."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (12, 12, 16, 12)]
    gens = [8, 5, 7, 6]
    arrivals = [0, 3, 7, 7]

    def run(paged):
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                            paged=paged, page_size=8)
        rids = [eng.submit(p, g, arrival_step=a)
                for p, g, a in zip(prompts, gens, arrivals)]
        fin = eng.run()
        return [fin[r].tokens for r in rids], eng

    ref, _ = run(False)
    got, eng = run(True)
    assert got == ref, "paged streams diverged from dense"
    assert got[0] == _static_tokens(params, cfg, prompts[0], gens[0],
                                    paged=True, page_size=8)
    assert eng.pool.alloc.pages_in_use == 0, "pages leaked after drain"
    eng.pool.alloc.check()
    assert eng.stats()["paged"] and eng.stats()["page_size"] == 8


def test_paged_tight_budget_serializes_without_deadlock():
    """With pages for only ~one request, admission must hold the second
    request back (pages-reservable gate, not just slot-free) and admit it
    when the first retires — same streams, no deadlock, no aliasing."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(2)]
    refs = [_static_tokens(params, cfg, p, 6, paged=True, page_size=8)
            for p in prompts]

    # each request needs ceil((12 + 6) / 8) = 3 pages; give the pool 4
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, num_pages=1 + 4)
    rids = [eng.submit(p, 6) for p in prompts]
    fin = eng.run()
    assert [fin[r].tokens for r in rids] == refs
    # the second request could not have shared the pool with the first
    assert fin[rids[1]].admit_step >= fin[rids[0]].finish_step
    assert eng.pool.alloc.pages_in_use == 0


def test_paged_pool_write_reset_roundtrip():
    """Paged slot ops: the scattered pages reproduce the prefill KV rows
    exactly through the block-table gather; retirement nulls the block
    table and resets the GO rows to -inf on the allocator's free path."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32))[None, :]
    src, _ = prefill(params, prompt, cfg, max_len=MAX_TOKENS)

    ps, P = 8, MAX_TOKENS // 8
    pool = init_decode_state(cfg, 3, MAX_TOKENS, per_slot_t=True,
                             paged=(3 * P + 1, ps))
    row = np.zeros(P, np.int32)
    row[:2] = [5, 2]                      # 10 prompt tokens -> 2 pages
    filled = write_decode_slot(pool, 1, src, page_ids=jnp.asarray(row))
    assert int(filled["t"][1]) == 10
    np.testing.assert_array_equal(np.asarray(filled["block_table"][1]), row)
    gathered = np.asarray(filled["k_pages"][:, row[:2]]).reshape(
        cfg.num_layers, 2 * ps, cfg.num_kv_heads, -1)
    np.testing.assert_array_equal(
        gathered[:, :10], np.asarray(src["k"][:, 0, :10]))
    np.testing.assert_array_equal(
        np.asarray(filled["go"].scores[:, 1]),
        np.asarray(src["go"].scores[:, 0]))

    reset = init_decode_slot(filled, 1)
    assert (np.asarray(reset["block_table"][1]) == 0).all()
    assert bool(jnp.isneginf(reset["go"].scores[:, 1]).all())
    assert int(reset["t"][1]) == 0


def test_paged_pool_rejects_unsupported_shapes():
    cfg, params = _setup("llama_moe_4_16")
    with pytest.raises(ValueError):      # max_tokens not page-granular
        ServingEngine(params, cfg, num_slots=1, max_tokens=20, paged=True,
                      page_size=16)
    xl = get_config("xlstm-1.3b", smoke=True)
    with pytest.raises(ValueError):      # recurrent arch has no KV pages
        init_decode_state(xl, 1, 16, per_slot_t=True, paged=(5, 8))
    # a request whose worst case exceeds the WHOLE page pool could never
    # reserve — reject at submit (the paged analogue of the max_tokens
    # check) instead of stalling the admission queue forever
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, num_pages=1 + 2)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(20, np.int32), 8)   # needs 4 pages, pool has 2


def test_scheduler_policy():
    sched = FIFOScheduler(max_slots=2, max_tokens=32, max_queue=2)

    def req(i, plen=8, gen=8, step=0):
        return Request(request_id=i, prompt=np.zeros(plen, np.int32),
                       max_new_tokens=gen, arrival_step=step)

    with pytest.raises(ValueError):    # prompt + gen exceeds max_tokens
        sched.submit(req(0, plen=30, gen=8))

    sched.submit(req(1))
    sched.submit(req(2))
    with pytest.raises(RuntimeError):  # backlog bound
        sched.submit(req(3))
    with pytest.raises(RuntimeError):  # deferred arrivals count too
        sched.submit(req(3, step=9))

    assert sched.next_admission(num_active=2) is None   # pool full
    assert sched.next_admission(num_active=0).request_id == 1   # FIFO
    assert sched.next_admission(num_active=1).request_id == 2

    sched.submit(req(4, step=5))       # trace-replay arrival
    assert not sched.queue and sched.has_pending()
    assert sched.poll(4) == []
    assert [r.request_id for r in sched.poll(5)] == [4]


def test_scheduler_priority_heap_fifo_within_level():
    """Lower priority value admits first; EQUAL priorities admit in strict
    submit order (starvation-freedom: a steady stream of same-priority
    arrivals can never leapfrog an older request)."""
    sched = FIFOScheduler(max_slots=1, max_tokens=64)

    def req(i, prio=0, step=0):
        return Request(request_id=i, prompt=np.zeros(4, np.int32),
                       max_new_tokens=4, priority=prio, arrival_step=step)

    for i in range(4):
        sched.submit(req(i, prio=1))      # same level, submit order 0..3
    sched.submit(req(9, prio=0))          # urgent: jumps the level
    sched.submit(req(10, prio=2))         # background: drains last
    order = []
    while sched.queue:
        order.append(sched.next_admission(0).request_id)
    assert order == [9, 0, 1, 2, 3, 10]

    # can_admit gates the HEAD only — a blocked head blocks the queue
    # instead of letting later requests overtake (keeps FIFO starvation-free)
    sched.submit(req(20))
    sched.submit(req(21))
    assert sched.next_admission(0, can_admit=lambda r: False) is None
    assert sched.next_admission(0).request_id == 20

    # trace-replay arrivals keep their SUBMIT order inside a level — one
    # total order decides ties no matter how arrivals interleave
    sched2 = FIFOScheduler(max_slots=1, max_tokens=64)
    sched2.submit(req(0, step=5))
    sched2.submit(req(1, step=5))
    sched2.submit(req(2, step=3))
    sched2.poll(5)
    assert [sched2.next_admission(0).request_id for _ in range(3)] == [0, 1, 2]


def test_engine_priority_starvation_free():
    """Engine-level: a lower-priority-value request submitted last still
    overtakes the whole backlog (admission happens at tick time), and the
    equal-priority backlog then drains in strict submit order on a 1-slot
    pool — nobody starves."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(4)]
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    r0 = eng.submit(prompts[0], 3)
    r1 = eng.submit(prompts[1], 3)
    r2 = eng.submit(prompts[2], 3)
    r_hi = eng.submit(prompts[3], 3, priority=-1)  # overtakes the backlog
    fin = eng.run()
    admits = {r: fin[r].admit_step for r in (r0, r1, r2, r_hi)}
    assert admits[r_hi] < admits[r0] < admits[r1] < admits[r2]


def test_engine_pallas_backend_bit_identical():
    """Continuous batching on the Pallas grouped-GEMM engine: the GO-decode
    selected-experts GEMM and the flattened prefill plan must stream the
    exact same greedy tokens as the static generate() path."""
    import dataclasses
    cfg = get_config("llama_moe_4_16", smoke=True)
    cfg = cfg.with_overrides(
        moe=dataclasses.replace(cfg.moe, backend="pallas", gmm_block_rows=8))
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(3)]

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=24)
    rids = [eng.submit(p, 4, arrival_step=a)
            for p, a in zip(prompts, [0, 0, 2])]
    fin = eng.run()
    assert eng.stats()["moe_backend"] == "pallas"

    for rid, p in zip(rids, prompts):
        ref = _static_tokens(params, cfg, p, 4, max_tokens=24)
        assert fin[rid].tokens == ref, \
            f"request {rid} diverged from static generate() on pallas"


def test_engine_rejects_oversized_request():
    cfg, params = _setup("llama_moe_4_16")
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=16)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), 8)


# ------------------------------------------------- temperature/top-p sampling

def test_sampling_top_p_epsilon_equals_greedy():
    """top_p -> 0 keeps only the argmax in the nucleus, so a sampling
    request must emit the EXACT greedy stream — pinning the top-p filter
    end to end through the sampled decode step and the sampled first
    token."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32)
    ref = _static_tokens(params, cfg, p, 6)

    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    rid = eng.submit(p, 6, temperature=1.0, top_p=1e-9, seed=123)
    fin = eng.run()
    assert fin[rid].tokens == ref


def test_sampling_deterministic_and_mixed_pool():
    """Sampled requests are reproducible given a seed, and a greedy request
    sharing the pool with a sampled one still emits its exact greedy
    stream (row-wise independence of the sampled step)."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(5)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32)
              for _ in range(2))
    ref_greedy = _static_tokens(params, cfg, p0, 6)

    def run_once():
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS)
        r_g = eng.submit(p0, 6)                                   # greedy
        r_s = eng.submit(p1, 6, temperature=0.8, top_p=0.9, seed=7)
        fin = eng.run()
        return fin[r_g].tokens, fin[r_s].tokens

    g1, s1 = run_once()
    g2, s2 = run_once()
    assert g1 == g2 == ref_greedy
    assert s1 == s2                        # same seed -> same stream
    assert all(0 <= t < cfg.vocab_size for t in s1)


def test_sampling_rejects_bad_top_p():
    cfg, params = _setup("llama_moe_4_16")
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(4, np.int32), 2, temperature=1.0, top_p=0.0)


# ------------------------------------------------- prompt-length bucketing

def test_bucketed_prefill_matches_unpadded_dense():
    """Dense arch (causal attention + rowwise MLP): a right-padded prefill
    with valid_len must reproduce the unpadded prefill — same last-token
    logits, same KV rows for the real positions, decode position at the
    true length."""
    from repro.models.model import prefill
    cfg, params = _setup("starcoder2-3b")
    rng = np.random.default_rng(6)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=11,
                                      dtype=np.int32))[None, :]
    padded = jnp.pad(prompt, ((0, 0), (0, 5)))            # 11 -> 16 bucket
    st_ref, lg_ref = prefill(params, prompt, cfg, max_len=MAX_TOKENS)
    st_b, lg_b = prefill(params, padded, cfg, max_len=MAX_TOKENS,
                         valid_len=jnp.asarray(11, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(st_b["t"]) == 11
    np.testing.assert_allclose(
        np.asarray(st_b["k"][:, :, :11], np.float32),
        np.asarray(st_ref["k"][:, :, :11], np.float32), rtol=1e-4, atol=1e-5)


def test_bucketed_prefill_keeps_pads_out_of_go_cache():
    """Expert-choice MoE: with valid_len the routing mask must keep padded
    positions out of the GO cache — every cached token id is a real
    position (or an empty -1 slot)."""
    from repro.models.model import prefill
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(7)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=9,
                                      dtype=np.int32))[None, :]
    padded = jnp.pad(prompt, ((0, 0), (0, 7)))            # 9 -> 16 bucket
    st, _ = prefill(params, padded, cfg, max_len=MAX_TOKENS,
                    valid_len=jnp.asarray(9, jnp.int32))
    tok_ids = np.asarray(st["go"].token_ids)              # [L, B, E, k]
    scores = np.asarray(st["go"].scores)
    real = tok_ids[tok_ids >= 0]
    assert real.size and (real < 9).all(), \
        f"padded positions leaked into the GO cache: {np.unique(real)}"
    # pad slots that exist only because C > valid_len carry zero weight
    assert (scores[(tok_ids >= 9)] <= 0).all()


def test_engine_bucketing_caps_prefill_compiles_and_streams():
    """Engine-level bucketing: mixed prompt lengths collapse onto
    power-of-two buckets (bounded prefill compile count) and, on a dense
    arch, every stream still equals the unbucketed engine's."""
    cfg, params = _setup("starcoder2-3b")
    rng = np.random.default_rng(8)
    lens = [5, 6, 7, 9, 12, 13]
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in lens]

    def run(buckets):
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                            prompt_buckets=buckets)
        ids = [eng.submit(p, 5) for p in prompts]
        fin = eng.run()
        return [fin[i].tokens for i in ids], eng

    ref, eng_ref = run(False)
    got, eng_b = run(True)
    if _quant_active(eng_b):
        # Bucket padding perturbs prefill KV rows by ~1e-4 (pinned above),
        # which can move a page's int8 amax — bucketed and unbucketed
        # quantized streams are boundedly divergent, not bit-equal. Pin the
        # invariant that survives quantization: pooled == solo at the SAME
        # bucketing.
        for p, t in zip(prompts, got):
            assert t == _static_tokens(params, cfg, p, 5, prompt_buckets=True)
    else:
        assert got == ref
    assert eng_b.stats()["prefill_lengths"] == [8, 16]    # 6 lengths -> 2
    assert len(eng_ref.stats()["prefill_lengths"]) == len(set(lens))


# --------------------------------------------------------- chunked prefill

def test_chunked_prefill_matches_one_shot_dense_arch():
    """Dense arch: admitting long prompts one chunk per tick must stream
    exactly what one-shot prefill streams — same tokens per request —
    while short prompts keep taking the one-shot path. Works on the dense
    and the paged pool."""
    cfg, params = _setup("starcoder2-3b")
    rng = np.random.default_rng(12)
    lens = [30, 12, 25]                      # 30/25 chunk, 12 one-shot
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in lens]

    def run(**kw):
        eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                            **kw)
        rids = [eng.submit(p, 6, arrival_step=a)
                for p, a in zip(prompts, [0, 1, 2])]
        fin = eng.run()
        return [fin[r].tokens for r in rids], eng

    ref, _ = run()
    got, eng = run(prefill_chunk=16)
    got_paged, eng_p = run(prefill_chunk=16, paged=True, page_size=16)
    if _quant_active(eng_p):
        # One-shot prefill quantizes each page once against its final amax;
        # chunked prefill rescales already-written int8 rows as later chunks
        # grow a page's amax. Both are deterministic but round differently
        # (up to 1 LSB per rescale), so chunked-vs-one-shot is boundedly
        # divergent, not bit-equal. Pin what stays exact under int8: the
        # chunked stream is reproducible, and forced vs explicit paging at
        # the same geometry cannot change it.
        got_paged2, _ = run(prefill_chunk=16, paged=True, page_size=16)
        assert got_paged == got_paged2, \
            "chunked quantized streams not deterministic"
        if _quant_active(eng):
            assert got == got_paged, "forced paging changed the chunked stream"
        else:
            assert got == ref, "chunked streams diverged from one-shot"
    else:
        assert got == ref, "chunked streams diverged from one-shot"
        assert got_paged == ref, "paged+chunked streams diverged"
    assert eng.chunk_ticks == 4              # 30 -> 2 chunks, 25 -> 2 chunks
    assert ref[0] == _static_tokens(params, cfg, prompts[0], 6)


def test_chunked_prefill_moe_deterministic_and_go_clean():
    """Expert-choice MoE: chunked prefill routes per chunk (capacity from
    the chunk length), so streams are deterministic per chunking — two runs
    agree — and every positively-scored GO entry is a REAL prompt position
    (pads and future positions can never be cached)."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, size=27, dtype=np.int32)

    def run():
        eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                            paged=True, page_size=8, prefill_chunk=8)
        rid = eng.submit(prompt, 2)          # short gen: cache ~= prefill
        fin = eng.run()
        return fin[rid].tokens, eng

    t1, eng = run()
    t2, _ = run()
    assert t1 == t2 and len(t1) == 2
    assert eng.chunk_ticks == 4              # ceil(27/8) chunks per run

    # rebuild the chunked cache directly and inspect it
    from repro.models.model import prefill_chunk as pc
    st = init_decode_state(cfg, 1, MAX_TOKENS)
    padded = np.pad(prompt, (0, 32 - 27))
    for i in range(4):
        st, _ = jax.jit(pc, static_argnames="cfg")(
            params, st, jnp.asarray(padded[8 * i:8 * (i + 1)])[None, :],
            cfg, jnp.asarray(8 * i, jnp.int32),
            jnp.asarray(min(8, 27 - 8 * i), jnp.int32))
    ids = np.asarray(st["go"].token_ids)
    scores = np.asarray(st["go"].scores)
    assert (ids[scores > 0] < 27).all() and (ids[scores > 0] >= 0).all(), \
        "non-prompt position cached with positive score"
    assert int(st["t"]) == 27


# ----------------------------------------------------------- fault domain

def test_deadline_expires_queued_request_without_touching_survivors():
    """deadline_s counts from submission, queue wait included: a request
    that blows it while still queued retires TIMEOUT with zero tokens, and
    the stream it was queued behind is untouched."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(21)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    r0 = eng.submit(p0, 8)
    r1 = eng.submit(p1, 6, deadline_s=0.0)    # expires while queued
    fin = eng.run()
    assert fin[r1].status is RequestStatus.TIMEOUT
    assert fin[r1].tokens == [] and fin[r1].fail_reason
    assert fin[r0].status is RequestStatus.DONE
    assert fin[r0].tokens == _static_tokens(params, cfg, p0, 8)
    assert eng.stats()["statuses"] == {"DONE": 1, "TIMEOUT": 1}


def test_max_wall_retires_mid_decode_and_frees_the_slot():
    """max_wall_s counts from first admission: an admitted stream that
    blows it is retired TIMEOUT mid-decode — partial tokens kept (a true
    prefix of its solo stream), slot + pages freed — while the cohabiting
    stream stays bit-identical."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(22)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS)
    r0 = eng.submit(p0, 24, max_wall_s=0.0)   # blown right after admission
    r1 = eng.submit(p1, 8)
    fin = eng.run()
    assert fin[r0].status is RequestStatus.TIMEOUT
    ref0 = _static_tokens(params, cfg, p0, 24)
    assert 0 < len(fin[r0].tokens) < 24
    assert fin[r0].tokens == ref0[:len(fin[r0].tokens)]
    assert fin[r1].status is RequestStatus.DONE
    assert fin[r1].tokens == _static_tokens(params, cfg, p1, 8)
    assert not eng.pool.any_active()
    if eng.pool.paged:
        assert eng.pool.alloc.pages_in_use == 0


def test_cancel_across_the_request_lifecycle():
    """cancel() retires a request wherever it is: queued (no tokens),
    actively decoding (partial prefix kept, slot freed) — and returns False
    for unknown ids and double cancels."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(23)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    r0 = eng.submit(p0, 16)
    r1 = eng.submit(p1, 8)                    # queued behind the only slot
    for _ in range(6):
        eng.step()
    assert eng.cancel(r1)                     # still queued
    assert eng.cancel(r0)                     # mid-decode
    assert not eng.cancel(r0)                 # already terminal
    assert not eng.cancel(10 ** 6)            # unknown id
    fin = eng.run()
    ref0 = _static_tokens(params, cfg, p0, 16)
    assert fin[r0].status is RequestStatus.CANCELLED
    assert 0 < len(fin[r0].tokens) < 16
    assert fin[r0].tokens == ref0[:len(fin[r0].tokens)]
    assert fin[r1].status is RequestStatus.CANCELLED and fin[r1].tokens == []
    assert not eng.pool.any_active()
    if eng.pool.paged:
        assert eng.pool.alloc.pages_in_use == 0


def test_cancel_mid_chunk_prefill_frees_claimed_pages():
    """Cancelling a request whose chunked prefill is in flight must return
    its up-front page claim AND reservation to the allocator, and the pool
    must serve later requests as if it never existed."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(24)
    long_p = rng.integers(0, cfg.vocab_size, size=28, dtype=np.int32)
    p1 = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)  # one-shot
    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, prefill_chunk=8)
    r0 = eng.submit(long_p, 8)
    for _ in range(5):                        # chaos pressure may delay start
        eng.step()
        if eng._chunk_job is not None:
            break
    assert eng._chunk_job is not None and eng._chunk_job.req.request_id == r0
    assert eng.pool.alloc.pages_in_use > 0
    assert eng.cancel(r0)
    assert eng._chunk_job is None
    assert eng.pool.alloc.pages_in_use == 0
    eng.pool.alloc.check()
    assert eng.finished[r0].status is RequestStatus.CANCELLED
    r1 = eng.submit(p1, 6)
    fin = eng.run()
    assert fin[r1].tokens == _static_tokens(params, cfg, p1, 6,
                                            paged=True, page_size=8)


def test_queue_full_is_typed_and_counted():
    """The backlog cap raises QueueFull carrying the observed depth, old
    RuntimeError handlers still catch it, and the rejection is counted —
    without perturbing the admitted stream."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(25)
    p = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS,
                        max_queue=1)
    r0 = eng.submit(p, 4)
    with pytest.raises(QueueFull) as ei:
        eng.submit(p, 4)
    assert isinstance(ei.value, RuntimeError)
    assert ei.value.depth == 1 and ei.value.max_queue == 1
    assert eng.stats()["rejected"]["queue_full"] == 1
    fin = eng.run()
    assert fin[r0].status is RequestStatus.DONE
    assert fin[r0].tokens == _static_tokens(params, cfg, p, 4)


def test_oversized_rejection_is_typed_and_counted():
    """Requests that could NEVER fit fail fast at submit with
    RequestTooLarge (a ValueError subclass) on both bounds: the per-slot
    max_tokens and the paged pool's whole-pool page budget."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(26)
    big = rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=16)
    with pytest.raises(RequestTooLarge) as ei:
        eng.submit(big, 8)                    # 12 + 8 > 16: never fits a slot
    assert isinstance(ei.value, ValueError)
    assert eng.stats()["rejected"]["oversized"] == 1

    # paged whole-pool bound: tighter than max_tokens when the pool is small
    eng2 = ServingEngine(params, cfg, num_slots=2, max_tokens=48,
                         paged=True, page_size=8, num_pages=4)
    with pytest.raises(RequestTooLarge, match="pages"):
        eng2.submit(big, 24)                  # needs 5 pages, pool has 3
    assert eng2.stats()["rejected"]["oversized"] == 1


@pytest.mark.parametrize("arch", ["llama_moe_4_16", "starcoder2-3b"])
def test_page_pressure_preemption_resumes_bit_identical(arch):
    """The tentpole pin: two low-priority streams fill the page pool; a
    high-priority arrival evicts one (snapshot + page free), finishes, and
    the evicted stream resumes via block-table surgery — every stream,
    including the preempted-then-resumed one, equals running alone bit for
    bit, and the pool drains clean."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(2)
    lo = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
          for _ in range(2)]
    hi = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    eng = ServingEngine(params, cfg, num_slots=3, max_tokens=MAX_TOKENS,
                        paged=True, page_size=8, num_pages=9,
                        preemption=True)
    r_lo = [eng.submit(p, 24, priority=5) for p in lo]
    r_hi = eng.submit(hi, 8, priority=0, arrival_step=6)
    fin = eng.run()
    s = eng.stats()
    assert s["preemptions"] >= 1 and s["resumes"] >= 1
    for rid, p, g in [(r_lo[0], lo[0], 24), (r_lo[1], lo[1], 24),
                      (r_hi, hi, 8)]:
        assert fin[rid].status is RequestStatus.DONE
        assert fin[rid].tokens == _static_tokens(params, cfg, p, g,
                                                 paged=True, page_size=8), \
            f"request {rid} diverged after preemption churn"
    assert any(fin[r].preemptions >= 1 for r in r_lo)
    if eng.chaos is None:   # deterministic outside the env-chaos lane
        # the high-priority request overtook the stream evicted for it
        assert fin[r_hi].finish_step < max(fin[r].finish_step for r in r_lo)
    assert eng.pool.alloc.pages_in_use == 0
    eng.pool.alloc.check()
    eng.pool.audit()


@pytest.mark.parametrize("paged", [False, True])
def test_nan_poison_quarantines_one_slot_not_its_cohabitants(paged):
    """Poisoning one slot's decode state mid-flight retires THAT request
    FAILED ("non-finite logits") with its pre-poison prefix kept — and the
    cohabiting stream in the same pool finishes bit-identical."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(27)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    kw = dict(num_slots=2, max_tokens=MAX_TOKENS)
    gkw = {}                                  # ref geometry under quant lane
    if paged:
        kw.update(paged=True, page_size=8)
        gkw = dict(paged=True, page_size=8)
    eng = ServingEngine(params, cfg, **kw)
    r0 = eng.submit(p0, 16)
    r1 = eng.submit(p1, 16)
    for _ in range(40):                       # decode a few tokens first
        eng.step()
        slot0 = next((s for s, o in enumerate(eng.pool.owner)
                      if o is not None and o.request_id == r0), None)
        if slot0 is not None and \
                len(eng.pool.owner[slot0].tokens) >= 4:
            break
    eng.pool.poison_slot(slot0)
    fin = eng.run()
    assert fin[r0].status is RequestStatus.FAILED
    assert fin[r0].fail_reason == "non-finite logits"
    ref0 = _static_tokens(params, cfg, p0, 16, **gkw)
    assert 4 <= len(fin[r0].tokens) < 16
    assert fin[r0].tokens == ref0[:len(fin[r0].tokens)]
    ref1 = _static_tokens(params, cfg, p1, 16, **gkw)
    assert fin[r1].status is RequestStatus.DONE and fin[r1].tokens == ref1
    assert not eng.pool.any_active()
    assert eng.stats()["statuses"] == {"DONE": 1, "FAILED": 1}
