"""Continuous-batching engine == static-batch generate(), bit for bit.

The engine and generate() share the same compiled decode kernels (per-slot
positions broadcast from the scalar form), and every batched op in the decode
path is row-wise independent — so a request served from a busy slot pool must
produce EXACTLY the token stream it produces running alone. These tests pin
that, plus the slot lifecycle: mid-flight admission, retirement on
length/EOS, slot reuse, and the per-slot state ops the engine is built on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import generate
from repro.models.model import (init_decode_slot, init_decode_state,
                                model_init, prefill, write_decode_slot)
from repro.serving import ServingEngine
from repro.serving.scheduler import FIFOScheduler, Request

MAX_TOKENS = 48


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    return cfg, params


def _static_tokens(params, cfg, prompt, gen):
    """Reference: the request alone through static-batch generate(), with the
    same cache capacity as the pool."""
    res = generate(params, cfg, jnp.asarray(prompt)[None, :], gen,
                   max_len=MAX_TOKENS)
    return np.asarray(res["tokens"][0]).tolist()


@pytest.mark.parametrize("arch", ["llama_moe_4_16", "starcoder2-3b"])
def test_staggered_arrivals_bit_identical_with_slot_reuse(arch):
    """Requests arriving at steps {0, 3, 7} with mixed gen lengths on a
    2-slot pool: every stream equals running alone, and a retired slot is
    reused by a later request."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (12, 12, 16, 12)]
    gens = [8, 5, 7, 6]
    arrivals = [0, 3, 7, 7]

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=MAX_TOKENS)
    rids = [eng.submit(p, g, arrival_step=a)
            for p, g, a in zip(prompts, gens, arrivals)]
    fin = eng.run()

    for rid, p, g in zip(rids, prompts, gens):
        assert fin[rid].tokens == _static_tokens(params, cfg, p, g), \
            f"request {rid} diverged from static-batch generate()"

    # 4 requests over 2 slots: at least one slot served multiple requests
    slots = [fin[rid].slot for rid in rids]
    assert len(slots) == 4 and max(np.bincount(slots)) >= 2
    assert eng.stats()["finished"] == 4
    assert not eng.pool.any_active()


def test_eos_retires_early_and_slot_is_reacquired():
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(1)
    p0, p1 = (rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
              for _ in range(2))
    ref0 = _static_tokens(params, cfg, p0, 8)
    eos = ref0[2]                       # force retirement after 3 tokens

    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=MAX_TOKENS)
    r0 = eng.submit(p0, 8, eos_id=eos)
    r1 = eng.submit(p1, 4)              # queued behind the only slot
    fin = eng.run()

    stop = ref0.index(eos) + 1
    assert fin[r0].tokens == ref0[:stop]
    assert fin[r1].tokens == _static_tokens(params, cfg, p1, 4)
    assert fin[r0].slot == fin[r1].slot == 0


def test_slot_ops_write_then_reset_roundtrip():
    """write_decode_slot installs a single-request prefill into one row and
    leaves the others untouched; init_decode_slot restores the empty state."""
    cfg, params = _setup("llama_moe_4_16")
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=10, dtype=np.int32))[None, :]

    pool = init_decode_state(cfg, 3, MAX_TOKENS, per_slot_t=True)
    empty = jax.tree.map(lambda a: np.asarray(a), pool)
    src, _ = prefill(params, prompt, cfg, max_len=MAX_TOKENS)

    filled = write_decode_slot(pool, 1, src)
    assert int(filled["t"][1]) == 10 and int(filled["t"][0]) == 0
    np.testing.assert_array_equal(
        np.asarray(filled["k"][:, 1]), np.asarray(src["k"][:, 0]))
    np.testing.assert_array_equal(
        np.asarray(filled["go"].scores[:, 1]),
        np.asarray(src["go"].scores[:, 0]))
    # neighbours untouched
    np.testing.assert_array_equal(np.asarray(filled["k"][:, 0]),
                                  empty["k"][:, 0])
    np.testing.assert_array_equal(np.asarray(filled["go"].scores[:, 2]),
                                  empty["go"].scores[:, 2])

    reset = init_decode_slot(filled, 1)
    assert int(reset["t"][1]) == 0
    assert bool(jnp.isneginf(reset["go"].scores[:, 1]).all())
    assert bool((reset["go"].token_ids[:, 1] == -1).all())
    assert bool((reset["k"][:, 1] == 0).all())


def test_scheduler_policy():
    sched = FIFOScheduler(max_slots=2, max_tokens=32, max_queue=2)

    def req(i, plen=8, gen=8, step=0):
        return Request(request_id=i, prompt=np.zeros(plen, np.int32),
                       max_new_tokens=gen, arrival_step=step)

    with pytest.raises(ValueError):    # prompt + gen exceeds max_tokens
        sched.submit(req(0, plen=30, gen=8))

    sched.submit(req(1))
    sched.submit(req(2))
    with pytest.raises(RuntimeError):  # backlog bound
        sched.submit(req(3))
    with pytest.raises(RuntimeError):  # deferred arrivals count too
        sched.submit(req(3, step=9))

    assert sched.next_admission(num_active=2) is None   # pool full
    assert sched.next_admission(num_active=0).request_id == 1   # FIFO
    assert sched.next_admission(num_active=1).request_id == 2

    sched.submit(req(4, step=5))       # trace-replay arrival
    assert not sched.queue and sched.has_pending()
    assert sched.poll(4) == []
    assert [r.request_id for r in sched.poll(5)] == [4]


def test_engine_pallas_backend_bit_identical():
    """Continuous batching on the Pallas grouped-GEMM engine: the GO-decode
    selected-experts GEMM and the flattened prefill plan must stream the
    exact same greedy tokens as the static generate() path."""
    import dataclasses
    cfg = get_config("llama_moe_4_16", smoke=True)
    cfg = cfg.with_overrides(
        moe=dataclasses.replace(cfg.moe, backend="pallas", gmm_block_rows=8))
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(3)]

    eng = ServingEngine(params, cfg, num_slots=2, max_tokens=24)
    rids = [eng.submit(p, 4, arrival_step=a)
            for p, a in zip(prompts, [0, 0, 2])]
    fin = eng.run()
    assert eng.stats()["moe_backend"] == "pallas"

    for rid, p in zip(rids, prompts):
        ref = generate(params, cfg, jnp.asarray(p)[None, :], 4, max_len=24)
        assert fin[rid].tokens == np.asarray(ref["tokens"][0]).tolist(), \
            f"request {rid} diverged from static generate() on pallas"


def test_engine_rejects_oversized_request():
    cfg, params = _setup("llama_moe_4_16")
    eng = ServingEngine(params, cfg, num_slots=1, max_tokens=16)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), 8)
