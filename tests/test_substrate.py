"""Substrate: data pipeline, optimizer, checkpointing, fault runtime,
elastic re-meshing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_lr)
from repro.runtime.elastic import remesh_plan
from repro.runtime.fault import RestartRequired, StepSupervisor


# ----------------------------------------------------------------- pipeline

def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    c1, c2 = SyntheticCorpus(cfg), SyntheticCorpus(cfg)
    b_a = c1.batch(5)
    b_b = c2.batch(5)                       # fresh instance, same step
    np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])
    assert not np.array_equal(c1.batch(6)["tokens"], b_a["tokens"])


def test_data_shards_disjoint_and_cover():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=0)
    c = SyntheticCorpus(cfg)
    full = c.batch(3)["tokens"]
    parts = [c.batch(3, shard=i, num_shards=4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=0)
    b = SyntheticCorpus(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------- optimizer

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    assert float(cosine_lr(0, base_lr=1.0, warmup=10, total=100)) < 0.2
    peak = float(cosine_lr(10, base_lr=1.0, warmup=10, total=100))
    end = float(cosine_lr(99, base_lr=1.0, warmup=10, total=100))
    assert peak > 0.9 and end < peak


# --------------------------------------------------------------- checkpoint

def test_ckpt_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ckpt.save(str(tmp_path), 10, tree)
    ckpt.save(str(tmp_path), 20, tree)
    assert ckpt.latest_step(str(tmp_path)) == 20
    back = ckpt.restore(str(tmp_path), 10, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))


def test_uncommitted_ckpt_ignored(tmp_path):
    tree = {"a": jnp.ones(3)}
    ckpt.save(str(tmp_path), 1, tree)
    # fake a crashed write: directory without COMMITTED marker
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_ckpt_async_writer(tmp_path):
    tree = {"a": jnp.arange(1000)}
    w = ckpt.save(str(tmp_path), 5, tree, async_=True)
    w.wait()
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_ckpt_gc_keeps_last(tmp_path):
    tree = {"a": jnp.ones(2)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree)
    assert ckpt.committed_steps(str(tmp_path)) == [3, 4, 5]


def test_ckpt_truncated_leaf_raises_typed_error(tmp_path):
    """The classic crash corruption — a leaf file cut short — must raise
    CorruptCheckpoint NAMING the leaf, before anything is device_put."""
    tree = {"a": jnp.arange(64, dtype=jnp.float32), "b": jnp.ones(4)}
    ckpt.save(str(tmp_path), 1, tree)
    f = tmp_path / "step_00000001" / "arr_0.npy"
    f.write_bytes(f.read_bytes()[:-16])
    with pytest.raises(ckpt.CorruptCheckpoint, match="arr_0.npy.*truncated"):
        ckpt.restore(str(tmp_path), 1, tree)


def test_ckpt_garbage_header_and_shape_mismatch_raise(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4)}
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(ckpt.CorruptCheckpoint, match="leaf 0.*ckpt shape"):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    (tmp_path / "step_00000001" / "arr_0.npy").write_bytes(b"not an npy")
    with pytest.raises(ckpt.CorruptCheckpoint, match="arr_0.npy.*header"):
        ckpt.restore(str(tmp_path), 1, tree)
    os.unlink(tmp_path / "step_00000001" / "arr_0.npy")
    with pytest.raises(ckpt.CorruptCheckpoint, match="missing"):
        ckpt.restore(str(tmp_path), 1, tree)


def test_ckpt_orphan_dirs_swept_on_next_save(tmp_path):
    """Crash leftovers — uncommitted step dirs and stale .tmp dirs — are
    swept by the NEXT save; committed steps are untouched."""
    tree = {"a": jnp.ones(3)}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000007")          # crashed before marker
    os.makedirs(tmp_path / "step_00000003.tmp")      # crashed mid-write
    ckpt.save(str(tmp_path), 2, tree)
    assert not (tmp_path / "step_00000007").exists()
    assert not (tmp_path / "step_00000003.tmp").exists()
    assert ckpt.committed_steps(str(tmp_path)) == [1, 2]


# -------------------------------------------------------------------- fault

def test_supervisor_retries_then_restart():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        raise RuntimeError("transient")

    sup = StepSupervisor(max_retries=2)
    with pytest.raises(RestartRequired):
        sup.run(flaky, step=3)
    assert calls["n"] == 3


def test_supervisor_straggler_flag():
    import time
    sup = StepSupervisor(straggler_factor=5.0)
    for _ in range(5):
        sup.run(lambda: time.sleep(0.01))
    sup.run(lambda: time.sleep(0.2))
    assert len(sup.stats.stragglers) == 1


# ------------------------------------------------------------------ elastic

@pytest.mark.parametrize("n,expect", [
    (512, ((2, 16, 16), ("pod", "data", "model"))),
    (256, ((16, 16), ("data", "model"))),
    (96, ((6, 16), ("data", "model"))),
    (24, ((3, 8), ("data", "model"))),
    (7, ((7, 1), ("data", "model"))),
])
def test_remesh_plan(n, expect):
    assert remesh_plan(n) == expect
    shape, _ = remesh_plan(n)
    assert int(np.prod(shape)) == n
