"""Paged-attention kernel parity lane: the Pallas block-table kernel
(kernels/paged_attn.py) against the dense-gather paged path and the dense
pool, for decode AND chunked prefill.

Layers pinned here:
  kernel vs gather   bounded-ulp (online vs one-shot softmax; same masks,
                     same GQA broadcast, same softcap);
  gather vs dense    BIT equality (the gathered pages reproduce the dense
                     layout exactly — masked stale/null positions contribute
                     exactly 0);
  poisoned vs clean  BIT equality per mode (null pages, fresh admissions,
                     freed-then-reused pages: stale KV must never reach a
                     live softmax);
  engine streams     kernel-mode == gather-mode == dense-pool greedy token
                     streams, unsharded and under 2x2 / 1x4 meshes.

The cases sweep ragged per-slot positions crossing page boundaries
(t % page_size in {0, 1, ps-1}), GQA head ratios, sliding windows and logit
softcap. Mesh cases run in-process on >= 4 devices (the CI mesh job);
single-device hosts re-run them in a forced-4-device subprocess. The
companion CI lane REPRO_FORCE_PAGED_KERNEL=1 runs tests/test_serving.py
through the kernel end to end."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import paged_attn as PA
from repro.models import attention as ATT

MULTI = jax.device_count() >= 4

needs_mesh = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 host devices (mesh CI job / subprocess)")

MESHES = [(2, 2), (1, 4)]

PS = 8          # page size
P = 4           # logical pages per slot -> Smax = 32


def _mesh(shape):
    return jax.make_mesh(shape, ("data", "model"))


def _cfg(nkv=2, softcap=0.0, mode="auto"):
    return ModelConfig(name="tiny", family="dense", num_layers=1, d_model=32,
                       num_heads=4, num_kv_heads=nkv, d_ff=0, vocab_size=64,
                       dtype="float32", logit_softcap=softcap,
                       paged_attn=mode)


def _pools(cfg, t, seed=0):
    """Random page pools + contiguous per-row block tables covering each
    row's positions 0..t[b] (page for the NEXT write included, like
    grow_active). Unallocated entries stay at the null page 0."""
    rng = np.random.default_rng(seed)
    hd = cfg.resolved_head_dim()
    B = len(t)
    NP = B * P + 1
    kp = jnp.asarray(rng.normal(size=(NP, PS, cfg.num_kv_heads, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NP, PS, cfg.num_kv_heads, hd)),
                     jnp.float32)
    bt = np.zeros((B, P), np.int32)
    nxt = 1
    for b in range(B):
        for j in range(int(t[b]) // PS + 1):
            bt[b, j] = nxt
            nxt += 1
    return kp, vp, jnp.asarray(bt)


# page-boundary sweep: t % ps in {0, 1, ps-1} at several page counts
RAGGED_T = np.array([0, 1, 7, 8, 9, 15, 24])


# ----------------------------------------------------------------- decode

@pytest.mark.parametrize("nkv", [1, 2, 4])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (5, 0.0), (0, 4.0)])
def test_decode_kernel_gather_dense_parity(nkv, window, softcap):
    """attn_decode through the kernel vs the gather path vs a dense cache,
    on ragged positions crossing page boundaries. Gather == dense bitwise;
    kernel == gather to fp32 accumulation tolerance; all three scatter the
    new token identically."""
    cfg = _cfg(nkv=nkv, softcap=softcap)
    params = ATT.attn_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B = len(RAGGED_T)
    x_t = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    t = jnp.asarray(RAGGED_T, jnp.int32)
    kp, vp, bt = _pools(cfg, RAGGED_T)
    hd = cfg.resolved_head_dim()

    # dense reference built from the same pre-write page contents
    dk = kp[bt].reshape(B, P * PS, nkv, hd)
    dv = vp[bt].reshape(B, P * PS, nkv, hd)

    outg, ckg, cvg = ATT.attn_decode(
        params, x_t, kp, vp, t, cfg=cfg.with_overrides(paged_attn="gather"),
        window=window, block_table=bt)
    outk, ckk, cvk = ATT.attn_decode(
        params, x_t, kp, vp, t, cfg=cfg.with_overrides(paged_attn="kernel"),
        window=window, block_table=bt)
    outd, _, _ = ATT.attn_decode(params, x_t, dk, dv, t, cfg=cfg,
                                 window=window)

    np.testing.assert_array_equal(np.asarray(ckg), np.asarray(ckk))
    np.testing.assert_array_equal(np.asarray(cvg), np.asarray(cvk))
    np.testing.assert_array_equal(np.asarray(outg), np.asarray(outd))
    np.testing.assert_allclose(np.asarray(outk), np.asarray(outg),
                               rtol=2e-5, atol=2e-5)


def test_resolve_mode():
    assert PA.resolve_mode(_cfg(mode="kernel")) == "kernel"
    assert PA.resolve_mode(_cfg(mode="gather")) == "gather"
    # auto resolves per lowering platform — gather on CPU hosts
    expected = "kernel" if jax.default_backend() == "tpu" else "gather"
    assert PA.resolve_mode(_cfg(mode="auto")) == expected
    with pytest.raises(ValueError, match="paged_attn"):
        PA.resolve_mode(_cfg(mode="bogus"))


# ---------------------------------------------------------- chunked prefill

def test_chunk_kernel_gather_dense_parity():
    """attn_chunk over a 27-token prompt in 8-token chunks: the paged
    scatter + gather reproduces the dense chunk path bit for bit (caches
    AND outputs, pads included), and the kernel tracks it to tolerance."""
    cfg = _cfg(nkv=2)
    params = ATT.attn_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    hd = cfg.resolved_head_dim()
    nkv = cfg.num_kv_heads
    plen, Cs = 27, 8
    x = jnp.asarray(rng.normal(size=(1, -(-plen // Cs) * Cs, cfg.d_model)),
                    jnp.float32)

    NP = P + 1
    kpg = jnp.zeros((NP, PS, nkv, hd), jnp.float32)
    vpg = jnp.zeros((NP, PS, nkv, hd), jnp.float32)
    kpk, vpk = kpg, vpg
    dk = jnp.zeros((1, P * PS, nkv, hd), jnp.float32)
    dv = jnp.zeros((1, P * PS, nkv, hd), jnp.float32)
    bt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)

    for start in range(0, x.shape[1], Cs):
        xc = x[:, start:start + Cs]
        valid = min(Cs, plen - start)
        kvl = start + valid
        outd, dk, dv = ATT.attn_chunk(params, xc, dk, dv, start, cfg=cfg,
                                      kv_len=kvl)
        outg, kpg, vpg = ATT.attn_chunk(
            params, xc, kpg, vpg, start,
            cfg=cfg.with_overrides(paged_attn="gather"), kv_len=kvl,
            block_table=bt)
        outk, kpk, vpk = ATT.attn_chunk(
            params, xc, kpk, vpk, start,
            cfg=cfg.with_overrides(paged_attn="kernel"), kv_len=kvl,
            block_table=bt)
        np.testing.assert_array_equal(np.asarray(outg), np.asarray(outd))
        np.testing.assert_allclose(np.asarray(outk), np.asarray(outg),
                                   rtol=2e-5, atol=2e-5)

    np.testing.assert_array_equal(np.asarray(kpk), np.asarray(kpg))
    np.testing.assert_array_equal(
        np.asarray(kpg[bt].reshape(1, P * PS, nkv, hd)), np.asarray(dk))
    np.testing.assert_array_equal(
        np.asarray(vpg[bt].reshape(1, P * PS, nkv, hd)), np.asarray(dv))


# ----------------------------------------------------------- quantized pages

def test_quantized_decode_kernel_gather_parity():
    """int8 pages + per-page scales through attn_decode: the kernel's
    in-kernel dequant (scales ride a scalar-prefetch BlockSpec) must track
    the gather path's dequant-at-gather to fp32 tolerance, and both modes
    must write the SAME int8 bytes and scales back (the rescale-on-write
    scatter runs outside the kernel, shared by both paths)."""
    from repro.core import quant as Q
    cfg = _cfg(nkv=2)
    params = ATT.attn_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B = len(RAGGED_T)
    x_t = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    t = jnp.asarray(RAGGED_T, jnp.int32)
    kp, vp, bt = _pools(cfg, RAGGED_T)
    qk, ks = Q.quantize_pages(kp)
    qv, vs = Q.quantize_pages(vp)

    outg, (ckg, ksg), (cvg, vsg) = ATT.attn_decode(
        params, x_t, (qk, ks), (qv, vs), t,
        cfg=cfg.with_overrides(paged_attn="gather"), block_table=bt)
    outk, (ckk, ksk), (cvk, vsk) = ATT.attn_decode(
        params, x_t, (qk, ks), (qv, vs), t,
        cfg=cfg.with_overrides(paged_attn="kernel"), block_table=bt)

    np.testing.assert_array_equal(np.asarray(ckg), np.asarray(ckk))
    np.testing.assert_array_equal(np.asarray(cvg), np.asarray(cvk))
    np.testing.assert_array_equal(np.asarray(ksg), np.asarray(ksk))
    np.testing.assert_array_equal(np.asarray(vsg), np.asarray(vsk))
    np.testing.assert_allclose(np.asarray(outk), np.asarray(outg),
                               rtol=2e-5, atol=2e-5)


def test_quantized_chunk_kernel_gather_parity():
    """Chunked prefill over int8 pages: kernel vs gather, chunk by chunk —
    identical int8 scatter results, outputs within fp32 tolerance."""
    from repro.core import quant as Q
    cfg = _cfg(nkv=2)
    params = ATT.attn_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    hd = cfg.resolved_head_dim()
    nkv = cfg.num_kv_heads
    plen, Cs = 27, 8
    x = jnp.asarray(rng.normal(size=(1, -(-plen // Cs) * Cs, cfg.d_model)),
                    jnp.float32)
    NP = P + 1
    zero_p = jnp.zeros((NP, PS, nkv, hd), jnp.int8)
    zero_s = jnp.zeros((NP, nkv), jnp.float32)
    kg, vg = (zero_p, zero_s), (zero_p, zero_s)
    kk, vk = (zero_p, zero_s), (zero_p, zero_s)
    bt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)

    for start in range(0, x.shape[1], Cs):
        xc = x[:, start:start + Cs]
        kvl = start + min(Cs, plen - start)
        outg, kg, vg = ATT.attn_chunk(
            params, xc, kg, vg, start,
            cfg=cfg.with_overrides(paged_attn="gather"), kv_len=kvl,
            block_table=bt)
        outk, kk, vk = ATT.attn_chunk(
            params, xc, kk, vk, start,
            cfg=cfg.with_overrides(paged_attn="kernel"), kv_len=kvl,
            block_table=bt)
        np.testing.assert_allclose(np.asarray(outk), np.asarray(outg),
                                   rtol=2e-5, atol=2e-5)

    np.testing.assert_array_equal(np.asarray(kk[0]), np.asarray(kg[0]))
    np.testing.assert_array_equal(np.asarray(kk[1]), np.asarray(kg[1]))
    np.testing.assert_array_equal(np.asarray(vk[0]), np.asarray(vg[0]))
    np.testing.assert_array_equal(np.asarray(vk[1]), np.asarray(vg[1]))


# --------------------------------------------- adversarial null / stale pages

@pytest.mark.parametrize("mode", ["gather", "kernel"])
def test_null_and_reused_pages_never_leak(mode):
    """Poisoning every UNREACHABLE position — the null page, unallocated
    pages, and the stale tails of freed-then-reused pages — must not change
    a single output bit. Row 0 is a fresh admission (t=0: everything past
    position 0 is null/stale), row 1 sits mid-page, row 2's second page is
    'reused' with a hot stale tail."""
    cfg = _cfg(nkv=2, mode=mode)
    params = ATT.attn_init(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(5)
    t = np.array([0, 3, 11])
    B = len(t)
    x_t = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    kp, vp, bt = _pools(cfg, t, seed=6)
    btn = np.asarray(bt)

    # poison: huge finite values everywhere a correct path must never look
    poison = np.full(np.asarray(kp).shape, 1e4, np.float32)
    kpp, vpp = np.array(poison), np.array(-poison)
    live = np.zeros((kp.shape[0], PS), bool)          # position-level liveness
    for b in range(B):
        for pos in range(int(t[b]) + 1):              # 0..t live (t rewritten)
            live[btn[b, pos // PS], pos % PS] = True
    kpc = np.where(live[:, :, None, None], np.asarray(kp), 0.0)
    vpc = np.where(live[:, :, None, None], np.asarray(vp), 0.0)
    kpp = np.where(live[:, :, None, None], np.asarray(kp), kpp)
    vpp = np.where(live[:, :, None, None], np.asarray(vp), vpp)

    out_c, _, _ = ATT.attn_decode(params, x_t, jnp.asarray(kpc),
                                  jnp.asarray(vpc), jnp.asarray(t, jnp.int32),
                                  cfg=cfg, block_table=bt)
    out_p, _, _ = ATT.attn_decode(params, x_t, jnp.asarray(kpp),
                                  jnp.asarray(vpp), jnp.asarray(t, jnp.int32),
                                  cfg=cfg, block_table=bt)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))


@pytest.mark.parametrize("mode", ["gather", "kernel"])
def test_engine_page_reuse_streams_clean(mode):
    """Engine-level freed-then-reused pages: 4 requests over 2 slots force
    retirement + page reuse mid-trace; every greedy stream must equal the
    dense pool's, through the gather path AND the kernel."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    cfg = get_config("llama_moe_4_16", smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (12, 12, 16, 12)]
    kw = dict(num_slots=2, max_tokens=32, arrival_steps=[0, 1, 3, 3])
    ref = serve_continuous(params, cfg, prompts, 6, **kw)
    got = serve_continuous(params, cfg.with_overrides(paged_attn=mode),
                           prompts, 6, paged=True, page_size=8, **kw)
    assert got["stats"]["paged"]
    for rid in ref["tokens"]:
        np.testing.assert_array_equal(ref["tokens"][rid],
                                      got["tokens"][rid])


@pytest.mark.parametrize("mode", ["gather", "kernel"])
def test_engine_chunked_prefill_paged_native(mode):
    """Chunked prefill on a paged pool prefills STRAIGHT into the pool's
    pages (no dense [1, max_tokens] copy) — streams must still equal the
    dense-pool chunked engine's, on both paged realizations."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    cfg = get_config("starcoder2-3b", smoke=True)
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (27, 9, 21)]
    kw = dict(num_slots=2, max_tokens=48, arrival_steps=[0, 0, 2],
              prefill_chunk=16)
    ref = serve_continuous(params, cfg, prompts, 5, **kw)
    got = serve_continuous(params, cfg.with_overrides(paged_attn=mode),
                           prompts, 5, paged=True, page_size=8, **kw)
    assert got["stats"]["chunk_ticks"] >= 2
    for rid in ref["tokens"]:
        np.testing.assert_array_equal(ref["tokens"][rid],
                                      got["tokens"][rid])


# ------------------------------------------------------------------- meshes

@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
def test_kernel_mesh_decode_parity(shape):
    """The kernel under a GSPMD mesh (inputs pinned replicated — pallas has
    no SPMD rule) must reproduce its unsharded output."""
    cfg = _cfg(nkv=2)
    hd = cfg.resolved_head_dim()
    rng = np.random.default_rng(7)
    kp, vp, bt = _pools(cfg, RAGGED_T, seed=8)
    B = len(RAGGED_T)
    q = jnp.asarray(rng.normal(size=(B, cfg.num_heads, hd)), jnp.float32)
    t = jnp.asarray(RAGGED_T, jnp.int32)
    ref = PA.paged_attn_decode(q, kp, vp, bt, t, window=5)
    with _mesh(shape):
        got = PA.paged_attn_decode(q, kp, vp, bt, t, window=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@needs_mesh
@pytest.mark.parametrize("shape", MESHES)
@pytest.mark.parametrize("mode", ["gather", "kernel"])
def test_sharded_engine_mesh_stream_parity(shape, mode):
    """Paged engine under the mesh (kernel mode flips the page-store layout
    to whole-page staging: heads over "model" — launch/sharding.py): every
    stream equals the unsharded paged engine's."""
    from repro.configs.registry import get_config
    from repro.launch.serve import serve_continuous
    from repro.models.model import model_init
    cfg = get_config("llama_moe_4_16", smoke=True).with_overrides(
        paged_attn=mode)
    params = model_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(3)]
    kw = dict(num_slots=2, max_tokens=32, arrival_steps=[0, 1, 3],
              paged=True, page_size=8)
    ref = serve_continuous(params, cfg, prompts, 5, **kw)
    got = serve_continuous(params, cfg, prompts, 5, mesh=_mesh(shape), **kw)
    assert got["stats"]["mesh"] == dict(zip(("data", "model"), shape))
    for rid in ref["tokens"]:
        np.testing.assert_array_equal(ref["tokens"][rid],
                                      got["tokens"][rid])


def test_mesh_cases_subprocess():
    """Tier-1 fallback: on a single-device host, re-run this file's mesh
    cases in a subprocess with 4 forced host devices."""
    if MULTI:
        pytest.skip("mesh cases already ran in-process")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "mesh and not subprocess"],
        env=env, capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
