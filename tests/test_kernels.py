"""Pallas kernels vs pure-jnp oracles (interpret=True), swept over
shapes/dtypes per the kernel contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.go_topk import go_topk_update
from repro.kernels.moe_gmm import gmm, gmm_swiglu

SWEEP = [
    # (N, K, F, E, bn, dtype)
    (128, 256, 128, 2, 64, jnp.float32),
    (256, 512, 256, 4, 128, jnp.float32),
    (256, 512, 384, 8, 64, jnp.float32),
    (512, 1024, 512, 8, 128, jnp.bfloat16),
    (128, 512, 128, 3, 32, jnp.float32),
    # non-tile-aligned K/F (registry d=48/96-style dims + K > bk non-divisible)
    (128, 48, 96, 4, 32, jnp.float32),
    (64, 688, 172, 4, 32, jnp.float32),
]


@pytest.mark.parametrize("N,K,F,E,bn,dtype", SWEEP)
def test_gmm_sweep(N, K, F, E, bn, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(N + K), 3)
    x = (jax.random.normal(k1, (N, K)) * 0.1).astype(dtype)
    w = (jax.random.normal(k2, (E, K, F)) * 0.05).astype(dtype)
    te = jax.random.randint(k3, (N // bn,), 0, E)
    y = gmm(x, w, te, bn=bn, interpret=True)
    y_ref = ref.gmm_ref(x, w, te, bn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("N,K,F,E,bn,dtype", SWEEP)
def test_gmm_swiglu_sweep(N, K, F, E, bn, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(N + F), 4)
    x = (jax.random.normal(k1, (N, K)) * 0.1).astype(dtype)
    wg = (jax.random.normal(k2, (E, K, F)) * 0.05).astype(dtype)
    wi = (jax.random.normal(k3, (E, K, F)) * 0.05).astype(dtype)
    te = jax.random.randint(k4, (N // bn,), 0, E)
    h = gmm_swiglu(x, wg, wi, te, bn=bn, interpret=True)
    h_ref = ref.gmm_swiglu_ref(x, wg, wi, te, bn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,E,k", [(1, 4, 2), (4, 16, 4), (8, 64, 6), (3, 40, 8)])
def test_go_topk_sweep(B, E, k):
    key = jax.random.PRNGKey(B * E + k)
    k1, k2, k3 = jax.random.split(key, 3)
    sp = jax.random.normal(k1, (B, E, k))
    tp = jax.random.randint(k2, (B, E, k), 0, 1000)
    sn = jax.random.normal(k3, (B, E))
    got = go_topk_update(sp, tp, sn, 1001, interpret=True)
    want = ref.go_topk_ref(sp, tp, sn, 1001)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _check_plan(ef, E, bn):
    """Row/tile invariants of plan_tile_dispatch for a given distribution."""
    from repro.kernels.ops import plan_tile_dispatch
    ef = jnp.asarray(ef, jnp.int32)
    N = ef.shape[0]
    plan = plan_tile_dispatch(ef, E, bn)
    dest = np.asarray(plan.dest)
    te = np.asarray(plan.tile_expert)
    tv = np.asarray(plan.tile_valid)
    # all rows land in bounds, no two pairs share a slot
    assert dest.max() < plan.n_pad
    assert len(np.unique(dest)) == len(dest)
    # every tile's rows belong to the tile's expert, and that tile is valid
    e_of_row = np.asarray(ef)
    for r, dst in enumerate(dest):
        assert te[dst // bn] == e_of_row[r]
        assert tv[dst // bn]
    # row_valid marks exactly the occupied slots; counts account every pair
    assert int(np.asarray(plan.row_valid).sum()) == N
    assert int(np.asarray(plan.counts).sum()) == N
    # valid tiles cover exactly the tile-padded runs (skipped tiles = padding)
    padded = (np.asarray(plan.counts) + bn - 1) // bn * bn
    assert int(tv.sum()) == int(padded.sum() // bn)
    return plan


def test_tile_plan_properties():
    key = jax.random.PRNGKey(0)
    ef = jax.random.randint(key, (200,), 0, 8)
    _check_plan(ef, 8, 32)


@pytest.mark.parametrize("case", ["all_one_expert", "empty_experts",
                                  "single_pair", "last_expert_only"])
def test_tile_plan_adversarial(case):
    """Planner invariants under adversarial expert distributions."""
    E, bn = 8, 32
    if case == "all_one_expert":
        ef = np.full(200, 3)
    elif case == "empty_experts":
        ef = np.concatenate([np.full(100, 0), np.full(100, 7)])
    elif case == "single_pair":
        ef = np.array([5])
    else:
        ef = np.full(33, E - 1)
    plan = _check_plan(ef, E, bn)
    if case == "all_one_expert":
        assert int(np.asarray(plan.tile_valid).sum()) == -(-200 // bn)


def test_gmm_scaled_matches_ref():
    """Fused-combine gmm: per-row weights applied in-kernel, fp32 out."""
    from repro.kernels.moe_gmm import gmm_scaled
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(9), 4)
    N, K, F, E, bn = 128, 96, 80, 4, 32
    x = jax.random.normal(k1, (N, K)) * 0.1
    w = jax.random.normal(k2, (E, K, F)) * 0.05
    te = jax.random.randint(k3, (N // bn,), 0, E)
    s = jax.random.normal(k4, (N, 1))
    y = gmm_scaled(x, w, te, None, s, bn=bn, interpret=True)
    assert y.dtype == jnp.float32
    y_ref = ref.gmm_scaled_ref(x, w, te, s, bn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_gmm_tile_valid_skips_compute():
    """Invalid tiles must produce zero rows (their MXU work is skipped)."""
    from repro.kernels.moe_gmm import gmm
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    N, K, F, E, bn = 64, 32, 32, 2, 16
    x = jax.random.normal(k1, (N, K)) * 0.1
    w = jax.random.normal(k2, (E, K, F)) * 0.05
    te = jnp.array([0, 1, 0, 1])
    tv = jnp.array([1, 0, 1, 0])
    y = gmm(x, w, te, tv, bn=bn, interpret=True)
    y_full = gmm(x, w, te, None, bn=bn, interpret=True)
    np.testing.assert_array_equal(np.asarray(y[bn:2 * bn]), 0.0)
    np.testing.assert_array_equal(np.asarray(y[3 * bn:]), 0.0)
    np.testing.assert_allclose(np.asarray(y[:bn]), np.asarray(y_full[:bn]))


@pytest.mark.parametrize("B,S,H,hd", [(1, 16, 2, 8), (2, 24, 4, 16),
                                      (3, 33, 4, 32)])
def test_slstm_seq_kernel(B, S, H, hd):
    """Fused sLSTM sequence kernel vs the model's per-step cell (§Perf Cell A
    consequence: state + recurrent weights VMEM-resident across the scan)."""
    import jax
    from repro.kernels.slstm_cell import slstm_seq
    from repro.models.xlstm import _slstm_cell

    key = jax.random.PRNGKey(B * S)
    u = jax.random.normal(key, (B, S, 4 * H * hd)) * 0.5
    r = jax.random.normal(jax.random.PRNGKey(1), (4, H, hd, hd)) / (hd ** 0.5)
    params = {"r": r}
    st = {k: jnp.zeros((B, H, hd)) for k in ("c", "n", "m", "h")}
    hs = []
    for t in range(S):
        st = _slstm_cell(params, u[:, t], st, H, hd)
        hs.append(st["h"].reshape(B, -1))
    ref = jnp.stack(hs, axis=1)
    got = slstm_seq(u, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
