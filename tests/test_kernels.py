"""Pallas kernels vs pure-jnp oracles (interpret=True), swept over
shapes/dtypes per the kernel contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st
from repro.kernels import ref
from repro.kernels.go_topk import go_topk_update
from repro.kernels.moe_gmm import gmm, gmm_swiglu

SWEEP = [
    # (N, K, F, E, bn, dtype)
    (128, 256, 128, 2, 64, jnp.float32),
    (256, 512, 256, 4, 128, jnp.float32),
    (256, 512, 384, 8, 64, jnp.float32),
    (512, 1024, 512, 8, 128, jnp.bfloat16),
    (128, 512, 128, 3, 32, jnp.float32),
    # non-tile-aligned K/F (registry d=48/96-style dims + K > bk non-divisible)
    (128, 48, 96, 4, 32, jnp.float32),
    (64, 688, 172, 4, 32, jnp.float32),
]


@pytest.mark.parametrize("N,K,F,E,bn,dtype", SWEEP)
def test_gmm_sweep(N, K, F, E, bn, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(N + K), 3)
    x = (jax.random.normal(k1, (N, K)) * 0.1).astype(dtype)
    w = (jax.random.normal(k2, (E, K, F)) * 0.05).astype(dtype)
    te = jax.random.randint(k3, (N // bn,), 0, E)
    y = gmm(x, w, te, bn=bn, interpret=True)
    y_ref = ref.gmm_ref(x, w, te, bn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("N,K,F,E,bn,dtype", SWEEP)
def test_gmm_swiglu_sweep(N, K, F, E, bn, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(N + F), 4)
    x = (jax.random.normal(k1, (N, K)) * 0.1).astype(dtype)
    wg = (jax.random.normal(k2, (E, K, F)) * 0.05).astype(dtype)
    wi = (jax.random.normal(k3, (E, K, F)) * 0.05).astype(dtype)
    te = jax.random.randint(k4, (N // bn,), 0, E)
    h = gmm_swiglu(x, wg, wi, te, bn=bn, interpret=True)
    h_ref = ref.gmm_swiglu_ref(x, wg, wi, te, bn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,E,k", [(1, 4, 2), (4, 16, 4), (8, 64, 6), (3, 40, 8)])
def test_go_topk_sweep(B, E, k):
    key = jax.random.PRNGKey(B * E + k)
    k1, k2, k3 = jax.random.split(key, 3)
    sp = jax.random.normal(k1, (B, E, k))
    tp = jax.random.randint(k2, (B, E, k), 0, 1000)
    sn = jax.random.normal(k3, (B, E))
    got = go_topk_update(sp, tp, sn, 1001, interpret=True)
    want = ref.go_topk_ref(sp, tp, sn, 1001)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _check_plan(ef, E, bn):
    """Row/tile invariants of plan_tile_dispatch for a given distribution."""
    from repro.kernels.ops import plan_tile_dispatch
    ef = jnp.asarray(ef, jnp.int32)
    N = ef.shape[0]
    plan = plan_tile_dispatch(ef, E, bn)
    dest = np.asarray(plan.dest)
    te = np.asarray(plan.tile_expert)
    tv = np.asarray(plan.tile_valid)
    # all rows land in bounds, no two pairs share a slot
    assert dest.max() < plan.n_pad
    assert len(np.unique(dest)) == len(dest)
    # every tile's rows belong to the tile's expert, and that tile is valid
    e_of_row = np.asarray(ef)
    for r, dst in enumerate(dest):
        assert te[dst // bn] == e_of_row[r]
        assert tv[dst // bn]
    # row_valid marks exactly the occupied slots; counts account every pair
    assert int(np.asarray(plan.row_valid).sum()) == N
    assert int(np.asarray(plan.counts).sum()) == N
    # valid tiles cover exactly the tile-padded runs (skipped tiles = padding)
    padded = (np.asarray(plan.counts) + bn - 1) // bn * bn
    assert int(tv.sum()) == int(padded.sum() // bn)
    return plan


def test_tile_plan_properties():
    key = jax.random.PRNGKey(0)
    ef = jax.random.randint(key, (200,), 0, 8)
    _check_plan(ef, 8, 32)


@pytest.mark.parametrize("case", ["all_one_expert", "empty_experts",
                                  "single_pair", "last_expert_only"])
def test_tile_plan_adversarial(case):
    """Planner invariants under adversarial expert distributions."""
    E, bn = 8, 32
    if case == "all_one_expert":
        ef = np.full(200, 3)
    elif case == "empty_experts":
        ef = np.concatenate([np.full(100, 0), np.full(100, 7)])
    elif case == "single_pair":
        ef = np.array([5])
    else:
        ef = np.full(33, E - 1)
    plan = _check_plan(ef, E, bn)
    if case == "all_one_expert":
        assert int(np.asarray(plan.tile_valid).sum()) == -(-200 // bn)


def test_gmm_scaled_matches_ref():
    """Fused-combine gmm: per-row weights applied in-kernel, fp32 out."""
    from repro.kernels.moe_gmm import gmm_scaled
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(9), 4)
    N, K, F, E, bn = 128, 96, 80, 4, 32
    x = jax.random.normal(k1, (N, K)) * 0.1
    w = jax.random.normal(k2, (E, K, F)) * 0.05
    te = jax.random.randint(k3, (N // bn,), 0, E)
    s = jax.random.normal(k4, (N, 1))
    y = gmm_scaled(x, w, te, None, s, bn=bn, interpret=True)
    assert y.dtype == jnp.float32
    y_ref = ref.gmm_scaled_ref(x, w, te, s, bn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_gmm_tile_valid_skips_compute():
    """Invalid tiles must produce zero rows (their MXU work is skipped)."""
    from repro.kernels.moe_gmm import gmm
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    N, K, F, E, bn = 64, 32, 32, 2, 16
    x = jax.random.normal(k1, (N, K)) * 0.1
    w = jax.random.normal(k2, (E, K, F)) * 0.05
    te = jnp.array([0, 1, 0, 1])
    tv = jnp.array([1, 0, 1, 0])
    y = gmm(x, w, te, tv, bn=bn, interpret=True)
    y_full = gmm(x, w, te, None, bn=bn, interpret=True)
    np.testing.assert_array_equal(np.asarray(y[bn:2 * bn]), 0.0)
    np.testing.assert_array_equal(np.asarray(y[3 * bn:]), 0.0)
    np.testing.assert_allclose(np.asarray(y[:bn]), np.asarray(y_full[:bn]))


# ------------------------------------------------- local-expert tile plans

@pytest.mark.parametrize("lo,E_loc", [(0, 4), (4, 4), (2, 2), (6, 2)])
def test_tile_plan_local_window(lo, E_loc):
    """plan_tile_dispatch with expert_offset/num_local: local pairs tile up
    against the LOCAL lane index; every non-local pair is ELIDED — it takes
    no buffer row (dest == the n_pad sentinel) and no tile, so the packed
    buffer scales with the shard's local traffic — the per-shard EP plan."""
    from repro.kernels.ops import plan_tile_dispatch
    E, bn = 8, 8
    key = jax.random.PRNGKey(lo * 10 + E_loc)
    ef = jax.random.randint(key, (100,), 0, E).astype(jnp.int32)
    plan = plan_tile_dispatch(ef, E, bn, expert_offset=lo, num_local=E_loc)
    dest = np.asarray(plan.dest)
    te = np.asarray(plan.tile_expert)
    tv = np.asarray(plan.tile_valid)
    ef_np = np.asarray(ef)
    local = (ef_np >= lo) & (ef_np < lo + E_loc)
    assert te.max() < E_loc                    # indexes the LOCAL bank only
    for r in range(100):
        if local[r]:
            tile = dest[r] // bn
            assert tv[tile] and te[tile] == ef_np[r] - lo
        else:
            assert dest[r] == plan.n_pad       # elided: no row, no tile
    # local rows are unique; elided pairs all share the sentinel
    assert len(np.unique(dest[local])) == int(local.sum())
    # counts: planned lanes = local experts, then the drop-lane tally
    cnt = np.asarray(plan.counts)
    assert cnt.shape == (E_loc + 1,)
    for j in range(E_loc):
        assert cnt[j] == int((ef_np == lo + j).sum())
    assert cnt[E_loc] == int((~local).sum())
    # row_valid marks exactly the COMPUTED occupied slots, and the occupied
    # tile count tracks the per-lane padded runs (nothing planned for drops)
    assert int(np.asarray(plan.row_valid).sum()) == int(local.sum())
    padded = (cnt[:E_loc] + bn - 1) // bn * bn
    assert int(np.asarray(plan.occupied)) == int(padded.sum() // bn)


def test_moe_ffn_fused_local_window_psums_to_global():
    """Sharded-plan equivalence without a mesh: running moe_ffn_fused once
    per local-expert window over the SAME pairs and summing the partial
    outputs equals the single global plan (what the EP shard body psums)."""
    from repro.kernels.ops import moe_ffn_fused
    E, T, d, de, k, bn = 8, 12, 16, 24, 2, 4
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    bank = {
        "wg": jax.random.normal(ks[0], (E, d, de)) * 0.1,
        "wi": jax.random.normal(ks[1], (E, d, de)) * 0.1,
        "wo": jax.random.normal(ks[2], (E, de, d)) * 0.1,
    }
    x = jax.random.normal(ks[3], (T, d)) * 0.3
    ef = jax.random.randint(ks[4], (T * k,), 0, E).astype(jnp.int32)
    wf = jnp.abs(jax.random.normal(ks[4], (T * k,)))
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    y_full, _, _ = moe_ffn_fused(x, tok, ef, wf, bank, E, T, bn=bn)
    for M in (2, 4):
        E_loc = E // M
        y_sum = 0
        for i in range(M):
            loc = jax.tree.map(lambda a: a[i * E_loc:(i + 1) * E_loc], bank)
            y_i, _, plan = moe_ffn_fused(x, tok, ef, wf, loc, E, T, bn=bn,
                                         expert_offset=i * E_loc,
                                         num_local=E_loc)
            assert int(plan.counts[:E_loc].sum()) == int(
                ((np.asarray(ef) // E_loc) == i).sum())
            y_sum = y_sum + y_i
        np.testing.assert_allclose(np.asarray(y_sum), np.asarray(y_full),
                                   rtol=1e-5, atol=1e-6)


# ------------------------------------ go_selected_ffn drop-lane masking

def _go_selected_case(selected, bn):
    """Drop-lane masking invariant: unselected pairs must come back as EXACT
    zero rows (no garbage scatter), selected pairs must match the dense
    oracle — regardless of how selection aligns with the tile boundary."""
    from repro.kernels.ops import go_selected_ffn
    B, E = selected.shape
    d, de = 16, 24
    ks = jax.random.split(jax.random.PRNGKey(int(selected.sum()) + bn), 5)
    bank = {
        "wg": jax.random.normal(ks[0], (E, d, de)) * 0.1,
        "wi": jax.random.normal(ks[1], (E, d, de)) * 0.1,
        "wo": jax.random.normal(ks[2], (E, de, d)) * 0.1,
    }
    x = jax.random.normal(ks[3], (B, d)) * 0.3
    g = jax.nn.softmax(jax.random.normal(ks[4], (B, E)), axis=-1)
    contrib, plan = go_selected_ffn(x, jnp.asarray(selected), g, bank, E,
                                    bn=bn)
    got = np.asarray(contrib)
    # dense oracle: per-pair SwiGLU FFN weighted by g
    h = jax.nn.silu(jnp.einsum("bd,edf->bef", x, bank["wg"])) * jnp.einsum(
        "bd,edf->bef", x, bank["wi"])
    eo = jnp.einsum("bef,efd->bed", h, bank["wo"])
    want = np.asarray(g[..., None] * eo)
    np.testing.assert_array_equal(got[~selected], 0.0)
    np.testing.assert_allclose(got[selected], want[selected],
                               rtol=1e-5, atol=1e-6)
    assert int(plan.counts[:E].sum()) == int(selected.sum())


@pytest.mark.parametrize("case", ["tail_tile_all_dropped", "none_selected",
                                  "one_selected", "all_selected_unaligned"])
def test_go_selected_adversarial_tail(case):
    """The all-dropped-tail-tile family: the selected-row count is NOT a
    multiple of bn and every pair of the trailing tile(s) is dropped."""
    B, E, bn = 3, 4, 8
    sel = np.zeros((B, E), bool)
    if case == "tail_tile_all_dropped":
        # 5 selected rows (5 % 8 != 0); the remaining 7 pairs fill the drop
        # lane, so its final tile holds ONLY dropped pairs
        sel[0, :2] = sel[1, :2] = sel[2, 0] = True
    elif case == "one_selected":
        sel[1, 2] = True
    elif case == "all_selected_unaligned":
        sel[:] = True                        # 12 pairs, 12 % 8 != 0
    _go_selected_case(sel, bn)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 12 - 1), st.sampled_from([4, 8]))
def test_go_selected_mask_property(bits, bn):
    """Property sweep over arbitrary selection masks (incl. the empty and
    full masks): zeros where unselected, oracle where selected."""
    sel = np.array([(bits >> i) & 1 for i in range(12)],
                   bool).reshape(3, 4)
    _go_selected_case(sel, bn)


# ------------------------------------------------- interpret-mode resolution

def test_default_interpret_resolves_from_lowering_context(monkeypatch):
    """default_interpret keys off the ACTUAL lowering target: the active
    mesh's devices when inside one, the host default backend otherwise —
    a forced CPU mesh on a (faked) TPU host must pick the interpreter."""
    from repro.kernels import moe_gmm
    assert moe_gmm.default_interpret() is (jax.default_backend() != "tpu")
    monkeypatch.setattr(moe_gmm.jax, "default_backend", lambda: "tpu")
    assert moe_gmm.default_interpret() is False      # no mesh: host decides
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # CPU devices
    with mesh:
        assert moe_gmm.default_interpret() is True   # mesh devices decide
    assert moe_gmm.default_interpret() is False      # context popped


def test_default_block_rows_follows_lowering_context(monkeypatch):
    from repro.kernels import moe_gmm, ops
    monkeypatch.setattr(moe_gmm.jax, "default_backend", lambda: "tpu")
    assert ops.default_block_rows() == 128
    with jax.make_mesh((1, 1), ("data", "model")):
        assert ops.default_block_rows() == 8         # CPU mesh: small tiles


@pytest.mark.parametrize("B,S,H,hd", [(1, 16, 2, 8), (2, 24, 4, 16),
                                      (3, 33, 4, 32)])
def test_slstm_seq_kernel(B, S, H, hd):
    """Fused sLSTM sequence kernel vs the model's per-step cell (§Perf Cell A
    consequence: state + recurrent weights VMEM-resident across the scan)."""
    import jax
    from repro.kernels.slstm_cell import slstm_seq
    from repro.models.xlstm import _slstm_cell

    key = jax.random.PRNGKey(B * S)
    u = jax.random.normal(key, (B, S, 4 * H * hd)) * 0.5
    r = jax.random.normal(jax.random.PRNGKey(1), (4, H, hd, hd)) / (hd ** 0.5)
    params = {"r": r}
    st = {k: jnp.zeros((B, H, hd)) for k in ("c", "n", "m", "h")}
    hs = []
    for t in range(S):
        st = _slstm_cell(params, u[:, t], st, H, hd)
        hs.append(st["h"].reshape(B, -1))
    ref = jnp.stack(hs, axis=1)
    got = slstm_seq(u, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
