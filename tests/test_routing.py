"""Routing invariants + the paper's TopKUpdate (eq. 4-5) exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st   # hypothesis, or skip shim

from repro.core import routing as R


def test_token_choice_shapes_and_weights():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (10, 16))
    w = jax.random.normal(key, (16, 8))
    r = R.token_choice(x, w, 3)
    assert r.expert_idx.shape == (10, 3)
    np.testing.assert_allclose(np.asarray(r.weights.sum(-1)), 1.0, rtol=1e-5)
    # chosen experts are the k largest scores
    s = np.asarray(r.scores)
    for t in range(10):
        top = set(np.argsort(-s[t])[:3])
        assert set(np.asarray(r.expert_idx[t]).tolist()) == top


def test_expert_choice_balanced_by_construction():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (32, 16))
    w = jax.random.normal(key, (16, 8))
    r = R.expert_choice(x, w, capacity=4)
    assert r.token_idx.shape == (8, 4)
    # every expert selects exactly `capacity` tokens: loads are equal
    counts = np.bincount(np.asarray(r.token_idx).reshape(-1), minlength=32)
    assert counts.sum() == 8 * 4


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(1, 4),
       st.integers(5, 40))
def test_topk_update_matches_full_recompute(seed, E, k, steps):
    """The paper's incremental TopKUpdate == exact top-k over the full score
    history (the selection invariant that makes the GO cache lossless w.r.t.
    fixed-capacity expert choice)."""
    rng = np.random.default_rng(seed)
    history = rng.normal(size=(k, E)).astype(np.float32)  # warm cache
    s_prev = jnp.asarray(np.sort(history, axis=0)[::-1].T.copy())  # [E, k]
    tok_prev = jnp.asarray(np.argsort(-history, axis=0).T.copy().astype(np.int32))
    all_scores = [history]
    for t in range(steps):
        s_new = rng.normal(size=E).astype(np.float32)
        upd = R.topk_update(s_prev, tok_prev, jnp.asarray(s_new), k + t)
        all_scores.append(s_new[None])
        full = np.concatenate(all_scores, axis=0)        # [k+t+1, E]
        for e in range(E):
            expect_topk = np.sort(full[:, e])[::-1][:k]
            got = np.sort(np.asarray(upd.new_scores[e]))[::-1]
            np.testing.assert_allclose(got, expect_topk, rtol=1e-6)
            # selection flag: new score is in the exact top-k
            kth = expect_topk[-1]
            assert bool(upd.selected[e]) == bool(s_new[e] >= kth) or \
                np.isclose(s_new[e], kth)
        s_prev, tok_prev = upd.new_scores, upd.new_token_ids


def test_load_balance_loss_prefers_uniform():
    key = jax.random.PRNGKey(2)
    T, E, k = 64, 8, 2
    uniform_scores = jax.random.normal(key, (T, E)) * 0.01
    skew_scores = uniform_scores.at[:, 0].add(10.0)
    u_idx = jax.lax.top_k(uniform_scores, k)[1]
    s_idx = jax.lax.top_k(skew_scores, k)[1]
    lu = R.load_balance_loss(uniform_scores, u_idx, E)
    ls = R.load_balance_loss(skew_scores, s_idx, E)
    assert float(ls) > float(lu)
