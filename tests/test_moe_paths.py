"""MoE execution-path equivalence: dense oracle == dispatch == grouped ==
Pallas grouped GEMM == expert-parallel shard_map."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import moe as MOE
from repro.core.grouping import default_groups, group_of_expert_from_groups


@pytest.fixture(scope="module")
def setup():
    e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0,
                  group_size=2)
    key = jax.random.PRNGKey(0)
    p = MOE.moe_init(key, 64, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 0.3
    return e, p, x


def test_dispatch_matches_dense(setup):
    e, p, x = setup
    y_ref = MOE.dense_forward(p, x, e)
    y, aux = MOE.dispatch_forward(p, x, e)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(aux["dropped"]) == 0
    assert int(aux["counts"].sum()) == 24 * e.top_k


def test_group_forward_matches_dense(setup):
    e, p, x = setup
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    y_ref = MOE.dense_forward(p, x, e)
    y, aux = MOE.group_forward(p, x, e, goe, pool_factor=8.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(aux["dropped"]) == 0


def test_group_pooling_reduces_slots(setup):
    """C1: pooled group capacity < sum of per-expert capacities (the padding
    economy that multiplexing buys)."""
    e, p, x = setup
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    e_tight = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                        capacity_factor=1.25, group_size=2)
    _, aux = MOE.group_forward(p, x, e_tight, goe, pool_factor=0.7)
    import math
    C_exp = max(1, math.ceil(24 * 2 / 8 * 1.25))
    assert int(aux["slots"]) < 8 * C_exp


def test_expert_choice_capacity_and_combine(setup):
    e, p, x = setup
    ec = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                   routing="expert_choice")
    y, aux = MOE.expert_choice_forward(p, x, ec)
    C = MOE.ec_capacity(24, ec)
    assert aux["chosen_tokens"].shape == (8, C)
    y_ref = MOE.dense_forward(p, x, ec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_pallas_moe_matches_dispatch(setup):
    e, p, x = setup
    from repro.core.routing import token_choice
    from repro.kernels.ops import moe_ffn_pallas
    r = token_choice(x, p["gate"], e.top_k)
    y_pallas = moe_ffn_pallas(x, r.expert_idx, r.weights, p["experts"],
                              e.num_experts, bn=8)
    y_ref, _ = MOE.dispatch_forward(p, x, e)
    y_ref = y_ref - MOE._shared_out(p, x)       # pallas path: routed part only
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoEConfig
from repro.core import moe as MOE
e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = MOE.moe_init(key, 64, e, jnp.float32)
h = jax.random.normal(key, (4, 16, 64)) * 0.3
y_ref = jnp.stack([MOE.dispatch_forward(p, h[b], e)[0] for b in range(4)])
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    y, aux = jax.jit(lambda p, h: MOE.moe_forward_ep(p, h, e))(p, h)
assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
assert int(aux["counts"].sum()) == 4 * 16 * 2
print("EP-OK")
"""


def test_ep_matches_dispatch_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "EP-OK" in out.stdout, out.stderr[-2000:]
