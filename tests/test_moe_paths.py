"""MoE execution-path equivalence: dense oracle == dispatch == grouped ==
Pallas grouped GEMM == expert-parallel shard_map — on BOTH backends
(`MoEConfig.backend`): the xla masked/capacity realization and the pallas
tile-dispatch grouped GEMM engine."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import moe as MOE
from repro.core.grouping import default_groups, group_of_expert_from_groups


def _pallas(e: MoEConfig, **kw) -> MoEConfig:
    return dataclasses.replace(e, backend="pallas", gmm_block_rows=8, **kw)


@pytest.fixture(scope="module")
def setup():
    e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0,
                  group_size=2)
    key = jax.random.PRNGKey(0)
    p = MOE.moe_init(key, 64, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 0.3
    return e, p, x


def test_dispatch_matches_dense(setup):
    e, p, x = setup
    y_ref = MOE.dense_forward(p, x, e)
    y, aux = MOE.dispatch_forward(p, x, e)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(aux["dropped"]) == 0
    assert int(aux["counts"].sum()) == 24 * e.top_k


def test_group_forward_matches_dense(setup):
    e, p, x = setup
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    y_ref = MOE.dense_forward(p, x, e)
    y, aux = MOE.group_forward(p, x, e, goe, pool_factor=8.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(aux["dropped"]) == 0


def test_group_pooling_reduces_slots(setup):
    """C1: pooled group capacity < sum of per-expert capacities (the padding
    economy that multiplexing buys)."""
    e, p, x = setup
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    e_tight = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                        capacity_factor=1.25, group_size=2)
    _, aux = MOE.group_forward(p, x, e_tight, goe, pool_factor=0.7)
    import math
    C_exp = max(1, math.ceil(24 * 2 / 8 * 1.25))
    assert int(aux["slots"]) < 8 * C_exp


def test_expert_choice_capacity_and_combine(setup):
    e, p, x = setup
    ec = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                   routing="expert_choice")
    y, aux = MOE.expert_choice_forward(p, x, ec)
    C = MOE.ec_capacity(24, ec)
    assert aux["chosen_tokens"].shape == (8, C)
    y_ref = MOE.dense_forward(p, x, ec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_pallas_moe_matches_dispatch(setup):
    e, p, x = setup
    from repro.core.routing import token_choice
    from repro.kernels.ops import moe_ffn_pallas
    r = token_choice(x, p["gate"], e.top_k)
    y_pallas = moe_ffn_pallas(x, r.expert_idx, r.weights, p["experts"],
                              e.num_experts, bn=8)
    y_ref, _ = MOE.dispatch_forward(p, x, e)
    y_ref = y_ref - MOE._shared_out(p, x)       # pallas path: routed part only
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------- backend="pallas" engine

def test_backend_pallas_token_choice_matches_dense(setup):
    e, p, x = setup
    y_ref = MOE.dense_forward(p, x, e)
    y, aux = MOE.dispatch_forward(p, x, _pallas(e))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(aux["dropped"]) == 0
    assert int(aux["counts"].sum()) == 24 * e.top_k


def test_backend_pallas_group_matches_dense(setup):
    e, p, x = setup
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    y_ref = MOE.dense_forward(p, x, e)
    y, aux = MOE.group_forward(p, x, _pallas(e), goe, pool_factor=8.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    assert int(aux["dropped"]) == 0


def test_backend_pallas_group_drop_parity_with_xla(setup):
    """Pooled-capacity overflow must drop the SAME pairs on both backends:
    the pallas path realizes a drop as a zero combine weight, bit-equal to
    the xla path's buffer eviction."""
    e, p, x = setup
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e)))
    e_tight = dataclasses.replace(e, capacity_factor=1.25)
    y_x, a_x = MOE.group_forward(p, x, e_tight, goe, pool_factor=0.7)
    y_p, a_p = MOE.group_forward(p, x, _pallas(e_tight), goe, pool_factor=0.7)
    assert int(a_x["dropped"]) == int(a_p["dropped"]) > 0
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                               rtol=1e-5, atol=1e-6)


def test_backend_pallas_expert_choice_matches_dense(setup):
    e, p, x = setup
    ec = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                   routing="expert_choice")
    y_ref = MOE.dense_forward(p, x, ec)
    y, aux = MOE.expert_choice_forward(p, x, _pallas(ec))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    # GO-prefill aux parity with the xla realization
    _, aux_x = MOE.expert_choice_forward(p, x, ec)
    np.testing.assert_array_equal(np.asarray(aux["chosen_tokens"]),
                                  np.asarray(aux_x["chosen_tokens"]))
    np.testing.assert_allclose(np.asarray(aux["weighted_outputs"]),
                               np.asarray(aux_x["weighted_outputs"]),
                               rtol=1e-4, atol=1e-5)


def test_backend_pallas_non_aligned_dims():
    """Registry-style non-tile-aligned widths (d=48, d_expert=96 vs bn=8,
    bk/bf defaults) must lower cleanly through the padding path."""
    e = MoEConfig(num_experts=6, top_k=2, d_expert=96, capacity_factor=8.0)
    p = MOE.moe_init(jax.random.PRNGKey(3), 48, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (17, 48)) * 0.3
    y_ref = MOE.dense_forward(p, x, e)
    y, _ = MOE.dispatch_forward(p, x, _pallas(e))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_go_decode_selected_matches_dense_and_counts(setup):
    """C4 decode: the selected-experts grouped GEMM equals the dense
    fallback, and the planner's row counts prove only selected pairs were
    computed (vs B*E on the dense path)."""
    from repro.core.go_cache import go_cache_init, go_cache_step
    from repro.kernels.ops import go_selected_ffn
    e, p, x = setup
    B, E, k, d = 5, e.num_experts, e.top_k, 64
    gate = p["gate"]
    dense_fn = lambda xt: MOE.expert_ffn_all(p, xt)
    sel_fn = lambda xt, sel, g: go_selected_ffn(
        xt, sel, g, p["experts"], E, bn=8)[0]

    cache_d = cache_s = go_cache_init(B, E, k, d, jnp.float32)
    key = jax.random.PRNGKey(7)
    for t in range(k + 6):
        key, sub = jax.random.split(key)
        xt = jax.random.normal(sub, (B, d)) * 0.3
        r_d = go_cache_step(cache_d, xt, t, gate, dense_fn)
        r_s = go_cache_step(cache_s, xt, t, gate, contrib_fn=sel_fn)
        np.testing.assert_array_equal(np.asarray(r_d.selected),
                                      np.asarray(r_s.selected))
        np.testing.assert_allclose(np.asarray(r_d.y), np.asarray(r_s.y),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(r_d.cache.outputs),
                                   np.asarray(r_s.cache.outputs),
                                   rtol=1e-5, atol=1e-6)
        # planner computes exactly the selected rows (cache warm => sparse)
        g = jax.nn.softmax(xt.astype(jnp.float32) @ gate, axis=-1)
        _, plan = go_selected_ffn(xt, r_d.selected, g, p["experts"], E, bn=8)
        assert int(plan.counts[:E].sum()) == int(r_d.selected.sum())
        if t >= k:
            assert int(r_d.selected.sum()) < B * E
        cache_d, cache_s = r_d.cache, r_s.cache


@pytest.mark.parametrize("arch", ["llama_moe_4_16", "deepseek-moe-16b"])
def test_backend_pallas_model_forward_matches_xla(arch):
    """Whole-model parity on dropless MoE configs, B>1: covers the batched
    expert-choice flatten (llama_moe) AND the token-choice/grouped
    batch-flatten branch in blocks._ffn_apply (deepseek: shared experts +
    group_size=2). Dropless so per-sequence (xla) and batch-pooled (pallas)
    capacity semantics coincide."""
    from repro.configs.registry import get_config
    from repro.models.model import model_forward, model_init
    cfg = get_config(arch, smoke=True)
    if cfg.moe.routing == "token_choice":
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    cfgp = cfg.with_overrides(
        moe=dataclasses.replace(cfg.moe, backend="pallas", gmm_block_rows=8))
    params = model_init(jax.random.PRNGKey(5), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    x_xla, _ = model_forward(params, tokens, cfg, {})
    x_pal, _ = model_forward(params, tokens, cfgp, {})
    np.testing.assert_allclose(np.asarray(x_xla, np.float32),
                               np.asarray(x_pal, np.float32),
                               rtol=1e-4, atol=1e-5)


def test_backend_pallas_fails_fast_under_grad(setup):
    """Explicit backend="pallas" in a grad trace must die with the clear
    no-backward-pass message, not a missing-VJP error deep inside jax."""
    e, p, x = setup
    for fwd in (
        lambda pp: MOE.dispatch_forward(pp, x, _pallas(e))[0],
        lambda pp: MOE.expert_choice_forward(
            pp, x, _pallas(MoEConfig(num_experts=8, top_k=2, d_expert=32,
                                     routing="expert_choice")))[0],
    ):
        with pytest.raises(NotImplementedError, match="no backward pass"):
            jax.grad(lambda pp: fwd(pp).sum())(p)


def test_backend_pallas_grad_guard_via_loss_fn():
    """Whole-model: loss_fn with an explicit pallas backend fails fast under
    value_and_grad; backend="auto" still trains (pinned to xla)."""
    import dataclasses
    from repro.configs.registry import get_config
    from repro.models.model import loss_fn, model_init
    cfg = get_config("llama_moe_4_16", smoke=True)
    params = model_init(jax.random.PRNGKey(2), cfg)
    batch = {
        "tokens": jnp.zeros((1, 8), jnp.int32),
        "labels": jnp.zeros((1, 8), jnp.int32),
    }
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)                       # auto -> xla: fine
    assert np.isfinite(float(loss))
    cfg_p = cfg.with_overrides(
        moe=dataclasses.replace(cfg.moe, backend="pallas", gmm_block_rows=8))
    with pytest.raises(NotImplementedError, match="no backward pass"):
        jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg_p)


def test_backend_pallas_forward_not_blocked_by_guard(setup):
    """The guard must NOT trip on inference traces (plain jit)."""
    e, p, x = setup
    y, _ = jax.jit(lambda pp, xx: MOE.dispatch_forward(pp, xx, _pallas(e)))(
        p, x)
    assert np.all(np.isfinite(np.asarray(y)))


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoEConfig
from repro.core import moe as MOE
e = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = MOE.moe_init(key, 64, e, jnp.float32)
h = jax.random.normal(key, (4, 16, 64)) * 0.3
y_ref = jnp.stack([MOE.dispatch_forward(p, h[b], e)[0] for b in range(4)])
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    y, aux = jax.jit(lambda p, h: MOE.moe_forward_ep(p, h, e))(p, h)
assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
assert int(aux["counts"].sum()) == 4 * 16 * 2
print("EP-OK")
"""


def test_ep_matches_dispatch_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "EP-OK" in out.stdout, out.stderr[-2000:]
