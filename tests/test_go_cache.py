"""GO cache (C4): decode step vs naive full-recompute oracle, plus the
chunked-prefill merge property — any chunk split reproduces the one-shot
expert-choice cache exactly, ties included."""
import jax
import jax.numpy as jnp
import numpy as np
from conftest import given, settings, st

from repro.core import moe as MOE
from repro.core.go_cache import (GOCache, go_cache_bytes, go_cache_init,
                                 go_cache_merge, go_cache_prefill,
                                 go_cache_step)


def _naive_expert_choice_decode(hiddens, gate_w, expert_fn, k):
    """The inefficiency the paper removes: at step t, re-run the gate over ALL
    retained hidden states; expert selects its top-k; the new token's output
    is the sum of contributions from experts whose top-k contains it."""
    g = jax.nn.softmax(hiddens.astype(jnp.float32) @ gate_w, axis=-1)  # [T, E]
    T, E = g.shape
    sel = jnp.zeros((E,), bool)
    for e in range(E):
        topk = jnp.argsort(-g[:, e])[:k]
        sel = sel.at[e].set(jnp.any(topk == T - 1))
    eo = expert_fn(hiddens[-1:])[0]                       # [E, d]
    contrib = g[-1][:, None] * eo.astype(jnp.float32)
    return jnp.where(sel[:, None], contrib, 0.0).sum(0), sel


def test_go_step_matches_naive_recompute():
    key = jax.random.PRNGKey(0)
    d, E, k, steps = 16, 4, 2, 12
    gate_w = jax.random.normal(key, (d, E))
    wkeys = jax.random.split(key, 3)
    bank = {"wg": jax.random.normal(wkeys[0], (E, d, 8)) * 0.3,
            "wi": jax.random.normal(wkeys[1], (E, d, 8)) * 0.3,
            "wo": jax.random.normal(wkeys[2], (E, 8, d)) * 0.3}
    expert_fn = lambda x: MOE.expert_ffn_all({"experts": bank}, x)

    # warm start: k tokens so the cache is full (no -inf placeholders)
    hiddens = jax.random.normal(key, (k, d))
    g0 = jax.nn.softmax(hiddens.astype(jnp.float32) @ gate_w, axis=-1)
    cache = GOCache(
        scores=g0.T[None].copy(),                        # [1, E, k]
        token_ids=jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (1, E, k)).copy(),
        outputs=jnp.zeros((1, E, k, d)),
    )
    for t in range(k, k + steps):
        key, sub = jax.random.split(key)
        x_t = jax.random.normal(sub, (1, d))
        hiddens = jnp.concatenate([hiddens, x_t], axis=0)
        res = go_cache_step(cache, x_t, t, gate_w, expert_fn)
        y_naive, sel_naive = _naive_expert_choice_decode(
            hiddens, gate_w, expert_fn, k)
        np.testing.assert_array_equal(np.asarray(res.selected[0]),
                                      np.asarray(sel_naive))
        np.testing.assert_allclose(np.asarray(res.y[0]), np.asarray(y_naive),
                                   rtol=2e-4, atol=2e-5)
        cache = res.cache


def test_at_most_one_slot_changes_per_expert_per_step():
    """Paper: 'each generation step will result in at most one change per
    expert' — the output cache is O(1) per step."""
    key = jax.random.PRNGKey(1)
    d, E, k = 8, 6, 3
    gate_w = jax.random.normal(key, (d, E))
    expert_fn = lambda x: jnp.zeros((x.shape[0], E, d))
    cache = go_cache_init(1, E, k, d, jnp.float32)
    for t in range(10):
        key, sub = jax.random.split(key)
        res = go_cache_step(cache, jax.random.normal(sub, (1, d)), t,
                            gate_w, expert_fn)
        changed = (res.cache.scores != cache.scores).sum(axis=-1)  # [1, E]
        assert int(changed.max()) <= 1
        cache = res.cache


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_go_cache_merge_reproduces_one_shot(data):
    """Property: splitting a prompt into ARBITRARY chunks, building each
    chunk's cache (per-chunk expert-choice top-min(len, k)) and folding
    old-first through go_cache_merge reproduces the one-shot prefill cache
    EXACTLY — scores, token ids AND stored order. Scores draw from a
    4-value set so capacity ties are common: the stable-top_k tie-break
    (earlier operand wins on merge, lower index wins in-chunk) must agree
    with the one-shot lower-global-index order, or chunked streams would
    depend on the chunking."""
    E, d = 3, 4
    T = data.draw(st.integers(1, 20), label="T")
    k = data.draw(st.integers(1, 4), label="k")
    flat = data.draw(st.lists(st.integers(0, 3), min_size=T * E,
                              max_size=T * E), label="scores")
    scores = np.asarray(flat, np.float32).reshape(T, E) / 3.0
    ncuts = data.draw(st.integers(0, min(4, T - 1)), label="ncuts")
    cuts = sorted(data.draw(
        st.lists(st.integers(1, T - 1), min_size=ncuts, max_size=ncuts,
                 unique=True), label="cuts")) if ncuts else []
    bounds = [0] + cuts + [T]
    # deterministic per-(token, expert) outputs, like the weighted expert
    # outputs the real prefill feeds in
    outs = jnp.asarray(
        np.random.default_rng(0).normal(size=(T, E, d)), jnp.float32)

    def chunk_cache(lo, hi, cap):
        cap = min(cap, hi - lo)
        s = jnp.asarray(scores[lo:hi].T)[None]                # [1, E, n]
        cs, ci = jax.lax.top_k(s, cap)                        # [1, E, cap]
        ct = ci[0] + lo                                       # global ids
        eo = outs[ct, jnp.arange(E)[:, None]][None]           # [1, E, cap, d]
        return go_cache_prefill(None, None, eo, ct[None], cs, k)

    one = chunk_cache(0, T, T)
    acc = go_cache_init(1, E, k, d, jnp.float32)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        acc = go_cache_merge(acc, chunk_cache(lo, hi, k))
    np.testing.assert_array_equal(np.asarray(one.scores),
                                  np.asarray(acc.scores))
    np.testing.assert_array_equal(np.asarray(one.token_ids),
                                  np.asarray(acc.token_ids))
    np.testing.assert_array_equal(np.asarray(one.outputs),
                                  np.asarray(acc.outputs))


def test_cache_size_static():
    """Paper: storage is k x E x d — independent of sequence length."""
    b1 = go_cache_bytes(1, 16, 4, 4096)
    assert b1 == go_cache_bytes(1, 16, 4, 4096)  # trivially static
    # paper's own number: 512 KB output cache for Llama-MoE-4/16
    out_bytes = 4 * 16 * 4096 * 2
    assert out_bytes == 512 * 1024
