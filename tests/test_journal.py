"""The durability layer in isolation: record framing, torn-tail tolerance,
snapshot commit atomicity, and request (de)serialization.

The load-bearing property is byte-level: `read_records` must return a valid
PREFIX of the written events for a journal truncated at ANY byte offset —
that is exactly the file a SIGKILL mid-append leaves behind. The exhaustive
loop pins it for a fixed small journal; the hypothesis test generalizes it
over random event shapes and cut points. Engine-level recovery semantics
(bit-identical resume) live in tests/test_crash_recovery.py."""
import os
import pickle

import numpy as np
import pytest

from conftest import given, settings, st
from repro.serving import EngineJournal
from repro.serving.journal import (_HEADER, _SEGMENT_MAGIC, append_record,
                                   read_records, request_from_record,
                                   request_record)
from repro.serving.scheduler import Request, RequestStatus


def _write_segment(path, events):
    sizes = []
    with open(path, "wb") as f:
        f.write(_SEGMENT_MAGIC)
        for ev in events:
            sizes.append(append_record(f, ev))
    return sizes


_EVENTS = [("submit", {"rid": 0, "prompt": np.arange(7, dtype=np.int32)}),
           ("install", {"rid": 0, "step": 1, "token": 42}),
           ("tick", {"toks": {0: 5, 1: 7}}),
           ("terminal", {"rid": 1, "status": "DONE"}),
           ("tick", {"toks": {0: 9}})]


def _assert_prefix(got, want):
    assert len(got) <= len(want)
    for g, w in zip(got, want):
        assert g[0] == w[0]
        assert set(g[1]) == set(w[1])


def test_truncation_at_every_byte_offset(tmp_path):
    """Exhaustive: cut the segment at EVERY byte from empty to full. Replay
    never raises, and returns exactly the records that fit whole below the
    cut — the valid prefix, never garbage, never one record too many."""
    seg = str(tmp_path / "journal_00000000.log")
    sizes = _write_segment(seg, _EVENTS)
    blob = open(seg, "rb").read()
    bounds = [len(_SEGMENT_MAGIC)]
    for s in sizes:
        bounds.append(bounds[-1] + s)
    cut_path = str(tmp_path / "cut.log")
    for cut in range(len(blob) + 1):
        with open(cut_path, "wb") as f:
            f.write(blob[:cut])
        got = read_records(cut_path)
        want_n = sum(1 for b in bounds[1:] if b <= cut)
        assert len(got) == want_n, f"cut at byte {cut}"
        _assert_prefix(got, _EVENTS)


def test_corrupt_byte_yields_valid_prefix(tmp_path):
    """A flipped byte (disk corruption, not truncation) fails the CRC and
    stops replay at the record it lands in — everything before it is
    returned intact."""
    seg = str(tmp_path / "journal_00000000.log")
    sizes = _write_segment(seg, _EVENTS)
    blob = bytearray(open(seg, "rb").read())
    # flip a byte inside the THIRD record's payload
    off = len(_SEGMENT_MAGIC) + sizes[0] + sizes[1] + _HEADER.size + 2
    blob[off] ^= 0xFF
    with open(seg, "wb") as f:
        f.write(bytes(blob))
    got = read_records(seg)
    assert len(got) == 2
    _assert_prefix(got, _EVENTS)


def test_foreign_file_is_empty_tail(tmp_path):
    missing = str(tmp_path / "nope.log")
    assert read_records(missing) == []
    foreign = str(tmp_path / "foreign.log")
    with open(foreign, "wb") as f:
        f.write(b"NOTAJRNL" + b"\x00" * 64)
    assert read_records(foreign) == []


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(
           st.sampled_from(["submit", "install", "tick", "terminal"]),
           st.dictionaries(st.sampled_from(["rid", "step", "token", "n"]),
                           st.integers(0, 2 ** 30), max_size=4)),
       min_size=1, max_size=8),
       st.integers(0, 10 ** 9))
def test_truncation_property(events, cut_seed):
    """Property form: random event shapes, random cut point — replay is
    total (never raises) and returns a strict prefix of what was written."""
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        seg = os.path.join(d, "journal_00000000.log")
        _write_segment(seg, events)
        blob = open(seg, "rb").read()
        cut = cut_seed % (len(blob) + 1)
        with open(seg, "wb") as f:
            f.write(blob[:cut])
        got = read_records(seg)
        assert got == events[:len(got)]


# ----------------------------------------------------------- EngineJournal


def test_commit_and_latest_committed(tmp_path):
    j = EngineJournal(str(tmp_path), snapshot_every=4)
    seq = j.commit_snapshot({"meta": {"step": 0}}, 0)
    j.append("tick", toks={0: 1})
    j.append("tick", toks={0: 2})
    assert j.events_written == 2 and j.bytes_written > 0
    got_seq, payload = EngineJournal.latest_committed(str(tmp_path))
    assert got_seq == seq and payload["meta"]["step"] == 0
    tail = EngineJournal.read_tail(str(tmp_path), seq)
    assert [k for k, _ in tail] == ["tick", "tick"]
    assert EngineJournal.recoverable(str(tmp_path))
    j.close()


def test_uncommitted_snapshot_skipped_for_previous(tmp_path):
    """The adversarial commit-ordering case: a snapshot that crashed before
    its COMMITTED marker must lose to the OLDER committed one, and the
    older generation's journal tail must still replay."""
    j = EngineJournal(str(tmp_path), snapshot_every=4)
    j.commit_snapshot({"meta": {"step": 0}, "gen": "old"}, 0)
    j.append("tick", toks={0: 1})
    j.write_uncommitted_snapshot({"meta": {"step": 5}, "gen": "torn"})
    assert os.path.isdir(tmp_path / "snap_00000001")
    assert not os.path.exists(tmp_path / "snap_00000001" / "COMMITTED")
    seq, payload = EngineJournal.latest_committed(str(tmp_path))
    assert seq == 0 and payload["gen"] == "old"
    assert len(EngineJournal.read_tail(str(tmp_path), seq)) == 1
    j.close()


def test_committed_but_unloadable_snapshot_falls_back(tmp_path):
    """Disk corruption inside a committed snapshot: recovery prefers the
    older-but-consistent generation over the newer-but-broken one."""
    j = EngineJournal(str(tmp_path), snapshot_every=4)
    j.commit_snapshot({"gen": "old"}, 0)
    j.commit_snapshot({"gen": "new"}, 8)
    with open(tmp_path / "snap_00000001" / "state.pkl", "wb") as f:
        f.write(b"\x00garbage")
    seq, payload = EngineJournal.latest_committed(str(tmp_path))
    assert seq == 0 and payload["gen"] == "old"
    j.close()


def test_tear_tail_drops_only_last_record(tmp_path):
    j = EngineJournal(str(tmp_path), snapshot_every=4)
    seq = j.commit_snapshot({}, 0)
    j.append("tick", toks={0: 1})
    j.append("tick", toks={0: 2})
    j.tear_tail(3)
    tail = EngineJournal.read_tail(str(tmp_path), seq)
    assert [p["toks"] for _, p in tail] == [{0: 1}]
    j.close()


def test_prune_keeps_last_committed_and_sweeps_orphans(tmp_path):
    j = EngineJournal(str(tmp_path), snapshot_every=4, keep=2)
    for step in range(4):
        j.commit_snapshot({"step": step}, step)
        j.append("tick", toks={0: step})
    snaps = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("snap_"))
    segs = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("journal_"))
    assert snaps == ["snap_00000002", "snap_00000003"]
    assert segs == ["journal_00000002.log", "journal_00000003.log"]
    # a stale .tmp (crash mid-commit) is swept by the next commit
    os.makedirs(tmp_path / "snap_00000009.tmp")
    j.commit_snapshot({"step": 9}, 9)
    assert not os.path.exists(tmp_path / "snap_00000009.tmp")
    j.close()


def test_journal_validates_cadence_and_requires_segment(tmp_path):
    with pytest.raises(ValueError, match="snapshot_every"):
        EngineJournal(str(tmp_path), snapshot_every=0)
    j = EngineJournal(str(tmp_path / "j"), snapshot_every=1)
    with pytest.raises(AssertionError, match="commit_snapshot"):
        j.append("tick", toks={})
    assert not EngineJournal.recoverable(str(tmp_path / "j"))
    assert not EngineJournal.recoverable(str(tmp_path / "absent"))


def test_request_record_roundtrip():
    req = Request(request_id=7, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=9, eos_id=3, arrival_step=2, priority=-1,
                  temperature=0.7, top_p=0.9, seed=123, deadline_s=4.5,
                  max_wall_s=2.0)
    req.seq = 11
    req.times_skipped = 2
    req.tokens = [1, 2, 3]
    req.status = RequestStatus.ACTIVE
    req.admit_step = 4
    req.slot = 1
    back = request_from_record(pickle.loads(pickle.dumps(
        request_record(req, runtime=True))))
    for f in ("request_id", "max_new_tokens", "eos_id", "arrival_step",
              "priority", "temperature", "top_p", "seed", "deadline_s",
              "max_wall_s", "seq", "times_skipped", "tokens", "status",
              "admit_step", "slot"):
        assert getattr(back, f) == getattr(req, f), f
    np.testing.assert_array_equal(back.prompt, req.prompt)
    # identity-only record must NOT carry runtime state
    slim = request_from_record(request_record(req))
    assert slim.tokens == [] and slim.status is RequestStatus.QUEUED
