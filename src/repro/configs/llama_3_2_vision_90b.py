"""llama-3.2-vision-90b — cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-Vision]. The vision frontend is a STUB: input_specs()
provides precomputed patch embeddings [B, num_image_tokens, d_model] consumed
as cross-attention memory. 100 layers = 20 x (4 self + 1 cross).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1600,
    rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    cross_attn_every=2,
    num_image_tokens=16,
    dtype="float32",
)
