"""zamba2-1.2b — Mamba2 backbone + weight-shared attention block
[arXiv:2411.15242]. 38 Mamba2 layers; the shared attention(+MLP) block is
applied after every 6th layer (6 applications, each with its own KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    block="mamba2",
    ssm_state=64,
    attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    block="mamba2",
    ssm_state=16,
    attn_every=2,
    dtype="float32",
)
