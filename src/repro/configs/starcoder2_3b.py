"""starcoder2-3b — GQA, RoPE, sliding-window 4096 [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    sliding_window=4096,
    rope_theta=1e5,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    sliding_window=32,
    dtype="float32",
)
