"""granite-8b — llama-arch code model [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
)
