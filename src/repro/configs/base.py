"""Config system: every architecture is a ModelConfig; shapes are ShapeConfig.

Configs are plain dataclasses (no framework deps) so the launcher, tests and
benchmarks can construct them without touching jax.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts layer configuration (the paper's target module)."""

    num_experts: int
    top_k: int
    d_expert: int                     # hidden width of each expert FFN
    num_shared_experts: int = 0       # deepseek-style always-on experts
    routing: str = "token_choice"     # "token_choice" | "expert_choice"
    # --- paper technique knobs (C1-C3) ---
    group_size: int = 1               # crossbar-multiplexing analogue: experts per shared lane
    grouping: str = "sorted"          # "uniform" | "sorted" (load-aware, C2)
    capacity_factor: float = 1.25     # token-choice expert capacity
    balance_coef: float = 0.01        # aux balance-loss coefficient (training)
    use_grouped_gemm: bool = True     # group-multiplexed execution path (C1)
    # --- execution backend ---
    # "pallas" streams every path through the tile-dispatch grouped GEMM
    # (kernels/moe_gmm.py): zero-redundancy C1 multiplexing, dropless.
    # "xla" is the masked einsum realization (validation + CPU production).
    # "auto" resolves per host: pallas on TPU (Mosaic), xla elsewhere —
    # except under training (loss_fn), which pins "auto" to xla until the
    # pallas kernels grow a VJP (see ROADMAP).
    backend: str = "auto"             # "auto" | "xla" | "pallas"
    gmm_block_rows: int = 0           # pallas row-tile height (0 = auto)
    # --- C4 ---
    go_cache: bool = True             # gate-output cache for expert-choice decode


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. All assigned archs reduce to this one schema."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    block: str = "attn"               # attn | xlstm | mamba2
    moe: Optional[MoEConfig] = None

    # attention details
    qkv_bias: bool = False            # qwen2
    rope_theta: float = 10000.0
    sliding_window: int = 0           # >0 enables local attention layers
    local_global_ratio: int = 0       # gemma3: N local layers per 1 global
    logit_softcap: float = 0.0

    # --- serving: paged-attention realization ---
    # "kernel" walks the block table in Pallas (kernels/paged_attn.py):
    # per-tick HBM traffic scales with live tokens. "gather" re-materializes
    # the dense [B, max_tokens] layout per layer per tick (bit-exact vs the
    # dense pool, the escape hatch). "auto" resolves per lowering platform:
    # kernel on TPU (Mosaic), gather elsewhere — CPU CI opts into the kernel
    # explicitly (REPRO_FORCE_PAGED_KERNEL / with_overrides).
    paged_attn: str = "auto"          # "auto" | "kernel" | "gather"

    # --- serving: quantized decode state (paged pools only) ---
    # "int8" stores KV pages as int8 with per-page, per-kv-head amax scales
    # (f32 [L, NP, Hkv]) and GO rows as int8 with per-row scales — bytes per
    # resident token drop ~4x vs the fp32 smoke dtype (~2x vs bf16) while
    # attention compute stays fp32 (dequantized in-kernel / at the gather).
    # The enum leaves room for fp8 once hardware dtypes land. "none" keeps
    # the full-precision pages. Quantized mode REQUIRES a paged pool — scale
    # granularity is page granularity (core/quant.py).
    kv_quant: str = "none"            # "none" | "int8"

    # ssm / hybrid details
    ssm_state: int = 0                # mamba2 state size (zamba2: 64)
    ssm_chunk: int = 128              # SSD chunk length
    attn_every: int = 0               # zamba2: shared attention block every N layers
    slstm_every: int = 0              # xlstm: one sLSTM block every N layers
    conv_width: int = 4               # mamba2 short conv

    # multimodal / enc-dec details
    cross_attn_every: int = 0         # llama-vision: cross-attn layer cadence
    num_image_tokens: int = 0         # stub patch-embedding count
    encoder_layers: int = 0           # whisper: >0 -> encoder-decoder
    num_audio_frames: int = 0         # stub frame-embedding count

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # training-time knobs (used by launch/train.py and the dry-run)
    remat: bool = True
    scan_layers: bool = True
    seq_shard_activations: bool = True   # SP on the residual stream
    sp_attn: bool = False    # sequence-parallel attention fallback (forward-
                             # only paths; for head counts that don't divide
                             # the model axis — a §Perf hillclimb knob)

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        d, hd = self.d_model, self.resolved_head_dim()
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * nq + 2 * d * hd * nkv + hd * nq * d
        if self.block == "attn":
            mix = attn
        elif self.block == "xlstm":
            # mLSTM block: up(2x) + q/k/v (on 2d inner) + gates + down
            di = 2 * d
            mix = d * di * 2 + 3 * di * di // max(1, nq) * nq // max(1, nq) + di * d
            mix = d * di * 2 + 3 * di * hd * nq // max(nq, 1) + di * d  # approx
            mix = 2 * d * di + 3 * di * di + di * d
        elif self.block == "mamba2":
            di = 2 * d
            mix = d * (2 * di + 2 * self.ssm_state) + di * d
        else:
            raise ValueError(self.block)
        if self.moe is not None:
            e = self.moe
            ffn = (e.num_experts + e.num_shared_experts) * 3 * d * e.d_expert
            ffn += d * e.num_experts  # gate
        elif self.d_ff > 0:
            ffn = 3 * d * self.d_ff  # gated SwiGLU
        else:
            ffn = 0
        layers = self.num_layers * (mix + ffn)
        if self.attn_every:
            layers += attn  # zamba2 shared attention block params
        if self.cross_attn_every:
            n_x = self.num_layers // self.cross_attn_every
            layers += n_x * (attn + 3 * d * self.d_ff)
        if self.encoder_layers:
            layers += self.encoder_layers * (attn + 2 * d * self.d_ff)
            layers += self.num_layers * attn  # decoder cross-attn
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return layers + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top-k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d = self.d_model
        full_ffn = self.num_layers * (e.num_experts + e.num_shared_experts) * 3 * d * e.d_expert
        act_ffn = self.num_layers * (e.top_k + e.num_shared_experts) * 3 * d * e.d_expert
        return self.param_count() - full_ffn + act_ffn


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str                         # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    """End-to-end training-run knobs for launch/train.py."""

    steps: int = 100
    seq_len: int = 512
    global_batch: int = 8
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    microbatch: int = 0               # >0 enables gradient accumulation
