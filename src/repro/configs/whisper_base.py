"""whisper-base — encoder-decoder with conv frontend STUB [arXiv:2212.04356].

input_specs() provides precomputed frame embeddings [B, num_audio_frames,
d_model] in place of the log-mel conv stem. Encoder: bidirectional attention;
decoder: self-attention + cross-attention to the encoded frames. Learned
positions (no RoPE).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    num_audio_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    encoder_layers=2,
    num_audio_frames=16,
    dtype="float32",
)
