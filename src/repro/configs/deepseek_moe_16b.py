"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066]. The paper's C1-C3 techniques apply: experts are grouped
(group_size=2, load-sorted) and executed on the group-multiplexed path.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_expert=1408,
        num_shared_experts=2,
        routing="token_choice",
        group_size=2,
        grouping="sorted",
    ),
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=32,
    vocab_size=256,
    dtype="float32",
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_expert=32,
        num_shared_experts=1,
        routing="token_choice",
        group_size=2,
        grouping="sorted",
    ),
)
