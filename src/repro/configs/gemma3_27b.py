"""gemma3-27b — 5:1 local:global attention, 128k context [hf:google/gemma-3].

62 layers in repeating (5 local sliding-window 1024, 1 global) pattern.
head_dim fixed at 128 (not d_model / num_heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    sliding_window=1024,
    local_global_ratio=5,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    sliding_window=16,
    local_global_ratio=2,
    dtype="float32",
)
