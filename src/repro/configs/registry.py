"""Architecture registry: ``--arch <id>`` resolution for launcher/tests/benches.

Every assigned architecture (plus the paper's own llama_moe_4_16) registers a
FULL config and a reduced SMOKE config of the same structural family.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "starcoder2-3b": "starcoder2_3b",
    "granite-8b": "granite_8b",
    "qwen2-7b": "qwen2_7b",
    "gemma3-27b": "gemma3_27b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "whisper-base": "whisper_base",
    "llama_moe_4_16": "llama_moe_4_16",
}

ASSIGNED = [a for a in _MODULES if a != "llama_moe_4_16"]

# long_500k needs sub-quadratic attention: run only for SSM/hybrid families
# (constant or chunk-local state); full-attention archs skip (DESIGN.md §5).
LONG_CONTEXT_OK = {"xlstm-1.3b", "zamba2-1.2b"}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def shape_cells(name: str) -> list[ShapeConfig]:
    """The (arch x shape) cells this architecture runs in the dry-run."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if name in LONG_CONTEXT_OK:
        cells.append(SHAPES["long_500k"])
    return cells


def all_cells() -> list[tuple[str, ShapeConfig]]:
    out = []
    for a in _MODULES:
        for s in shape_cells(a):
            out.append((a, s))
    return out
