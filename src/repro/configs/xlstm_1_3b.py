"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H d_ff=0 vocab=50304. xLSTM[7:1]: every 8th block is an
sLSTM (scalar memory), the rest mLSTM (matrix memory). d_ff=0: the m/sLSTM
blocks carry their own up/down projections.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block="xlstm",
    slstm_every=8,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    block="xlstm",
    slstm_every=2,
    dtype="float32",
)
