"""llama_moe_4_16 — the paper's own target model (Llama-MoE-4/16
[arXiv:2406.16554]): Llama2-7B with every FFN split into 16 experts of
d_expert=688, top-4 routing. Following the paper we run it with EXPERT-CHOICE
routing (Zhou et al.) and the full technique stack: group-multiplexing
(group_size=2, load-sorted) + GO cache for generation.

16 experts x (2 matrices x 48 crossbars) = 1536 HERMES crossbars per layer in
the PIM mapping — matching the paper's setup exactly.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama_moe_4_16",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=688,
    vocab_size=32000,
    moe=MoEConfig(
        num_experts=16,
        top_k=4,
        d_expert=688,
        routing="expert_choice",
        group_size=2,
        grouping="sorted",
        go_cache=True,
    ),
)

SMOKE = ModelConfig(
    name="llama-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=32,
    vocab_size=256,
    dtype="float32",
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_expert=32,
        routing="expert_choice",
        group_size=2,
        grouping="sorted",
        go_cache=True,
    ),
)
