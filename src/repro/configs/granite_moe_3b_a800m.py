"""granite-moe-3b-a800m — 40 experts top-8 [hf:ibm-granite/granite-3.0].

E=40 does not divide the 16-way model axis, so the EP sharder falls back to
feature-dim TP on d_expert (=512, divisible); the C2 grouping still balances
the multiplexed lanes (group_size=2 -> 20 groups).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(
        num_experts=40,
        top_k=8,
        d_expert=512,
        routing="token_choice",
        group_size=2,
        grouping="sorted",
    ),
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=256,
    dtype="float32",
    moe=MoEConfig(
        num_experts=10,           # deliberately non-power-of-two, like 40
        top_k=2,
        d_expert=32,
        routing="token_choice",
        group_size=2,
        grouping="sorted",
    ),
)
