"""Sharded checkpointing with atomic commit, async writer, and step recovery.

Layout:  <dir>/step_<N>/
            manifest.json         tree structure, shapes, dtypes, shard map
            arr_<i>.npy           one file per leaf (host-gathered)
            COMMITTED             empty marker written LAST (atomic commit)

Fault-tolerance contract:
  * a crash mid-write leaves no COMMITTED marker -> restore() ignores it;
  * orphaned uncommitted step dirs (crash between marker and rename, or a
    crash mid-prune) are swept on the next save();
  * latest_step() returns the newest committed step;
  * the async writer snapshots leaves to host memory synchronously (cheap)
    and writes files on a background thread, so the train loop never blocks
    on disk; `wait()` joins before the next save or process exit.
  * restore() validates every leaf file's npy header (shape + dtype) against
    the manifest and the target tree BEFORE loading/device_put — corruption
    or truncation raises a typed CorruptCheckpoint naming the leaf instead
    of a cryptic numpy/jax error mid-restore;
  * restore() device_puts each leaf with the target sharding, so a restored
    run continues under a DIFFERENT mesh shape (elastic restart).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


class CorruptCheckpoint(RuntimeError):
    """A committed checkpoint failed validation (truncated/corrupt leaf
    file, or manifest inconsistent with the files or the target tree). The
    message names the offending leaf so the caller can fall back to an
    older step instead of chasing a numpy stack trace."""


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, *, async_: bool = False,
         keep: int = 3) -> "Writer | None":
    """Checkpoint `tree` at `step`. Returns a Writer handle if async_."""
    leaves, treedef = _leaf_paths(tree)
    host = [np.asarray(jax.device_get(x)) if x is not None else None
            for x in leaves]
    w = Writer(directory, step, host, treedef, keep)
    if async_:
        w.start()
        return w
    w.run()
    return None


class Writer:
    def __init__(self, directory, step, host_leaves, treedef, keep):
        self.dir = directory
        self.step = step
        self.leaves = host_leaves
        self.treedef = treedef
        self.keep = keep
        self._t = None

    def start(self):
        self._t = threading.Thread(target=self.run, daemon=True)
        self._t.start()

    def wait(self):
        if self._t is not None:
            self._t.join()

    def run(self):
        self._sweep_orphans()
        d = os.path.join(self.dir, f"step_{self.step:08d}")
        tmp = d + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": self.step, "leaves": []}
        for i, leaf in enumerate(self.leaves):
            if leaf is None:
                manifest["leaves"].append(None)
                continue
            np.save(os.path.join(tmp, f"arr_{i}.npy"), leaf)
            manifest["leaves"].append(
                {"file": f"arr_{i}.npy", "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w"):
            pass
        shutil.rmtree(d, ignore_errors=True)
        os.replace(tmp, d)
        self._gc()

    def _gc(self):
        steps = committed_steps(self.dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def _sweep_orphans(self):
        """Remove crash leftovers before writing: stale `step_*.tmp` dirs
        (other than this save's own) and uncommitted `step_*` dirs — a
        crash mid-prune or mid-commit can strand both, and nothing else
        ever cleans them (restore() skips them but they accumulate)."""
        own_tmp = f"step_{self.step:08d}.tmp"
        for name in os.listdir(self.dir) if os.path.isdir(self.dir) else []:
            if not name.startswith("step_") or name == own_tmp:
                continue
            path = os.path.join(self.dir, name)
            if name.endswith(".tmp") or \
                    not os.path.exists(os.path.join(path, "COMMITTED")):
                shutil.rmtree(path, ignore_errors=True)


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMITTED")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def _validate_leaf(path: str, i: int, meta: dict, ref) -> None:
    """Pre-load validation of one leaf file: npy header parseable, header
    shape/dtype match the manifest, shape matches the target tree, and the
    file is large enough to hold the data the header promises (truncation
    is the classic crash corruption). Raises CorruptCheckpoint naming the
    leaf — BEFORE np.load or device_put touch it."""
    name = meta["file"]
    try:
        with open(path, "rb") as f:
            version = np.lib.format.read_magic(f)
            if version == (1, 0):
                shape, _, dtype = np.lib.format.read_array_header_1_0(f)
            elif version == (2, 0):
                shape, _, dtype = np.lib.format.read_array_header_2_0(f)
            else:
                raise ValueError(f"unsupported npy format version {version}")
            header_end = f.tell()
    except CorruptCheckpoint:
        raise
    except FileNotFoundError:
        raise CorruptCheckpoint(
            f"leaf {i} ({name}): file missing from committed "
            "checkpoint") from None
    except Exception as e:
        raise CorruptCheckpoint(
            f"leaf {i} ({name}): unreadable npy header ({e})") from e
    if list(shape) != list(meta["shape"]) or str(dtype) != meta["dtype"]:
        raise CorruptCheckpoint(
            f"leaf {i} ({name}): file header {shape}/{dtype} != manifest "
            f"{tuple(meta['shape'])}/{meta['dtype']}")
    if tuple(shape) != tuple(ref.shape):
        raise CorruptCheckpoint(
            f"leaf {i} ({name}): ckpt shape {tuple(shape)} != model "
            f"{tuple(ref.shape)}")
    need = header_end + int(dtype.itemsize) * int(np.prod(shape, dtype=np.int64))
    have = os.path.getsize(path)
    if have < need:
        raise CorruptCheckpoint(
            f"leaf {i} ({name}): truncated — {have} bytes on disk, header "
            f"promises {need}")


def restore(directory: str, step: int, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated).
    `shardings`: optional matching pytree of NamedSharding — leaves are placed
    directly to their target shards (elastic-safe). Every leaf file is
    header-validated against the manifest and `like` up front; corruption
    raises CorruptCheckpoint naming the leaf."""
    d = os.path.join(directory, f"step_{step:08d}")
    assert os.path.exists(os.path.join(d, "COMMITTED")), f"no committed ckpt at {d}"
    leaves, treedef = _leaf_paths(like)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if len(manifest["leaves"]) != len(leaves):
        raise CorruptCheckpoint(
            f"manifest holds {len(manifest['leaves'])} leaves, target tree "
            f"has {len(leaves)}")
    for i, ref in enumerate(leaves):
        meta = manifest["leaves"][i]
        if meta is None or ref is None:
            continue
        _validate_leaf(os.path.join(d, meta["file"]), i, meta, ref)
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        meta = manifest["leaves"][i]
        if meta is None or ref is None:
            out.append(None)
            continue
        try:
            arr = np.load(os.path.join(d, meta["file"]))
        except Exception as e:
            raise CorruptCheckpoint(
                f"leaf {i} ({meta['file']}): load failed after header "
                f"validation ({e})") from e
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return treedef.unflatten(out)
