"""Batched serving driver with KV + GO caches (the paper's generation path).

Flow per batch of requests:
  1. prefill() — full-sequence pass fills the KV caches and, for
     expert-choice MoE, builds the per-layer GO caches (paper eq. 4-5);
  2. serve_step() per generated token — O(1) state growth: the gate sees ONE
     token, TopKUpdate against cached mins replaces at most one slot per
     expert, and only selecting experts' outputs are recomputed.

CPU-runnable with smoke configs:
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --batch 4 --prompt 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.model import model_init, prefill, serve_step


def generate(params, cfg, prompts: jax.Array, gen_tokens: int,
             extras: dict | None = None, *, greedy: bool = True,
             key=None) -> dict:
    """prompts [B, T] -> generated [B, gen_tokens] (+ stats)."""
    B, T = prompts.shape
    state, logits = jax.jit(
        prefill, static_argnames=("cfg", "max_len"))(
            params, prompts, cfg, extras or {}, max_len=T + gen_tokens + 1)
    step = jax.jit(serve_step, static_argnames="cfg")

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        out.append(tok)
        logits, state = step(params, state, tok, cfg)
        if greedy or key is None:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
    dt = time.time() - t0
    return {
        "tokens": jnp.stack(out, axis=1),
        "decode_s": dt,
        "tok_per_s": B * gen_tokens / dt,
        "state": state,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt), 0, cfg.vocab_size, dtype=jnp.int32)
    extras = {}
    if cfg.cross_attn_every:
        extras["image_embeds"] = extras["memory"] = jnp.zeros(
            (args.batch, cfg.num_image_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    res = generate(params, cfg, prompts, args.gen, extras)
    print(f"generated {res['tokens'].shape} in {res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s)")
    print("sample:", np.asarray(res["tokens"][0])[:16])


if __name__ == "__main__":
    main()
