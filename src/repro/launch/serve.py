"""Serving drivers with KV + GO caches (the paper's generation path).

Two modes share the same compiled kernels:

  generate()        static batch — a fixed batch of requests moves lock-step
                    from prefill to completion. The reference semantics (and
                    the oracle the serving tests compare against).
  ServingEngine     continuous batching (repro/serving) — requests join
                    mid-flight into free slots of a pooled KV+GO cache and
                    retire on EOS/length; nothing stalls, nothing recompiles.
                    This is the default for the CLI below.

Flow per request, either way:
  1. prefill() — full-sequence pass fills the KV caches and, for
     expert-choice MoE, builds the per-layer GO caches (paper eq. 4-5);
  2. serve_step() per generated token — O(1) state growth: the gate sees ONE
     token, TopKUpdate against cached mins replaces at most one slot per
     expert, and only selecting experts' outputs are recomputed.

CPU-runnable with smoke configs:
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --requests 8 --slots 4 --prompt 32 --gen 16
  # static-batch reference path:
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --static --batch 4 --prompt 32 --gen 16
  # sharded: slot rows over the data axis, decode under a (2, 2) mesh:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --requests 8 --slots 4 --mesh-model 2
  # paged KV pool + chunked prefill (block tables; long prompts admitted
  # one page-granular chunk per tick):
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --requests 8 --slots 4 --paged --page-size 16 --chunk-prefill 16
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.model import model_init, prefill, serve_step
from repro.serving import ServingEngine


def generate(params, cfg, prompts: jax.Array, gen_tokens: int,
             extras: dict | None = None, *, greedy: bool = True,
             key=None, max_len: int = 0) -> dict:
    """prompts [B, T] -> generated [B, gen_tokens] (+ stats). `max_len` sizes
    the KV/GO cache (0 -> prompt + gen + 1); pass the slot pool's max_tokens
    to compare bit-exactly against the continuous-batching engine."""
    B, T = prompts.shape
    state, logits = jax.jit(
        prefill, static_argnames=("cfg", "max_len"))(
            params, prompts, cfg, extras or {},
            max_len=max_len or (T + gen_tokens + 1))
    step = jax.jit(serve_step, static_argnames="cfg")

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        out.append(tok)
        logits, state = step(params, state, tok, cfg)
        if greedy or key is None:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
    dt = time.time() - t0
    return {
        "tokens": jnp.stack(out, axis=1),
        "decode_s": dt,
        "tok_per_s": B * gen_tokens / dt,
        "state": state,
    }


def serve_continuous(params, cfg, prompts: list, gen_tokens: int, *,
                     num_slots: int, max_tokens: int = 0,
                     extras: dict | None = None,
                     arrival_steps: list | None = None, mesh=None,
                     temperature: float = 0.0, top_p: float = 1.0,
                     prompt_buckets: bool = False, paged: bool = False,
                     page_size: int = 16, num_pages: int | None = None,
                     prefill_chunk: int = 0,
                     priorities: list | None = None,
                     preemption: bool = False, chaos=None,
                     deadline_s: float | None = None,
                     max_wall_s: float | None = None,
                     prefix_share: bool | None = None,
                     expert_aware: bool | None = None) -> dict:
    """Run a list of prompts through the continuous-batching engine.
    With `mesh`, slot rows are sharded across the data-parallel replicas and
    every decode tick runs under the mesh (launch/sharding.py rules).
    `temperature` > 0 samples with top-p nucleus filtering (per-request
    seeds derive from the request id); `prompt_buckets` pads prompts to
    power-of-two buckets so prefill compiles once per bucket. `paged` swaps
    the dense slot rows for the block-table page pool (`page_size`,
    `num_pages` — None keeps the dense token capacity); `prefill_chunk`
    admits long prompts one chunk per tick; `priorities` orders admission
    (lower = earlier, FIFO within a level). `preemption` lets a blocked
    higher-priority admission evict lower-priority streams (paged pools;
    evicted streams resume bit-identically); `chaos` injects seeded faults
    (serving/chaos.py); `deadline_s`/`max_wall_s` bound every request's
    wall clock (TIMEOUT past them). Requests that end in a non-DONE
    terminal status surface their partial streams. `prefix_share` maps
    prompts sharing a page-aligned prefix onto the same physical pages
    copy-on-write and skips the shared prefill (paged pools);
    `expert_aware` scores admission order by routing overlap with the
    active batch (MoE attention archs) — both default to the
    REPRO_PREFIX_SHARE / REPRO_EXPERT_AWARE env knobs.
    Returns per-request token arrays plus engine stats."""
    max_tokens = max_tokens or (
        max(len(p) for p in prompts) + gen_tokens + 1)
    # the engine requires max_tokens to be page- and chunk-granular; round
    # the derived default up so the CLI knobs compose in any combination
    grain = math.lcm(page_size if paged else 1,
                     prefill_chunk if prefill_chunk else 1)
    max_tokens += -max_tokens % grain
    eng = ServingEngine(params, cfg, num_slots=num_slots,
                        max_tokens=max_tokens, extras=extras, mesh=mesh,
                        prompt_buckets=prompt_buckets, paged=paged,
                        page_size=page_size, num_pages=num_pages,
                        prefill_chunk=prefill_chunk, preemption=preemption,
                        chaos=chaos, prefix_share=prefix_share,
                        expert_aware=expert_aware)
    ids = []
    for i, p in enumerate(prompts):
        step = arrival_steps[i] if arrival_steps else 0
        ids.append(eng.submit(p, gen_tokens, extras=extras,
                              arrival_step=step, temperature=temperature,
                              top_p=top_p,
                              priority=priorities[i] if priorities else 0,
                              deadline_s=deadline_s, max_wall_s=max_wall_s))
    t0 = time.time()
    fin = eng.run()
    dt = time.time() - t0
    toks = {rid: np.asarray(fin[rid].tokens, np.int32) for rid in ids}
    return {
        "tokens": toks,
        "decode_s": dt,
        "tok_per_s": sum(len(t) for t in toks.values()) / dt,
        "stats": eng.stats(),
        "engine": eng,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="static-batch generate() instead of the engine")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size for --static")
    ap.add_argument("--requests", type=int, default=8,
                    help="request count for the engine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--backend", choices=["auto", "xla", "pallas"],
                    default=None,
                    help="MoE execution backend override (default: config)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--buckets", action="store_true",
                    help="pad prompts to power-of-two buckets (one prefill "
                         "compile per bucket instead of per length)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool: block-table pages instead of dense "
                         "per-slot rows (attention-family archs)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size incl. the null page (0 = match the "
                         "dense pool's token capacity); smaller values "
                         "simulate a tighter HBM budget")
    ap.add_argument("--prefix-share", action="store_true",
                    help="copy-on-write prefix page sharing: prompts with a "
                         "page-aligned shared prefix map the same physical "
                         "pages and skip the shared prefill (needs --paged; "
                         "like REPRO_PREFIX_SHARE=1)")
    ap.add_argument("--expert-aware", action="store_true",
                    help="expert-aware admission: order admissions by "
                         "routing overlap with the active batch (MoE archs; "
                         "like REPRO_EXPERT_AWARE=1)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="admit prompts longer than this one chunk per tick "
                         "(0 = one-shot prefill); must divide max_tokens")
    ap.add_argument("--priority", type=int, default=0,
                    help="admission priority for the submitted requests "
                         "(lower = admitted first; FIFO within a level)")
    ap.add_argument("--preemption", action="store_true",
                    help="let blocked higher-priority admissions evict "
                         "lower-priority streams (paged pools; evicted "
                         "streams resume bit-identically)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request wall budget from submission "
                         "(0 = unbounded; exceeded -> status TIMEOUT)")
    ap.add_argument("--max-wall-s", type=float, default=0.0,
                    help="per-request wall budget from first admission "
                         "(0 = unbounded; exceeded -> status TIMEOUT)")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded fault injection: transient tick failures, "
                         "admission pressure, forced preemptions "
                         "(serving/chaos.py; like REPRO_CHAOS=1)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0,
                    help="run the engine under a smoke mesh with this "
                         "model-axis size (slot rows shard over the rest; "
                         "set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first on a single-device host)")
    args = ap.parse_args()
    if args.static and args.mesh_model:
        ap.error("--mesh-model shards the engine's slot pool; it has no "
                 "effect on the static generate() path (drop --static)")

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.backend is not None and cfg.moe is not None:
        import dataclasses
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, backend=args.backend))
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg)
    extras = {}
    if cfg.cross_attn_every:
        extras["image_embeds"] = extras["memory"] = jnp.zeros(
            (1 if not args.static else args.batch, cfg.num_image_tokens,
             cfg.d_model), jnp.dtype(cfg.dtype))

    if args.static:
        prompts = jax.random.randint(
            key, (args.batch, args.prompt), 0, cfg.vocab_size, dtype=jnp.int32)
        res = generate(params, cfg, prompts, args.gen, extras)
        print(f"generated {res['tokens'].shape} in {res['decode_s']:.2f}s "
              f"({res['tok_per_s']:.1f} tok/s)")
        print("sample:", np.asarray(res["tokens"][0])[:16])
        return

    mesh = None
    if args.mesh_model:
        from repro.launch.mesh import make_smoke_mesh
        mesh = make_smoke_mesh(model=args.mesh_model)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt, dtype=np.int32)
               for _ in range(args.requests)]
    # staggered arrivals: one new request every other engine tick
    arrivals = [2 * i for i in range(args.requests)]
    chaos = None
    if args.chaos:
        from repro.serving import Chaos
        chaos = Chaos(seed=args.chaos_seed, tick_fail=0.05, pressure=0.05,
                      preempt=0.05)
    res = serve_continuous(params, cfg, prompts, args.gen,
                           num_slots=args.slots, extras=extras or None,
                           arrival_steps=arrivals, mesh=mesh,
                           temperature=args.temperature, top_p=args.top_p,
                           prompt_buckets=args.buckets, paged=args.paged,
                           page_size=args.page_size,
                           num_pages=args.num_pages or None,
                           prefill_chunk=args.chunk_prefill,
                           priorities=[args.priority] * len(prompts),
                           preemption=args.preemption, chaos=chaos,
                           deadline_s=args.deadline_s or None,
                           max_wall_s=args.max_wall_s or None,
                           prefix_share=args.prefix_share or None,
                           expert_aware=args.expert_aware or None)
    s = res["stats"]
    print(f"served {s['finished']} requests over {s['steps']} ticks on "
          f"{args.slots} slots in {res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s)"
          + (f" [mesh {s['mesh']}]" if s["mesh"] else "")
          + (f" [paged ps={s['page_size']} pages={s['num_pages']}]"
             if s["paged"] else "")
          + (f" [chunk ticks {s['chunk_ticks']}]" if s["chunk_ticks"] else "")
          + (f" [prefix hits {s['prefix_hits']} shared pages "
             f"{s['pages_shared']} prefill skipped "
             f"{s['prefill_tokens_skipped']} tok]"
             if s["prefix_share"] else "")
          + (" [expert-aware]" if s["expert_aware"] else ""))
    print(f"statuses: {s['statuses']}  preemptions: {s['preemptions']} "
          f"(resumes {s['resumes']})  tick retries: {s['tick_retries']}"
          + (f"  chaos: {s['chaos']}" if s["chaos"] else ""))
    first = res["tokens"][min(res["tokens"])]
    print("sample:", first[:16])


if __name__ == "__main__":
    main()
