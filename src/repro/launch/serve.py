"""Serving drivers with KV + GO caches (the paper's generation path).

Two modes share the same compiled kernels:

  generate()        static batch — a fixed batch of requests moves lock-step
                    from prefill to completion. The reference semantics (and
                    the oracle the serving tests compare against).
  ServingEngine     continuous batching (repro/serving) — requests join
                    mid-flight into free slots of a pooled KV+GO cache and
                    retire on EOS/length; nothing stalls, nothing recompiles.
                    This is the default for the CLI below.

Flow per request, either way:
  1. prefill() — full-sequence pass fills the KV caches and, for
     expert-choice MoE, builds the per-layer GO caches (paper eq. 4-5);
  2. serve_step() per generated token — O(1) state growth: the gate sees ONE
     token, TopKUpdate against cached mins replaces at most one slot per
     expert, and only selecting experts' outputs are recomputed.

CPU-runnable with smoke configs:
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --requests 8 --slots 4 --prompt 32 --gen 16
  # static-batch reference path:
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --static --batch 4 --prompt 32 --gen 16
  # sharded: slot rows over the data axis, decode under a (2, 2) mesh:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --requests 8 --slots 4 --mesh-model 2
  # paged KV pool + chunked prefill (block tables; long prompts admitted
  # one page-granular chunk per tick):
  PYTHONPATH=src python -m repro.launch.serve --arch llama_moe_4_16 --smoke \
      --requests 8 --slots 4 --paged --page-size 16 --chunk-prefill 16
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.model import model_init, prefill, serve_step
from repro.serving import ServingEngine


def generate(params, cfg, prompts: jax.Array, gen_tokens: int,
             extras: dict | None = None, *, greedy: bool = True,
             key=None, max_len: int = 0) -> dict:
    """prompts [B, T] -> generated [B, gen_tokens] (+ stats). `max_len` sizes
    the KV/GO cache (0 -> prompt + gen + 1); pass the slot pool's max_tokens
    to compare bit-exactly against the continuous-batching engine."""
    B, T = prompts.shape
    state, logits = jax.jit(
        prefill, static_argnames=("cfg", "max_len"))(
            params, prompts, cfg, extras or {},
            max_len=max_len or (T + gen_tokens + 1))
    step = jax.jit(serve_step, static_argnames="cfg")

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        out.append(tok)
        logits, state = step(params, state, tok, cfg)
        if greedy or key is None:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
    dt = time.time() - t0
    return {
        "tokens": jnp.stack(out, axis=1),
        "decode_s": dt,
        "tok_per_s": B * gen_tokens / dt,
        "state": state,
    }


def serve_continuous(params, cfg, prompts: list, gen_tokens: int, *,
                     num_slots: int, max_tokens: int = 0,
                     extras: dict | None = None,
                     arrival_steps: list | None = None, mesh=None,
                     temperature: float = 0.0, top_p: float = 1.0,
                     prompt_buckets: bool = False, paged: bool = False,
                     page_size: int = 16, num_pages: int | None = None,
                     kv_quant: str | None = None,
                     prefill_chunk: int = 0,
                     priorities: list | None = None,
                     preemption: bool = False, chaos=None,
                     deadline_s: float | None = None,
                     max_wall_s: float | None = None,
                     prefix_share: bool | None = None,
                     expert_aware: bool | None = None,
                     journal_dir: str | None = None,
                     snapshot_every: int = 0) -> dict:
    """Run a list of prompts through the continuous-batching engine.
    With `mesh`, slot rows are sharded across the data-parallel replicas and
    every decode tick runs under the mesh (launch/sharding.py rules).
    `temperature` > 0 samples with top-p nucleus filtering (per-request
    seeds derive from the request id); `prompt_buckets` pads prompts to
    power-of-two buckets so prefill compiles once per bucket. `paged` swaps
    the dense slot rows for the block-table page pool (`page_size`,
    `num_pages` — None keeps the dense token capacity); `prefill_chunk`
    admits long prompts one chunk per tick; `priorities` orders admission
    (lower = earlier, FIFO within a level). `preemption` lets a blocked
    higher-priority admission evict lower-priority streams (paged pools;
    evicted streams resume bit-identically); `chaos` injects seeded faults
    (serving/chaos.py); `deadline_s`/`max_wall_s` bound every request's
    wall clock (TIMEOUT past them). Requests that end in a non-DONE
    terminal status surface their partial streams. `prefix_share` maps
    prompts sharing a page-aligned prefix onto the same physical pages
    copy-on-write and skips the shared prefill (paged pools);
    `expert_aware` scores admission order by routing overlap with the
    active batch (MoE attention archs) — both default to the
    REPRO_PREFIX_SHARE / REPRO_EXPERT_AWARE env knobs. `journal_dir`
    journals every request lifecycle event and commits an atomic engine
    snapshot every `snapshot_every` ticks (paged pools;
    serving/journal.py) — a crashed run resumes bit-identically via
    ServingEngine.recover(journal_dir).
    Returns per-request token arrays plus engine stats."""
    max_tokens = max_tokens or (
        max(len(p) for p in prompts) + gen_tokens + 1)
    # the engine requires max_tokens to be page- and chunk-granular; round
    # the derived default up so the CLI knobs compose in any combination
    grain = math.lcm(page_size if paged else 1,
                     prefill_chunk if prefill_chunk else 1)
    max_tokens += -max_tokens % grain
    eng = ServingEngine(params, cfg, num_slots=num_slots,
                        max_tokens=max_tokens, extras=extras, mesh=mesh,
                        prompt_buckets=prompt_buckets, paged=paged,
                        page_size=page_size, num_pages=num_pages,
                        kv_quant=kv_quant,
                        prefill_chunk=prefill_chunk, preemption=preemption,
                        chaos=chaos, prefix_share=prefix_share,
                        expert_aware=expert_aware,
                        journal_dir=journal_dir or None,
                        snapshot_every=snapshot_every)
    ids = []
    for i, p in enumerate(prompts):
        step = arrival_steps[i] if arrival_steps else 0
        ids.append(eng.submit(p, gen_tokens, extras=extras,
                              arrival_step=step, temperature=temperature,
                              top_p=top_p,
                              priority=priorities[i] if priorities else 0,
                              deadline_s=deadline_s, max_wall_s=max_wall_s))
    t0 = time.time()
    fin = eng.run()
    dt = time.time() - t0
    toks = {rid: np.asarray(fin[rid].tokens, np.int32) for rid in ids}
    return {
        "tokens": toks,
        "decode_s": dt,
        "tok_per_s": sum(len(t) for t in toks.values()) / dt,
        "stats": eng.stats(),
        "engine": eng,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="static-batch generate() instead of the engine")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size for --static")
    ap.add_argument("--requests", type=int, default=8,
                    help="request count for the engine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--backend", choices=["auto", "xla", "pallas"],
                    default=None,
                    help="MoE execution backend override (default: config)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--buckets", action="store_true",
                    help="pad prompts to power-of-two buckets (one prefill "
                         "compile per bucket instead of per length)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool: block-table pages instead of dense "
                         "per-slot rows (attention-family archs)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size incl. the null page (0 = match the "
                         "dense pool's token capacity); smaller values "
                         "simulate a tighter HBM budget")
    ap.add_argument("--kv-quant", choices=["none", "int8"], default="none",
                    help="quantized decode state: int8 KV pages + GO rows "
                         "with per-page / per-row f32 scales (needs --paged; "
                         "~4x more pages per HBM byte, decode logits within "
                         "a small dequant bound of fp32)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="copy-on-write prefix page sharing: prompts with a "
                         "page-aligned shared prefix map the same physical "
                         "pages and skip the shared prefill (needs --paged; "
                         "like REPRO_PREFIX_SHARE=1)")
    ap.add_argument("--expert-aware", action="store_true",
                    help="expert-aware admission: order admissions by "
                         "routing overlap with the active batch (MoE archs; "
                         "like REPRO_EXPERT_AWARE=1)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="admit prompts longer than this one chunk per tick "
                         "(0 = one-shot prefill); must divide max_tokens")
    ap.add_argument("--priority", type=int, default=0,
                    help="admission priority for the submitted requests "
                         "(lower = admitted first; FIFO within a level)")
    ap.add_argument("--preemption", action="store_true",
                    help="let blocked higher-priority admissions evict "
                         "lower-priority streams (paged pools; evicted "
                         "streams resume bit-identically)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request wall budget from submission "
                         "(0 = unbounded; exceeded -> status TIMEOUT)")
    ap.add_argument("--max-wall-s", type=float, default=0.0,
                    help="per-request wall budget from first admission "
                         "(0 = unbounded; exceeded -> status TIMEOUT)")
    ap.add_argument("--journal-dir", default="",
                    help="durable request journal + atomic engine snapshots "
                         "in this directory (needs --paged). If it already "
                         "holds a committed snapshot, the run RECOVERS from "
                         "it (replaying the journal tail, resuming every "
                         "live stream bit-identically) instead of starting "
                         "fresh")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="engine ticks between atomic snapshots "
                         "(with --journal-dir)")
    ap.add_argument("--supervise", action="store_true",
                    help="run the engine in a supervised child process: "
                         "file-mtime heartbeats, missed-heartbeat SIGKILL, "
                         "exponential-backoff restart, each restarted "
                         "generation re-dispatches through recover() "
                         "(needs --journal-dir)")
    ap.add_argument("--crash-step", type=int, default=-1,
                    help="chaos: SIGKILL the engine process at this engine "
                         "tick, first generation only — restarted "
                         "generations run through (the kill-recover-resume "
                         "lane; needs --journal-dir)")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded fault injection: transient tick failures, "
                         "admission pressure, forced preemptions "
                         "(serving/chaos.py; like REPRO_CHAOS=1)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0,
                    help="run the engine under a smoke mesh with this "
                         "model-axis size (slot rows shard over the rest; "
                         "set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first on a single-device host)")
    args = ap.parse_args()
    if args.static and args.mesh_model:
        ap.error("--mesh-model shards the engine's slot pool; it has no "
                 "effect on the static generate() path (drop --static)")
    if args.journal_dir and not args.paged:
        ap.error("--journal-dir needs --paged (engine snapshots are "
                 "SlotPool.snapshot block-table surgery)")
    if args.kv_quant != "none" and not args.paged:
        ap.error("--kv-quant needs --paged (scale granularity IS page "
                 "granularity — there is nothing to quantize per-page "
                 "in the dense pool)")
    if (args.supervise or args.crash_step >= 0) and not args.journal_dir:
        ap.error("--supervise/--crash-step need --journal-dir (restarted "
                 "generations re-dispatch through recover())")

    if args.supervise:
        # parent: re-exec this CLI (minus --supervise) as a watched child.
        # The child journals; a restarted generation finds the committed
        # snapshot in --journal-dir and recovers instead of starting fresh.
        from repro.runtime.fault import ProcessSupervisor
        os.makedirs(args.journal_dir, exist_ok=True)
        child = [sys.executable, "-m", "repro.launch.serve"] + \
            [a for a in sys.argv[1:] if a != "--supervise"]
        sup = ProcessSupervisor(
            child,
            heartbeat_file=os.path.join(args.journal_dir, "heartbeat"))
        code = sup.run()
        print(f"supervised serve exited {code} after "
              f"{sup.stats.restarts} restart(s), "
              f"{sup.stats.heartbeat_kills} heartbeat kill(s) "
              f"(exit codes {sup.stats.exit_codes})")
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.backend is not None and cfg.moe is not None:
        import dataclasses
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, backend=args.backend))
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg)
    extras = {}
    if cfg.cross_attn_every:
        extras["image_embeds"] = extras["memory"] = jnp.zeros(
            (1 if not args.static else args.batch, cfg.num_image_tokens,
             cfg.d_model), jnp.dtype(cfg.dtype))

    if args.static:
        prompts = jax.random.randint(
            key, (args.batch, args.prompt), 0, cfg.vocab_size, dtype=jnp.int32)
        res = generate(params, cfg, prompts, args.gen, extras)
        print(f"generated {res['tokens'].shape} in {res['decode_s']:.2f}s "
              f"({res['tok_per_s']:.1f} tok/s)")
        print("sample:", np.asarray(res["tokens"][0])[:16])
        return

    mesh = None
    if args.mesh_model:
        from repro.launch.mesh import make_smoke_mesh
        mesh = make_smoke_mesh(model=args.mesh_model)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt, dtype=np.int32)
               for _ in range(args.requests)]
    # staggered arrivals: one new request every other engine tick
    arrivals = [2 * i for i in range(args.requests)]
    chaos = None
    if args.chaos:
        from repro.serving import Chaos
        chaos = Chaos(seed=args.chaos_seed, tick_fail=0.05, pressure=0.05,
                      preempt=0.05)
    if args.crash_step >= 0 and int(os.environ.get(
            "REPRO_SUPERVISE_GENERATION", "0") or 0) == 0:
        # arm the crash in the FIRST generation only: the restarted one
        # must sail past the same tick number to prove recovery terminates
        from repro.serving import Chaos
        if chaos is None:
            chaos = Chaos(seed=args.chaos_seed)
        chaos.crash_step = args.crash_step

    if args.journal_dir:
        from repro.serving import EngineJournal, ServingEngine
        if EngineJournal.recoverable(args.journal_dir):
            t0 = time.time()
            eng = ServingEngine.recover(args.journal_dir, params, cfg,
                                        mesh=mesh, chaos=chaos,
                                        snapshot_every=args.snapshot_every)
            fin = eng.run()
            dt = time.time() - t0
            info, s = eng.recovered_info, eng.stats()
            print(f"recovered from {args.journal_dir} (snapshot seq "
                  f"{info['snapshot_seq']}, {info['events']} replayed "
                  f"events, {info['wall_ms']:.1f}ms) — drained to "
                  f"{s['finished']} finished requests in {dt:.2f}s")
            print(f"statuses: {s['statuses']}  recoveries: "
                  f"{s['recoveries']}  restart generation: "
                  f"{s['restart_count']}")
            if fin:
                first = fin[min(fin)].tokens
                print("sample:", np.asarray(first[:16], np.int32))
            return

    res = serve_continuous(params, cfg, prompts, args.gen,
                           num_slots=args.slots, extras=extras or None,
                           arrival_steps=arrivals, mesh=mesh,
                           temperature=args.temperature, top_p=args.top_p,
                           prompt_buckets=args.buckets, paged=args.paged,
                           page_size=args.page_size,
                           num_pages=args.num_pages or None,
                           kv_quant=(args.kv_quant
                                     if args.kv_quant != "none" else None),
                           prefill_chunk=args.chunk_prefill,
                           priorities=[args.priority] * len(prompts),
                           preemption=args.preemption, chaos=chaos,
                           deadline_s=args.deadline_s or None,
                           max_wall_s=args.max_wall_s or None,
                           prefix_share=args.prefix_share or None,
                           expert_aware=args.expert_aware or None,
                           journal_dir=args.journal_dir or None,
                           snapshot_every=args.snapshot_every)
    s = res["stats"]
    print(f"served {s['finished']} requests over {s['steps']} ticks on "
          f"{args.slots} slots in {res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s)"
          + (f" [mesh {s['mesh']}]" if s["mesh"] else "")
          + (f" [paged ps={s['page_size']} pages={s['num_pages']}]"
             if s["paged"] else "")
          + (f" [kv-quant {s['kv_quant_dtype']} "
             f"{s['kv_bytes_per_token']:.0f} B/tok, dequant err "
             f"{s['dequant_max_abs_err']:.2e}]"
             if s["kv_quant_dtype"] else "")
          + (f" [chunk ticks {s['chunk_ticks']}]" if s["chunk_ticks"] else "")
          + (f" [prefix hits {s['prefix_hits']} shared pages "
             f"{s['pages_shared']} prefill skipped "
             f"{s['prefill_tokens_skipped']} tok]"
             if s["prefix_share"] else "")
          + (" [expert-aware]" if s["expert_aware"] else "")
          + (f" [journal {s['journal_bytes']}B, {s['snapshots']} snaps]"
             if s["journal_bytes"] else ""))
    print(f"statuses: {s['statuses']}  preemptions: {s['preemptions']} "
          f"(resumes {s['resumes']})  tick retries: {s['tick_retries']}"
          + (f"  chaos: {s['chaos']}" if s["chaos"] else ""))
    first = res["tokens"][min(res["tokens"])]
    print("sample:", first[:16])


if __name__ == "__main__":
    main()
