"""ShapeDtypeStruct stand-ins for every (arch x shape) cell — weak-type
correct, shardable, never allocating device memory.

Three lowerable entry points, chosen by the shape's kind:
  train    train_step(params, opt_state, batch) — microbatched grad-accum
  prefill  prefill_step(params, tokens, extras) — full-sequence forward
  decode   serve_step(params, state, tokens_t)  — one token + KV/GO caches
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import (init_decode_state, loss_fn, model_forward,
                                model_init, serve_step)
from repro.optim.adamw import adamw_init

F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def extras_specs(cfg: ModelConfig, batch: int, *, decode: bool) -> dict:
    """Modality-frontend STUBS: precomputed patch/frame embeddings."""
    dt = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.cross_attn_every > 0:
        key = "memory" if decode else "image_embeds"
        out[key] = _sds((batch, cfg.num_image_tokens, cfg.d_model), dt)
    if cfg.encoder_layers > 0:
        key = "memory" if decode else "audio_frames"
        out[key] = _sds((batch, cfg.num_audio_frames, cfg.d_model), dt)
    return out


def param_specs(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(partial(model_init, cfg=cfg), key)


def opt_specs(param_shapes):
    return jax.eval_shape(adamw_init, param_shapes)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      micro_global: int) -> dict:
    assert shape.global_batch % micro_global == 0
    n = shape.global_batch // micro_global
    out = {
        "tokens": _sds((n, micro_global, shape.seq_len), I32),
        "labels": _sds((n, micro_global, shape.seq_len), I32),
    }
    for k, v in extras_specs(cfg, micro_global, decode=False).items():
        out[k] = _sds((n, *v.shape), v.dtype)
    return out


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    extras = extras_specs(cfg, batch, decode=True)
    return jax.eval_shape(
        partial(init_decode_state, cfg, batch, max_len), extras=extras)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                micro_global: int = 0) -> dict:
    """All ShapeDtypeStruct inputs for the cell's entry point."""
    if shape.kind == "train":
        micro = micro_global or default_micro(cfg, shape)
        return {
            "params": param_specs(cfg),
            "opt_state": opt_specs(param_specs(cfg)),
            "batch": train_batch_specs(cfg, shape, micro),
        }
    if shape.kind == "prefill":
        return {
            "params": param_specs(cfg),
            "tokens": _sds((shape.global_batch, shape.seq_len), I32),
            "extras": extras_specs(cfg, shape.global_batch, decode=False),
        }
    # decode: one new token against caches of length seq_len
    return {
        "params": param_specs(cfg),
        "state": decode_state_specs(cfg, shape.global_batch, shape.seq_len),
        "tokens": _sds((shape.global_batch,), I32),
    }


def default_micro(cfg: ModelConfig, shape: ShapeConfig,
                  dp_total: int = 32) -> int:
    """Default global microbatch: one sequence per data-parallel shard."""
    return min(shape.global_batch, dp_total)


# ----------------------------------------------------------- entry points

def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    warmup: int = 100, total: int = 10000,
                    weight_decay: float = 0.1, grad_clip: float = 1.0):
    from repro.optim.adamw import accumulate_grads, adamw_update, cosine_lr

    def train_step(params, opt_state, batch):
        grads, loss = accumulate_grads(loss_fn, params, batch, cfg)
        step_lr = cosine_lr(opt_state.step, base_lr=lr, warmup=warmup,
                            total=total)
        params, opt_state, m = adamw_update(
            params, grads, opt_state, lr=step_lr,
            weight_decay=weight_decay, grad_clip=grad_clip)
        m["loss"] = loss
        return params, opt_state, m

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, extras):
        x, _ = model_forward(params, tokens, cfg, extras)
        from repro.models.model import logits_from_hidden
        return logits_from_hidden(params, x[:, -1, :], cfg)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def step(params, state, tokens_t):
        return serve_step(params, state, tokens_t, cfg)
    return step
