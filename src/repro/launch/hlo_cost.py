"""Loop-nest-aware HLO cost accounting.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE, which
under-reports scanned layer stacks / grad-accumulation / chunked attention by
orders of magnitude. The optimized HLO, however, annotates every `while` with
`backend_config={"known_trip_count":{"n":...}}`. This module parses the
post-GSPMD HLO text, builds the computation call graph (while bodies weighted
by trip count; fusion/call bodies weighted 1), and accumulates:

  flops        2 * result_elems * contraction_elems for every `dot`
               (batch/free dims are in the result; exact for GEMM/batched GEMM)
  bytes        HBM-traffic proxy: result + operand bytes of top-level
               data-moving ops (fusion, dot, copy, collectives, custom-call,
               dynamic-(update-)slice, scatter/gather, broadcast from HBM),
               i.e. the standard "fusion internals stay on-chip" roofline
               assumption — the same contract as XLA's own bytes-accessed.
  collectives  result bytes per kind, all-reduce counted twice (ring
               reduce + broadcast phases).

Validated against cost_analysis on fully-unrolled probes (tests/test_hlo_cost).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OPCODE = re.compile(r"([\w\-]+)\((.*)")


def xla_cost_dict(compiled) -> dict:
    """`compiled.cost_analysis()` normalized to a flat dict: depending on the
    jax/jaxlib version it returns a dict or a one-element list of dicts
    (per device partition)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _parse_instr(line: str):
    """'%name = SHAPE opcode(args), attrs' -> (name, shape, op, rest).
    Handles tuple shapes containing commas, layouts and /*index=N*/ comments."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape = rest[:end + 1]
        rest2 = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest2 = rest[sp + 1:]
    m = _OPCODE.match(rest2)
    if not m:
        return None
    return name, shape, m.group(1), m.group(2)
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_BODY = re.compile(r"body=%?([\w\.\-]+)")
_WHILE_COND = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+\"?(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# HBM-traffic proxy: ops that actually move data on a TPU. Layout/shape ops
# (transpose/reshape/broadcast/iota/convert) fuse into consumers on TPU and
# are excluded — in particular the CPU backend's hoisted bf16->f32 dot-operand
# conversions, which don't exist on the target.
_BYTES_OPS = {"fusion", "dot", "copy", "custom-call", "dynamic-slice",
              "dynamic-update-slice", "gather", "scatter", "concatenate",
              "reduce", "select-and-scatter", "sort", "rng", "convolution",
              "cholesky", "triangular-solve", *COLLECTIVES}
_SKIP_BYTES = {"get-tuple-element", "tuple", "parameter", "constant",
               "bitcast", "after-all", "while", "conditional", "call"}
# Layout/shape ops excluded from the fallback below for the same reason they
# are excluded from _BYTES_OPS (fuse into consumers on TPU).
_LAYOUT_OPS = {"transpose", "reshape", "broadcast", "iota", "convert",
               "bitcast-convert", "reverse", "pad", "slice",
               "copy-start", "copy-done"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Computation:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    bytes_inv: float = 0.0   # loop-invariant operand traffic (VMEM-resident
                             # on TPU across iterations -> charged once)
    upcast: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    # (callee, multiplier) edges
    calls: list = field(default_factory=list)
    # raw instruction records for the two-pass bytes attribution
    instrs: list = field(default_factory=list)
    param_gte: dict = field(default_factory=dict)   # sym -> tuple index
    root_operands: list = field(default_factory=list)


def parse_computations(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    # symbol table per computation: %name -> shape string
    symbols: dict[str, str] = {}
    upcast_syms: set[str] = set()
    fusion_bodies: set[str] = set()   # computations called BY fusion ops

    for line in hlo.splitlines():
        if line.startswith("ENTRY ") or (line.startswith("%") and "->" in line
                                         and line.rstrip().endswith("{")):
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                symbols = {}
                upcast_syms = set()
                if line.startswith("ENTRY"):
                    entry = cur.name
                # parameters: "name: shape" pairs in the header
                for pm in re.finditer(r"(\w[\w\.\-]*):\s*(\(?[a-z0-9\[\],\{\} ]+)",
                                      line):
                    symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        parsed = _parse_instr(line)
        if parsed is None:
            continue
        name, shape, op, rest = parsed
        symbols[name] = shape
        is_root = line.lstrip().startswith("ROOT ")
        if op == "get-tuple-element":
            im = re.search(r"index=(\d+)", line)
            ops0 = _OPERANDS.findall(rest.split(")")[0])
            if im and ops0 and ops0[0].startswith("arg_tuple"):
                cur.param_gte[name] = int(im.group(1))
        if is_root and op == "tuple":
            cur.root_operands = _OPERANDS.findall(rest.split(")")[0])
        if op == "convert" and shape.startswith("f32"):
            ops_part0 = rest.split(")")[0]
            first = _OPERANDS.findall(ops_part0)
            if first and symbols.get(first[0], "").startswith("bf16"):
                upcast_syms.add(name)   # f32 staging of a bf16 tensor

        if op == "parameter":
            continue
        if op == "while":
            body = _WHILE_BODY.search(line)
            trip = _TRIP.search(line)
            n = int(trip.group(1)) if trip else 1
            if body:
                cur.calls.append((body.group(1), n))
            cond = _WHILE_COND.search(line)
            if cond:
                cur.calls.append((cond.group(1), n))
            continue
        if op not in ("while",):
            for cm in _CALLS.finditer(line):
                cur.calls.append((cm.group(1), 1))
                if op == "fusion":
                    fusion_bodies.add(cm.group(1))
            bm = _BRANCHES.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        cur.calls.append((b, 1))
        if op == "dot":
            cdims = _CONTRACT.search(line)
            contract = 1
            ops_part = rest.split(")")[0]
            operand_names = _OPERANDS.findall(ops_part)
            if cdims and operand_names:
                lhs_shape = symbols.get(operand_names[0], "")
                sm = _SHAPE_RE.search(lhs_shape)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for ci in cdims.group(1).split(","):
                        if ci:
                            contract *= dims[int(ci)]
            cur.flops += 2.0 * _shape_elems(shape) * contract
        if op == "convolution":
            # rare here (stub frontends); approximate as result*2*window
            cur.flops += 2.0 * _shape_elems(shape)
        if op in COLLECTIVES:
            b = _shape_bytes(shape)
            cur.coll[op] += b * (2 if op == "all-reduce" else 1)
        if op == "convert" and shape.startswith("f32"):
            # CPU-backend bf16->f32 dot-operand upcasts (absent on TPU:
            # the MXU consumes bf16 natively). Tracked so the dry-run can
            # report a target-corrected memory watermark. Only large hoisted
            # copies matter (weight stacks, caches); counted once per
            # computation (allocations are reused across loop iterations).
            ops_part = rest.split(")")[0]
            operands = _OPERANDS.findall(ops_part)
            if operands and symbols.get(operands[0], "").startswith("bf16"):
                b = _shape_bytes(shape)
                if b >= 16 * 2**20:
                    cur.upcast += b
        fallback = (op not in _BYTES_OPS and op not in _SKIP_BYTES
                    and op not in _LAYOUT_OPS)
        if op in _BYTES_OPS or fallback:
            # The fallback catches UNFUSED elementwise ops (tanh, add,
            # select, ...): the CPU backend schedules them as standalone
            # top-level instructions — a real result+operands buffer
            # traversal. Inside fusion bodies the same opcodes are on-chip
            # temporaries already covered by the fusion call site's entry in
            # _BYTES_OPS, so the second pass drops fallback instrs there.
            ops_part = rest.split(")")[0]
            onames = _OPERANDS.findall(ops_part)
            cur.instrs.append((op, shape, [
                (on, symbols.get(on, ""), on in upcast_syms)
                for on in onames], fallback))

    # ---- second pass: bytes attribution.
    # * dynamic-slice/gather read only the sliced region (NOT the full
    #   stacked-weights buffer they index);
    # * dynamic-update-slice writes only the updated region (result aliases);
    # * operands that are loop-invariant tuple elements of a while body are
    #   VMEM-resident across iterations on TPU -> separated into bytes_inv,
    #   charged once per while execution instead of per iteration.
    for c in comps.values():
        invariant = {sym for sym, idx in c.param_gte.items()
                     if idx < len(c.root_operands)
                     and c.root_operands[idx] == sym}
        is_fusion_body = c.name in fusion_bodies
        for op, shape, operands, fallback in c.instrs:
            if fallback and is_fusion_body:
                continue        # on-chip temporary, counted at the call site
            rb = _shape_bytes(shape)
            if op == "dynamic-update-slice":
                upd = _shape_bytes(operands[1][1]) if len(operands) > 1 else rb
                c.bytes += 2 * min(upd, rb)
                continue
            b_var, b_inv = float(rb), 0.0
            for i, (on, oshape, upc) in enumerate(operands):
                ob = _shape_bytes(oshape)
                if upc:
                    ob //= 2
                if op in ("dynamic-slice", "gather") and i == 0:
                    ob = min(ob, rb)
                if op == "fusion":
                    # scan-xs slicing compiles to fusion(dynamic-slice(stack));
                    # a streaming fusion reads O(result), not the full stack.
                    # The 16x cap keeps reduction fusions exact while removing
                    # the full-stack-per-iteration artifact.
                    ob = min(ob, max(16 * rb, 1 << 20))
                if on in invariant:
                    b_inv += ob
                else:
                    b_var += ob
            c.bytes += b_var
            c.bytes_inv += b_inv
    return comps, entry


def analyze(hlo: str) -> dict:
    """Loop-aware totals for one HLO module."""
    comps, entry = parse_computations(hlo)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}

    # accumulate multipliers over the call graph (memoized DFS; HLO call
    # graphs are DAGs)
    totals = {"flops": 0.0, "bytes": 0.0}
    coll = defaultdict(float)
    from functools import lru_cache
    import sys
    sys.setrecursionlimit(100000)

    memo: dict[str, tuple] = {}

    def visit(name: str) -> tuple:
        """Returns (flops, bytes_var, bytes_inv, coll) incl. callees. A
        callee's invariant bytes are charged ONCE per call-site execution
        (mult applies only to the variant part)."""
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0, 0.0, {}
        f, b = c.flops, c.bytes
        cc = dict(c.coll)
        for callee, mult in c.calls:
            cf, cb, cinv, ccoll = visit(callee)
            f += mult * cf
            b += mult * cb + cinv          # invariant: once per execution
            for k, v in ccoll.items():
                cc[k] = cc.get(k, 0.0) + mult * v
        memo[name] = (f, b, c.bytes_inv, cc)
        return memo[name]

    f, b, binv, cc = visit(entry)
    b += binv
    upcast = sum(c.upcast for c in comps.values())   # allocated once each
    out = {"flops": f, "bytes": b, "cpu_upcast_bytes": upcast,
           "collectives": {**{k: v for k, v in cc.items()},
                           "total": sum(cc.values())}}
    return out
