import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell:
  jax.jit(entry, in_shardings, out_shardings).lower(**specs).compile()
then record memory_analysis / cost_analysis / collective bytes (parsed from
the post-GSPMD HLO) into artifacts/dryrun/<arch>_<shape>_<mesh>.json — the
roofline table (benchmarks/roofline.py) reads these artifacts.

Usage:
  python -m repro.launch.dryrun --arch deepseek-moe-16b --shape train_4k
  python -m repro.launch.dryrun --arch gemma3-27b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import get_config, list_archs, shape_cells
from repro.launch import sharding as SH
from repro.launch import specs as SPEC
from repro.launch.hlo_cost import analyze as hlo_analyze, xla_cost_dict
from repro.launch.mesh import dp_axes, make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\s(]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-tensor bytes per collective kind (all-reduce counted 2x for
    the ring's reduce+broadcast phases). Approximates per-device ICI bytes."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] += n * (2 if kind == "all-reduce" else 1)
    out["total"] = sum(out.values())
    return out


def _sharded_specs(cfg, shape, mesh, *, policy: str = "tp",
                   micro_global: int = 0):
    """(kwargs of ShapeDtypeStructs, in_shardings pytree, entry_fn)."""
    specs = SPEC.input_specs(cfg, shape, micro_global=micro_global)
    mode = "train" if shape.kind == "train" else "serve"
    if policy == "dp_only":
        mode += "_dp"
    p_sh = SH.param_shardings(specs["params"], cfg, mesh, mode)
    if shape.kind == "train":
        o_sh = SH.opt_shardings(specs["opt_state"], p_sh)
        b_sh = SH.batch_shardings(specs["batch"], mesh, policy)
        fn = SPEC.make_train_step(cfg)
        return specs, (p_sh, o_sh, b_sh), fn, ("params", "opt_state", "batch")
    if shape.kind == "prefill":
        dp = dp_axes(mesh)
        t_sh = NamedSharding(mesh, P(dp, None))
        e_sh = SH.state_shardings(specs["extras"], cfg, mesh,
                                  shape.global_batch)
        fn = SPEC.make_prefill_step(cfg)
        return specs, (p_sh, t_sh, e_sh), fn, ("params", "tokens", "extras")
    dp = dp_axes(mesh)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    s_sh = SH.state_shardings(specs["state"], cfg, mesh, shape.global_batch)
    t_sh = NamedSharding(
        mesh, P(dp) if shape.global_batch % dp_n == 0 else P(None))
    fn = SPEC.make_serve_step(cfg)
    return specs, (p_sh, s_sh, t_sh), fn, ("params", "state", "tokens")


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             smoke: bool = False, save: bool = True, *,
             policy: str = "tp", micro_global: int = 0,
             cfg_overrides: dict | None = None, variant: str = "") -> dict:
    cfg = get_config(arch, smoke=smoke)
    if cfg_overrides:
        cfg = cfg.with_overrides(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_tag = "2pod" if multi_pod else "1pod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        specs, in_sh, fn, order = _sharded_specs(
            cfg, shape, mesh, policy=policy, micro_global=micro_global)
        args = [specs[k] for k in order]
        # donation mirrors production: train donates (params, opt_state);
        # decode donates the serving state (KV/GO caches update in place)
        donate = (0, 1) if shape.kind == "train" else \
                 ((1,) if shape.kind == "decode" else ())
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = xla_cost_dict(compiled)
        hlo_text = compiled.as_text()
        loop_aware = hlo_analyze(hlo_text)   # trip-count-corrected totals

    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "variant": variant or "baseline",
        "policy": policy,
        "devices": n_dev,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # loop-aware (while bodies x known_trip_count) — the roofline inputs
        "flops_per_device": loop_aware["flops"],
        "bytes_per_device": loop_aware["bytes"],
        "collective_bytes_per_device": loop_aware["collectives"],
        # raw XLA numbers (loop bodies counted once) kept for reference
        "raw_cost_analysis": {
            "flops": cost.get("flops", -1.0),
            "bytes_accessed": cost.get("bytes accessed", -1.0),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
            # CPU backend upcasts bf16 dot operands to f32 and hoists the
            # conversions (weight stacks, caches). A TPU target consumes
            # bf16 natively, so the corrected watermark excludes them.
            "cpu_upcast_bytes": loop_aware["cpu_upcast_bytes"],
            "temp_bytes_tpu_corrected": max(
                0, getattr(mem, "temp_size_in_bytes", 0)
                - loop_aware["cpu_upcast_bytes"]),
        },
    }
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        suffix = f"_{variant}" if variant else ""
        path = os.path.join(
            ART_DIR, f"{arch}_{shape_name}_{mesh_tag}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in shape_cells(a):
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shp in cells:
        try:
            rec = run_cell(arch, shp, multi_pod=args.multi_pod,
                           smoke=args.smoke)
            print(f"OK   {arch:22s} {shp:12s} {rec['mesh']} "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"mem_temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                  f"(tpu~{rec['memory']['temp_bytes_tpu_corrected']/2**30:.2f}) "
                  f"coll={rec['collective_bytes_per_device']['total']/2**20:.1f}MiB "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                  flush=True)
        except Exception as e:
            failures.append((arch, shp, repr(e)))
            print(f"FAIL {arch:22s} {shp:12s}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed")


if __name__ == "__main__":
    main()
