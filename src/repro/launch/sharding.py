"""Rule-based sharder: assigns every parameter / optimizer / decode-state leaf
a PartitionSpec, CHECKING divisibility (JAX NamedSharding requires even
shards). Falls back down a priority list instead of failing:

Parameters (mode="train" adds FSDP over the data axis = ZeRO-3 via GSPMD;
mode="serve" keeps params TP-only so decode steps pay no per-step gathers):

  1. layer-stack leading dims (scan axes) are never sharded;
  2. expert banks: EP — expert dim over "model" when E % model == 0, with the
     C2 load-aware permutation applied to the expert index at deployment;
     otherwise fall back to feature-dim TP (e.g. granite-moe's E=40);
  3. otherwise TP on the largest dim divisible by the model-axis size
     (column-parallel for projections, vocab-parallel for embeddings);
  4. FSDP (train): the largest REMAINING dim divisible by the data-axis size;
  5. replicate whatever is left (biases, norms, gates).

Optimizer state (m, v) inherits the param spec (ZeRO-1: it is therefore
sharded over BOTH axes wherever the param is).

Decode state: batch over (pod, data); KV-cache sequence dim over "model"
(decode attention is a direct softmax -> GSPMD turns the S-reduction into
all-reduces = TPU flash-decoding); GO cache expert dim over "model" when
divisible.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes

# path components whose immediate child arrays are layer-stacked (scan axes)
STACK1 = {"layers", "encoder", "dec_self", "dec_cross", "cross_layers",
          "slayers"}
STACK2 = {"mlayers"}          # [n_seg, n_m, ...]
VLM_NESTED = {"layers"}       # vlm: layers is [n_sup, n_self, ...] (detected by rank)


def _path_keys(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def _stack_prefix(keys: list, shape, cfg) -> int:
    """How many leading dims are layer-stack (scan) axes."""
    n = 0
    for k in keys:
        if k in STACK2:
            n = 2
            break
        if k in STACK1:
            n = 1
            break
    # vlm / whisper nested stacks: layers under cross_attn_every archs are
    # [n_sup, n_self, ...]
    if n == 1 and cfg is not None and getattr(cfg, "cross_attn_every", 0) > 0 \
            and keys and keys[0] == "layers":
        n = 2
    return n


def param_spec(path, leaf, cfg, mesh, mode: str = "train") -> P:
    keys = _path_keys(path)
    shape = leaf.shape
    rank = len(shape)
    M = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    # FSDP only over the intra-pod data axis (pod axis does pure gradient
    # all-reduce — hierarchical DP keeps param all-gathers off the pod links)
    D = axis_size(mesh, "data") if mode == "train" else 1

    if rank == 0 or min(shape) == 0:
        return P()
    pre = _stack_prefix(keys, shape, cfg)
    dims = list(range(pre, rank))
    if not dims:
        return P()
    spec = [None] * rank

    if mode.endswith("_dp"):
        # pure-DP policy (§Perf knob): the model axis becomes an extra FSDP
        # axis; no tensor parallelism anywhere (odd-head archs / small models)
        DM = D * M
        for i in sorted(dims, key=lambda i: -shape[i]):
            if DM > 1 and shape[i] % DM == 0 and shape[i] >= DM:
                spec[i] = ("data", "model")
                break
            if D > 1 and shape[i] % D == 0 and shape[i] >= D:
                spec[i] = "data"
                break
        return P(*spec)

    def try_model(order):
        for i in order:
            if M > 1 and shape[i] % M == 0 and shape[i] >= 2 * M:
                spec[i] = "model"
                return True
        return False

    leaf_key = keys[-1] if keys else ""
    is_expert_bank = any(k in ("experts", "shared") for k in keys)
    # Megatron orientation: column-parallel weights shard the OUTPUT dim
    # (activations stay batch-sharded; no gather), row-parallel weights shard
    # the INPUT (contraction) dim (one all-reduce after).
    COL = {"wq", "wk", "wv", "wi", "wg", "up", "in_proj", "w_in", "ff_up",
           "w_if", "lm_head"}
    ROW = {"wo", "down", "out_proj", "ff_down"}

    if is_expert_bank and len(dims) >= 3:
        e_dim = dims[0]
        if M > 1 and shape[e_dim] % M == 0 and shape[e_dim] >= M:
            spec[e_dim] = "model"       # EP: experts across the model axis
        elif leaf_key in ROW:
            try_model(dims[1:-1] or dims[1:])
        else:
            try_model(dims[1:][::-1])   # prefer output (last) dim
    elif leaf_key == "embed":
        try_model([dims[0]]) or try_model(dims[1:])     # vocab-parallel
    elif leaf_key in COL:
        try_model(dims[::-1])           # output dim first
    elif leaf_key in ROW:
        try_model(dims)                 # input (contraction) dim first
    else:
        try_model(sorted(dims, key=lambda i: -shape[i]))

    if D > 1:
        rem = [i for i in dims if spec[i] is None]
        for i in sorted(rem, key=lambda i: -shape[i]):
            if shape[i] % D == 0 and shape[i] >= 2 * D and shape[i] >= 1024:
                spec[i] = "data"
                break
    return P(*spec)


def param_shardings(shapes: dict, cfg, mesh, mode: str = "train"):
    """Pytree of ShapeDtypeStructs -> matching pytree of NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_spec(p, x, cfg, mesh, mode)),
        shapes)


def opt_shardings(opt_shapes, p_shardings):
    """AdamW m/v inherit the param spec (ZeRO-1); step counter replicated."""
    flat_p = jax.tree.leaves(p_shardings)
    mesh = flat_p[0].mesh

    def inherit(tree):
        # m / v have the same tree structure as params
        return jax.tree.map(
            lambda s: s, p_shardings)

    import repro.optim.adamw as A
    return A.AdamWState(
        step=NamedSharding(mesh, P()),
        m=inherit(opt_shapes.m),
        v=inherit(opt_shapes.v),
    )


# ----------------------------------------------------------- decode state

def _maybe(mesh, axis, size) -> str | None:
    if isinstance(axis, tuple):
        n = int(np.prod([axis_size(mesh, a) for a in axis]))
        axis_out = axis
    else:
        n = axis_size(mesh, axis)
        axis_out = axis
    return axis_out if (n > 1 and size % n == 0 and size >= n) else None


def state_spec(path, leaf, cfg, mesh, batch: int) -> P:
    keys = _path_keys(path)
    shape = leaf.shape
    dp = dp_axes(mesh)
    k0 = keys[0] if keys else ""

    if k0 == "t" or len(shape) == 0:
        return P()
    if k0 == "block_table":
        # tiny int32 gather indices — every replica needs every slot's page
        # map (the decode gather may touch pages living on any replica)
        return P()
    if k0 in ("k_pages", "v_pages"):              # [L, NP, ps, h, hd]
        from repro.kernels.paged_attn import resolve_mode
        if resolve_mode(cfg) == "kernel":
            # Pallas paged-attention kernel: each grid step stages one WHOLE
            # page into VMEM, so the page interior must stay contiguous —
            # pages over the data-parallel axes, kv heads over "model" (the
            # Megatron head split the kernel's GQA grouping preserves)
            return P(None, _maybe(mesh, dp, shape[1]), None,
                     _maybe(mesh, "model", shape[3]), None)
        # gather path: physical pages over the data-parallel axes, the page
        # interior over "model" (the same S-dim flash-decoding split as the
        # dense rule, one page at a time)
        return P(None, _maybe(mesh, dp, shape[1]),
                 _maybe(mesh, "model", shape[2]), None, None)
    if k0 in ("k_scales", "v_scales"):            # [L, NP, Hkv]
        # per-page dequant scales co-locate with their pages (page dim over
        # the data-parallel axes); the tiny kv-head dim stays replicated —
        # both the kernel (scalar-prefetch BlockSpec) and the gather stage
        # whole [Hkv] scale rows per page
        return P(None, _maybe(mesh, dp, shape[1]), None)
    if k0 == "go_scales":                         # [L, B, E, k]
        # one scale per cached GO row — follows the go scores/token rule
        return P(None, _maybe(mesh, dp, shape[1]),
                 _maybe(mesh, "model", shape[2]), None)
    if k0 in ("k", "v"):
        if len(shape) == 5:                       # [L, B, S, h, hd]
            return P(None, _maybe(mesh, dp, shape[1]),
                     _maybe(mesh, "model", shape[2]), None, None)
        if len(shape) == 6:                       # vlm [n_sup, n_self, B, S, h, hd]
            return P(None, None, _maybe(mesh, dp, shape[2]),
                     _maybe(mesh, "model", shape[3]), None, None)
    if k0 == "memory":                            # [B, I, d]
        return P(_maybe(mesh, dp, shape[0]), None,
                 _maybe(mesh, "model", shape[2]))
    if k0 == "go":
        if len(shape) == 4:                       # scores/tok [L, B, E, k]
            return P(None, _maybe(mesh, dp, shape[1]),
                     _maybe(mesh, "model", shape[2]), None)
        if len(shape) == 5:                       # outputs [L, B, E, k, d]
            return P(None, _maybe(mesh, dp, shape[1]),
                     _maybe(mesh, "model", shape[2]), None, None)
    if k0 == "ssm":
        if len(shape) == 5:                       # [L, B, h, p, n]
            return P(None, _maybe(mesh, dp, shape[1]),
                     _maybe(mesh, "model", shape[2]), None, None)
        if len(shape) == 4:                       # conv [L, B, K-1, C]
            return P(None, _maybe(mesh, dp, shape[1]), None,
                     _maybe(mesh, "model", shape[3]))
    if k0 in ("mlstm", "slstm"):
        spec = [None] * len(shape)
        # find the batch dim (first dim equal to `batch` after stack dims)
        for i, s in enumerate(shape):
            if s == batch and i >= 1:
                spec[i] = _maybe(mesh, dp, s)
                break
        # largest trailing dim onto model
        for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
            if spec[i] is None and i >= 2 and \
                    _maybe(mesh, "model", shape[i]) and shape[i] >= 256:
                spec[i] = "model"
                break
        return P(*spec)
    # fallback: replicate
    return P()


def state_shardings(state_shapes, cfg, mesh, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(
            mesh, state_spec(p, x, cfg, mesh, batch)), state_shapes)


def serve_state_shardings(cfg, mesh, num_slots: int, max_tokens: int,
                          extras: dict | None = None, paged=None):
    """NamedShardings for the serving engine's pooled decode state: slot rows
    over the data-parallel axes, KV sequence / GO expert dims over "model"
    (the same rules `state_spec` applies to the static-batch decode state —
    the pool IS that state with the batch dim reinterpreted as slots).
    `paged=(num_pages, page_size)` lays out the paged pool instead: page dim
    over data-parallel, page interior over "model", block tables
    replicated."""
    from repro.models.model import init_decode_state
    shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, num_slots, max_tokens, extras or {},
                                  per_slot_t=True, paged=paged))
    return state_shardings(shapes, cfg, mesh, num_slots)


def batch_shardings(batch_shapes, mesh, policy: str = "tp"):
    """Training batch: leading (microbatch) dim replicated, batch dim over DP
    (plus the model axis under the pure-DP policy)."""
    dp = dp_axes(mesh)
    if policy == "dp_only":
        dp = dp + ("model",)

    def spec(x):
        b = x.shape[1] if x.ndim >= 3 else x.shape[0]
        n = 1
        axes = []
        for a in dp:
            if b % (n * mesh.shape[a]) == 0:
                axes.append(a)
                n *= mesh.shape[a]
        axes = tuple(axes) or None
        if x.ndim >= 3:                           # [n_micro, B, S(, d)]
            return P(None, axes, *([None] * (x.ndim - 2)))
        return P(axes, *([None] * (x.ndim - 1)))
    return jax.tree.map(lambda x: NamedSharding(mesh, spec(x)), batch_shapes)
