"""End-to-end training driver (CPU-runnable for smoke configs; the same loop
lowers to the production mesh via launch/sharding.py).

Fault-tolerance loop:
  * StepSupervisor retries transient step failures and flags stragglers;
  * committed checkpoints every --ckpt-every steps (async writer);
  * on RestartRequired the driver restores the latest committed step and
    continues — bit-exact, because the data pipeline is seekable;
  * on device-count change (elastic), runtime/elastic.remesh_plan picks a new
    mesh and the checkpoint is resharded onto it.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama_moe_4_16 --smoke \
      --steps 50 --seq-len 256 --global-batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import loss_fn, model_init
from repro.optim.adamw import (accumulate_grads, adamw_init, adamw_update,
                               cosine_lr)
from repro.runtime.fault import RestartRequired, StepSupervisor


def make_step(cfg, tc: TrainConfig):
    def train_step(params, opt_state, batch):
        if batch["tokens"].ndim == 3:          # [n_micro, B, S]
            grads, loss = accumulate_grads(loss_fn, params, batch, cfg)
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg)
        lr = cosine_lr(opt_state.step, base_lr=tc.lr, warmup=tc.warmup_steps,
                       total=tc.steps)
        params, opt_state, m = adamw_update(
            params, grads, opt_state, lr=lr,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
        m["loss"] = loss
        return params, opt_state, m

    return jax.jit(train_step, donate_argnums=(0, 1))


def run(cfg, tc: TrainConfig, *, resume: bool = True, log=print) -> dict:
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                      global_batch=tc.global_batch, seed=tc.seed)
    corpus = SyntheticCorpus(dcfg)

    key = jax.random.PRNGKey(tc.seed)
    params = model_init(key, cfg)
    opt_state = adamw_init(params)
    start = 0
    if resume:
        latest = ckpt.latest_step(tc.ckpt_dir)
        if latest is not None:
            params = ckpt.restore(tc.ckpt_dir, latest, params)
            opt_state = ckpt.restore(
                tc.ckpt_dir + "/opt", latest, opt_state)
            start = latest
            log(f"resumed from step {start}")

    step_fn = make_step(cfg, tc)
    sup = StepSupervisor()
    writer = None
    losses = []
    t0 = time.time()
    step = start
    while step < tc.steps:
        try:
            micro = tc.microbatch
            batch = corpus.batch(step)
            if micro and tc.global_batch % micro == 0 and micro < tc.global_batch:
                n = tc.global_batch // micro
                batch = {k: v.reshape(n, micro, *v.shape[1:])
                         for k, v in batch.items()}
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, m = sup.run(
                step_fn, params, opt_state, batch, step=step)
            losses.append(float(m["loss"]))
            if step % tc.log_every == 0:
                log(f"step {step:5d} loss {losses[-1]:.4f} "
                    f"gnorm {float(m['grad_norm']):.3f} "
                    f"({time.time()-t0:.1f}s)")
            if tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
                if writer is not None:
                    writer.wait()
                ckpt.save(tc.ckpt_dir, step + 1, params, async_=False)
                writer = ckpt.save(tc.ckpt_dir + "/opt", step + 1,
                                   opt_state, async_=True)
            step += 1
        except RestartRequired as e:
            log(f"RESTART at step {step}: {e}")
            latest = ckpt.latest_step(tc.ckpt_dir)
            if latest is None:
                raise
            params = ckpt.restore(tc.ckpt_dir, latest, params)
            opt_state = ckpt.restore(tc.ckpt_dir + "/opt", latest, opt_state)
            step = latest
    if writer is not None:
        writer.wait()
    return {"losses": losses, "steps": step - start,
            "stragglers": sup.stats.stragglers, "retries": sup.stats.retries}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(steps=args.steps, seq_len=args.seq_len,
                     global_batch=args.global_batch, lr=args.lr,
                     microbatch=args.microbatch, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
    out = run(cfg, tc, resume=not args.fresh)
    print(f"final loss {out['losses'][-1]:.4f} over {out['steps']} steps")


if __name__ == "__main__":
    main()
