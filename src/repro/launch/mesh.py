"""Production mesh definitions.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(model: int = 1):
    """Single-host mesh over however many devices exist (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
