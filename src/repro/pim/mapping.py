"""Deployment-time mapping: experts -> crossbars -> multiplexing groups.

Mirrors paper §III.A/B: each expert occupies `crossbars_per_expert` HERMES
cores (Llama-MoE-4/16: 96 -> 1536 total); groups of `group_size` experts share
one peripheral set. Grouping is uniform (U) or workload-sorted (S, C2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.grouping import (group_loads, imbalance, sorted_grouping,
                                 uniform_grouping)
from repro.pim.hermes import MoEModelSpec, PimSpec, moe_area_mm2


@dataclass(frozen=True)
class Mapping:
    groups: np.ndarray           # [G, g] expert ids sharing one peripheral set
    group_of_expert: np.ndarray  # [E]
    area_mm2: float
    n_crossbars: int

    @property
    def group_size(self) -> int:
        return self.groups.shape[1]


def build_mapping(model: MoEModelSpec, spec: PimSpec, group_size: int,
                  grouping: str, loads: np.ndarray | None = None,
                  seed: int = 0) -> Mapping:
    E = model.num_experts
    if group_size <= 1:
        groups = np.arange(E)[:, None]
    elif grouping == "uniform":
        groups = uniform_grouping(E, group_size, seed=seed)
    elif grouping == "sorted":
        assert loads is not None, "sorted grouping needs a traced workload"
        groups = sorted_grouping(loads, group_size)
    else:
        raise ValueError(grouping)
    goe = np.empty(E, np.int64)
    for gid, members in enumerate(groups):
        goe[members] = gid
    return Mapping(
        groups=groups,
        group_of_expert=goe,
        area_mm2=moe_area_mm2(model, spec, group_size),
        n_crossbars=model.total_crossbars(spec),
    )


def mapping_stats(m: Mapping, loads: np.ndarray) -> dict:
    gl = group_loads(loads, m.groups)
    return {
        "group_loads": gl.tolist(),
        "imbalance": imbalance(gl),
        "area_mm2": m.area_mm2,
    }
