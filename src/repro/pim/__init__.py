# C5 — the paper's operator-accurate PIM evaluation substrate (pure Python).
