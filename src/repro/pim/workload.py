"""Workload traces: token-expert gate affinities and choices.

The paper samples traces from RedPajama C4 through Llama-MoE-4/16's gates.
Offline we synthesize gate affinities with the empirically-typical structure:
a per-expert popularity skew (Zipf-like — the source of load imbalance that
C2 grouping targets) plus per-token noise. Real traces can be dropped in as
an .npy of logits [T, E]; every consumer only sees the (scores, choices)
interface.
"""
from __future__ import annotations

import numpy as np


def synth_gate_scores(num_tokens: int, num_experts: int, seed: int = 0,
                      skew: float = 0.5) -> np.ndarray:
    """Affinity logits [T, E]: expert popularity ~ Zipf(skew) + token noise."""
    rng = np.random.default_rng(seed)
    pop = 1.0 / np.arange(1, num_experts + 1) ** skew
    pop = np.log(pop / pop.sum())
    pop = rng.permutation(pop)                   # popularity unordered
    noise = rng.gumbel(0, 1.0, size=(num_tokens, num_experts))
    return pop[None, :] + noise


def softmax(x: np.ndarray, axis: int = -1) -> np.ndarray:
    m = x.max(axis=axis, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=axis, keepdims=True)


def expert_choice_matrix(scores: np.ndarray, capacity: int) -> np.ndarray:
    """Expert-choice routing: bool [T, E]; each expert takes its top-`capacity`
    tokens by softmax-over-experts affinity."""
    g = softmax(scores, axis=1)
    T, E = g.shape
    choices = np.zeros((T, E), bool)
    cap = min(capacity, T)
    for e in range(E):
        top = np.argsort(-g[:, e])[:cap]
        choices[top, e] = True
    return choices


def token_choice_matrix(scores: np.ndarray, k: int) -> np.ndarray:
    """Token-choice routing: bool [T, E]; each token picks its top-k experts."""
    T, E = scores.shape
    choices = np.zeros((T, E), bool)
    for t in range(T):
        choices[t, np.argsort(-scores[t])[:k]] = True
    return choices


def load_per_expert(choices: np.ndarray) -> np.ndarray:
    return choices.sum(axis=0).astype(np.float64)


class GenTrace:
    """Incremental expert-choice during generation with a k-slot score cache
    (paper eq. 4-5): yields per-step selected-expert counts."""

    def __init__(self, prefill_scores: np.ndarray, k: int, seed: int = 1,
                 skew: float = 0.5):
        T, E = prefill_scores.shape
        g = softmax(prefill_scores, axis=1)
        self.k = min(k, T)
        # cache: top-k affinities per expert
        self.cache = np.sort(g, axis=0)[::-1][:self.k, :]      # [k, E]
        self.E = E
        self.rng = np.random.default_rng(seed)
        pop = 1.0 / np.arange(1, E + 1) ** skew
        self.pop = np.log(pop / pop.sum())
        self.pop = np.random.default_rng(seed + 1).permutation(self.pop)

    def step(self) -> np.ndarray:
        """Returns bool [E]: which experts select the incoming token."""
        logits = self.pop + self.rng.gumbel(0, 1.0, size=self.E)
        g = softmax(logits[None, :], axis=1)[0]
        mins = self.cache.min(axis=0)
        sel = g >= mins
        slot = self.cache.argmin(axis=0)
        upd = self.cache[slot, np.arange(self.E)]
        new = np.where(sel, g, upd)
        self.cache[slot, np.arange(self.E)] = new
        return sel
