"""HERMES PIM chip specification + 3DCIM-style system constants.

Printed HERMES numbers (paper §IV.A): 256x256 crossbar, 8-bit I/O, one core
activation = 130 ns; core area 0.635 mm²; crossbar array = 40% of core area
(so shared peripherals are the remaining 60%, >60% of which is ADCs).
Core activation energy follows the HERMES JSSC energy efficiency
(~10.5 TOPS/W at 2 x 256 x 256 OPS / 130 ns -> ~0.096 W per active core,
matching the paper's printed "0.096" figure): 0.096 W x 130 ns = 12.48 nJ.

Digital-unit and DRAM constants are FIT to the paper's Table I anchors
(baseline and S2O+KVGO totals), exactly as the paper fits "polynomial
functions as in [7]" for the non-PIM components — see simulator.calibrate().
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class PimSpec:
    # --- HERMES core (printed values) ---
    xbar: int = 256                # crossbar rows = cols
    io_bits: int = 8
    t_core_ns: float = 130.0       # latency of one core activation
    p_core_w: float = 0.096        # power while active -> e_core = p*t
    area_core_mm2: float = 0.635
    xbar_area_frac: float = 0.40   # paper §IV.B: "crossbar area accounts for 40%"

    # --- digital unit (attention, gate, softmax) — calibrated ---
    # Cost of one invocation: t_dig_call_ns + ops / dig_ops_per_s (the
    # polynomial-fit form of 3DCIM's digital components: a per-call latency
    # floor plus a throughput term). Energy has no floor.
    dig_ops_per_s: float = 6.62262e13
    t_dig_call_ns: float = 6.59128e4
    dig_j_per_op: float = 1.34658e-13

    # --- off-chip DRAM (KV + GO caches, retained hidden states) — calibrated ---
    dram_gbps: float = 1.77306     # GB/s effective (critical-path)
    dram_j_per_byte: float = 8.01875e-11

    @property
    def e_core_nj(self) -> float:
        return self.p_core_w * self.t_core_ns  # (W x ns) = nJ

    def with_(self, **kw) -> "PimSpec":
        return replace(self, **kw)


@dataclass(frozen=True)
class MoEModelSpec:
    """Llama-MoE-4/16 (paper target): one transformer block."""
    d_model: int = 4096
    d_expert: int = 688            # 11008 / 16
    num_experts: int = 16
    top_k: int = 4
    num_heads: int = 32
    n_matrices: int = 2            # up + down (paper's 1536-crossbar count)

    def crossbars_per_expert(self, spec: PimSpec) -> int:
        import math
        rows = math.ceil(self.d_model / spec.xbar)
        cols = math.ceil(self.d_expert / spec.xbar)
        return self.n_matrices * rows * cols   # up [d,de] + down [de,d]

    def total_crossbars(self, spec: PimSpec) -> int:
        return self.num_experts * self.crossbars_per_expert(spec)

    def pair_ops(self) -> int:
        """MAC ops (x2) for one (token, expert) pass: up + down."""
        return 2 * self.n_matrices * self.d_model * self.d_expert

    def pair_latency_ns(self, spec: PimSpec) -> float:
        """Up stage then down stage; crossbars within a stage in parallel."""
        return self.n_matrices * spec.t_core_ns

    def pair_energy_nj(self, spec: PimSpec) -> float:
        return self.crossbars_per_expert(spec) * spec.e_core_nj


HERMES = PimSpec()
LLAMA_MOE_4_16 = MoEModelSpec()


def moe_area_mm2(model: MoEModelSpec, spec: PimSpec, group_size: int) -> float:
    """C1: crossbars keep their array area; peripherals are shared g-ways.
    2D layout for both ours and the baseline (paper §IV.A)."""
    n = model.total_crossbars(spec)
    frac = spec.xbar_area_frac + (1.0 - spec.xbar_area_frac) / max(1, group_size)
    return n * spec.area_core_mm2 * frac
