"""Operator-level PIM simulator (C5) — rebuilt from 3DCIM per paper §IV.A.

Cost model. Every simulated inference accumulates four buckets:

  pim_cycles      (token, expert) passes through crossbar groups; one cycle =
                  model.pair_latency_ns (up stage + down stage). Structural
                  contention (C1 sharing) enters via the schedule makespan.
  pim_transfers   operand transfers to a group's peripheral (Algorithm 1
                  minimizes these); pipelined -> energy only.
  dig_ops         digital-unit ops: attention projections/scores, gate.
  dram_bytes      off-chip traffic: KV cache, GO cache, retained hiddens.

latency = pim_ns + dig_ops / dig_ops_per_s + dram_bytes / dram_bw
energy  = pim_nJ + xfer_nJ + dig_ops * dig_j_per_op + dram_bytes * j_per_byte

The digital/DRAM constants are calibrated once against the paper's two
Table I anchors (`calibrate()`), the same way the paper fits the non-PIM
components of 3DCIM with polynomial functions; the PIM constants are the
printed HERMES values. All reported comparisons are then *ratios produced by
the simulator*, not fitted individually.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.scheduling import SCHEDULES
from repro.pim.hermes import HERMES, LLAMA_MOE_4_16, MoEModelSpec, PimSpec
from repro.pim.mapping import Mapping, build_mapping
from repro.pim import workload as W

XFER_NJ_PER_BYTE = 0.0005     # on-chip operand bus (~0.5 pJ/B)


@dataclass(frozen=True)
class SimConfig:
    group_size: int = 1
    grouping: str = "uniform"        # "uniform" | "sorted"
    schedule: str = "token_wise"     # "token_wise" | "compact" | "reschedule"
    kv_cache: bool = False
    go_cache: bool = False
    prompt: int = 32
    gen: int = 8
    seed: int = 0
    routing: str = "expert_choice"   # "expert_choice" | "token_choice"
    # expert-choice prefill is balanced by construction; the paper's Fig. 2/5
    # grouping+scheduling study exercises the unbalanced (token-choice) case

    def tag(self) -> str:
        g = {"uniform": "U", "sorted": "S"}[self.grouping]
        s = {"token_wise": "T", "compact": "C", "reschedule": "O"}[self.schedule]
        c = ("KV" if self.kv_cache else "") + ("GO" if self.go_cache else "")
        return f"{g}{self.group_size}{s}" + (f"+{c}" if c else "")


@dataclass
class Buckets:
    pim_cycles: int = 0
    pim_pairs: int = 0
    pim_transfers: int = 0
    dig_ops: float = 0.0
    dig_calls: int = 0
    dram_bytes: float = 0.0
    dram_bytes_crit: float = 0.0
    useful_ops: float = 0.0
    phase: dict = field(default_factory=dict)

    def add(self, other: "Buckets"):
        self.pim_cycles += other.pim_cycles
        self.pim_pairs += other.pim_pairs
        self.pim_transfers += other.pim_transfers
        self.dig_ops += other.dig_ops
        self.dig_calls += other.dig_calls
        self.dram_bytes += other.dram_bytes
        self.dram_bytes_crit += other.dram_bytes_crit
        self.useful_ops += other.useful_ops


@dataclass
class SimResult:
    latency_ns: float
    energy_nj: float
    area_mm2: float
    gops_per_mm2: float
    density: float                  # GOPS / W / mm²
    buckets: Buckets
    breakdown: dict
    # "MoE part" view — what the paper's Fig. 5 / 2.2x claim measures:
    # only the PIM linear cores (latency = schedule makespan, energy = pairs
    # + operand transfers, area = MoE crossbars + shared peripherals)
    moe_latency_ns: float = 0.0
    moe_energy_nj: float = 0.0
    moe_gops_per_mm2: float = 0.0
    moe_density: float = 0.0


# ------------------------------------------------------------- cost helpers

def _attn_proj_ops(tokens: int, d: int) -> float:
    return tokens * 4 * 2 * d * d                 # Q, K, V, O

def _attn_score_ops(q_tokens: int, ctx: int, d: int) -> float:
    return q_tokens * ctx * 2 * 2 * d             # QK^T + PV

def _gate_ops(tokens: int, d: int, E: int) -> float:
    return tokens * 2 * d * E


def _schedule_moe(choices: np.ndarray, mapping: Mapping, schedule: str):
    sched = SCHEDULES[schedule](choices, mapping.groups)
    return sched.makespan, sched.transfers


# ------------------------------------------------------------------ simulate

def simulate(cfg: SimConfig, model: MoEModelSpec = LLAMA_MOE_4_16,
             spec: PimSpec = HERMES) -> SimResult:
    d, E, k = model.d_model, model.num_experts, model.top_k
    T = cfg.prompt

    # --- workload + deployment-time mapping (C2 uses a traced sample) ---
    scores = W.synth_gate_scores(T, E, seed=cfg.seed)
    cap = max(1, (T * k) // E)
    if cfg.routing == "expert_choice":
        choices = W.expert_choice_matrix(scores, cap)
    else:
        choices = W.token_choice_matrix(scores, k)
    trace_scores = W.synth_gate_scores(256, E, seed=cfg.seed + 7)
    if cfg.routing == "expert_choice":
        trace_choices = W.expert_choice_matrix(trace_scores, max(1, 256 * k // E))
    else:
        trace_choices = W.token_choice_matrix(trace_scores, k)
    trace_loads = W.load_per_expert(trace_choices)
    mapping = build_mapping(model, spec, cfg.group_size, cfg.grouping,
                            loads=trace_loads, seed=cfg.seed)

    total = Buckets()

    # ---------------------------------------------------------- prefill
    pre = Buckets()
    mk, tr = _schedule_moe(choices, mapping, cfg.schedule)
    pre.pim_cycles += mk
    pre.pim_pairs += int(choices.sum())
    pre.pim_transfers += tr
    pre.dig_calls += 1                           # one batched attention pass
    pre.dig_ops += _attn_proj_ops(T, d)
    pre.dig_ops += sum(_attn_score_ops(1, t + 1, d) for t in range(T))
    pre.dig_ops += _gate_ops(T, d, E)
    if cfg.kv_cache:
        pre.dram_bytes += 2 * T * d              # write K,V (8-bit I/O)
    if cfg.go_cache:
        pre.dram_bytes += T * E * 2              # score cache write
        pre.dram_bytes += k * E * d * 2          # output cache init (512 KB)
    if not (cfg.kv_cache and cfg.go_cache):
        pre.dram_bytes += T * d                  # retain hidden states
    total.add(pre)

    # --------------------------------------------------------- generate
    gen = Buckets()
    gtrace = W.GenTrace(scores, k, seed=cfg.seed + 1)
    for t in range(1, cfg.gen + 1):
        S = T + t
        # attention: one digital call per step; without the KV cache the
        # call additionally re-projects K,V from the retained hidden states
        gen.dig_calls += 1
        gen.dig_ops += _attn_proj_ops(1, d) + _attn_score_ops(1, S, d)
        if cfg.kv_cache:
            # streamed alongside the score computation -> energy only
            gen.dram_bytes += 2 * S * d          # read cached K,V
            gen.dram_bytes += 2 * d              # append
        else:
            gen.dig_ops += (S - 1) * 2 * 2 * d * d
            gen.dram_bytes += S * d              # re-read retained hiddens
            gen.dram_bytes_crit += S * d         # blocks the K,V re-projection
        # gate + MoE
        if cfg.go_cache:
            gen.dig_ops += _gate_ops(1, d, E)
            sel = gtrace.step()                  # [E] bool
            n_sel = int(sel.sum())
            per_group = np.bincount(
                mapping.group_of_expert[sel], minlength=len(mapping.groups))
            gen.pim_cycles += int(per_group.max()) if n_sel else 0
            gen.pim_pairs += n_sel
            gen.pim_transfers += int((per_group > 0).sum())
            gen.dram_bytes += E * 2              # score append (32 B)
            gen.dram_bytes += n_sel * d * 2      # output-cache update
            gen.dram_bytes += k * d * 2          # compose y from cache
        else:
            gen.dig_ops += _gate_ops(S, d, E)
            gen.dram_bytes_crit += S * d         # gate/experts wait on hiddens
            sc = np.concatenate(
                [scores, W.synth_gate_scores(t, E, seed=cfg.seed + 100 + t)])
            if cfg.routing == "expert_choice":
                ch = W.expert_choice_matrix(sc, max(1, (S * k) // E))
            else:
                ch = W.token_choice_matrix(sc[-1:], k) if cfg.kv_cache else \
                    W.token_choice_matrix(sc, k)
            mk, tr = _schedule_moe(ch, mapping, cfg.schedule)
            gen.pim_cycles += mk
            gen.pim_pairs += int(ch.sum())
            gen.pim_transfers += tr
            gen.dram_bytes += S * d              # hidden states to experts
    total.add(gen)

    total.useful_ops = total.dig_ops + total.pim_pairs * model.pair_ops()
    total.phase = {"prefill": pre, "generate": gen}
    return _finalize(total, mapping, model, spec)


def _finalize(b: Buckets, mapping: Mapping, model: MoEModelSpec,
              spec: PimSpec) -> SimResult:
    pim_ns = b.pim_cycles * model.pair_latency_ns(spec)
    dig_ns = (b.dig_ops / spec.dig_ops_per_s * 1e9
              + b.dig_calls * spec.t_dig_call_ns)
    dram_ns = b.dram_bytes_crit / (spec.dram_gbps * 1e9) * 1e9
    lat = pim_ns + dig_ns + dram_ns

    pim_nj = b.pim_pairs * model.pair_energy_nj(spec)
    xfer_nj = b.pim_transfers * model.d_model * XFER_NJ_PER_BYTE
    dig_nj = b.dig_ops * spec.dig_j_per_op * 1e9
    dram_nj = b.dram_bytes * spec.dram_j_per_byte * 1e9
    en = pim_nj + xfer_nj + dig_nj + dram_nj

    area = mapping.area_mm2
    gops_mm2 = b.useful_ops / lat / area          # ops/ns = GOPS
    density = b.useful_ops / (en * 1e-9) / 1e9 / area
    moe_ops = b.pim_pairs * model.pair_ops()
    moe_lat = max(pim_ns, 1e-9)
    moe_en = max(pim_nj + xfer_nj, 1e-9)
    return SimResult(
        latency_ns=lat, energy_nj=en, area_mm2=area,
        gops_per_mm2=gops_mm2, density=density, buckets=b,
        breakdown={
            "latency_ns": {"pim": pim_ns, "digital": dig_ns, "dram": dram_ns},
            "energy_nj": {"pim": pim_nj, "xfer": xfer_nj, "digital": dig_nj,
                          "dram": dram_nj},
        },
        moe_latency_ns=moe_lat,
        moe_energy_nj=moe_en,
        moe_gops_per_mm2=moe_ops / moe_lat / area,
        moe_density=moe_ops / (moe_en * 1e-9) / 1e9 / area,
    )


# ----------------------------------------------------------------- calibrate

BASELINE = SimConfig()                                       # no cache, no sched
S2O_KVGO = SimConfig(group_size=2, grouping="sorted", schedule="reschedule",
                     kv_cache=True, go_cache=True)
S4O_KVGO = SimConfig(group_size=4, grouping="sorted", schedule="reschedule",
                     kv_cache=True, go_cache=True)

TABLE1_ANCHORS = {
    "baseline": {"latency_ns": 2_297_724.0, "energy_nj": 5_393_776.0},
    "s2o_kvgo": {"latency_ns": 717_752.0, "energy_nj": 1_096_691.0},
}


FIG4_TARGETS = {
    # generation-phase ratios read off the paper's Fig. 4 / §IV.B text
    "lat_base_over_kvgo_8": 4.2,
    "lat_kv_over_kvgo_8": 2.7,
    "lat_base_over_kvgo_64": 6.7,
    "en_base_over_kvgo_8": 10.1,
    "en_base_over_kvgo_64": 14.1,
}


def _phase_lin(b: Buckets, model: MoEModelSpec, spec: PimSpec):
    """(pim_ns, pim_nj) of one phase — the fixed (non-calibrated) part."""
    pim_ns = b.pim_cycles * model.pair_latency_ns(spec)
    pim_nj = (b.pim_pairs * model.pair_energy_nj(spec)
              + b.pim_transfers * model.d_model * XFER_NJ_PER_BYTE)
    return pim_ns, pim_nj


def calibrate(model: MoEModelSpec = LLAMA_MOE_4_16,
              spec: PimSpec = HERMES,
              anchor_weight: float = 4.0) -> PimSpec:
    """Fit the four non-PIM constants (digital ops/s & J/op, DRAM B/s & J/B)
    to the paper's published numbers: the two Table I anchors (weight 4) and
    the Fig. 4 generation-phase ratios (weight 1), by weighted least squares
    on log-space residuals over a 2-D grid per (latency, energy) pair.
    Latency depends only on (dig_ops_per_s, dram_gbps) and energy only on
    (dig_j_per_op, dram_j_per_byte), so the two fits are independent. The PIM
    bucket uses the printed HERMES constants and is held fixed — this mirrors
    the paper, which fits the non-PIM components of 3DCIM with polynomials."""
    import dataclasses

    def buckets_of(cfg):
        return simulate(cfg, model, spec).buckets

    b_base = buckets_of(BASELINE)
    b_s2o = buckets_of(S2O_KVGO)
    g8 = {k: buckets_of(dataclasses.replace(BASELINE, gen=8, **kw)).phase["generate"]
          for k, kw in [("base", {}), ("kv", {"kv_cache": True}),
                        ("kvgo", {"kv_cache": True, "go_cache": True})]}
    g64 = {k: buckets_of(dataclasses.replace(BASELINE, gen=64, **kw)).phase["generate"]
           for k, kw in [("base", {}), ("kvgo", {"kv_cache": True, "go_cache": True})]}

    def lat(b, th):     # th = (t_fix ns/call, u ns/op, v ns/byte)
        return (_phase_lin(b, model, spec)[0] + b.dig_calls * th[0]
                + b.dig_ops * th[1] + b.dram_bytes_crit * th[2])

    def en(b, th):      # th = (u nJ/op, v nJ/byte)
        return (_phase_lin(b, model, spec)[1]
                + b.dig_ops * th[0] + b.dram_bytes * th[1])

    def fit(measure, targets, th0):
        best, best_th = np.inf, np.asarray(th0, float)
        for scale in (2.0, 0.7, 0.2, 0.06):
            center = best_th.copy()
            grids = [c * np.logspace(-scale, scale, 14) for c in center]
            import itertools
            for th in itertools.product(*grids):
                err = 0.0
                for w, pred, tgt in targets(measure, th):
                    err += w * np.log(max(pred, 1e-12) / tgt) ** 2
                if err < best:
                    best, best_th = err, np.asarray(th)
        return best_th

    def lat_targets(measure, th):
        yield (anchor_weight, measure(b_base, th),
               TABLE1_ANCHORS["baseline"]["latency_ns"])
        yield (anchor_weight, measure(b_s2o, th),
               TABLE1_ANCHORS["s2o_kvgo"]["latency_ns"])
        yield (1.0, measure(g8["base"], th) / measure(g8["kvgo"], th),
               FIG4_TARGETS["lat_base_over_kvgo_8"])
        yield (1.0, measure(g8["kv"], th) / measure(g8["kvgo"], th),
               FIG4_TARGETS["lat_kv_over_kvgo_8"])
        yield (1.0, measure(g64["base"], th) / measure(g64["kvgo"], th),
               FIG4_TARGETS["lat_base_over_kvgo_64"])

    def en_targets(measure, th):
        yield (anchor_weight, measure(b_base, th),
               TABLE1_ANCHORS["baseline"]["energy_nj"])
        yield (anchor_weight, measure(b_s2o, th),
               TABLE1_ANCHORS["s2o_kvgo"]["energy_nj"])
        yield (1.0, measure(g8["base"], th) / measure(g8["kvgo"], th),
               FIG4_TARGETS["en_base_over_kvgo_8"])
        yield (1.0, measure(g64["base"], th) / measure(g64["kvgo"], th),
               FIG4_TARGETS["en_base_over_kvgo_64"])

    tfix, ul, vl = fit(lat, lat_targets, (5e4, 5e-5, 0.05))
    ue, ve = fit(en, en_targets, (1e-4, 0.02))
    return spec.with_(
        t_dig_call_ns=tfix,
        dig_ops_per_s=1e9 / ul,
        dram_gbps=1.0 / vl,
        dig_j_per_op=ue * 1e-9,
        dram_j_per_byte=ve * 1e-9,
    )
