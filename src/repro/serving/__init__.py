"""Continuous-batching serving over the paper's KV + GO cache pool.

  scheduler  priority-heap admission (FIFO within a level) +
             max-slots/max-tokens policy (host-side); ExpertAwareScheduler
             scores admission by routing overlap with the active batch
             (per-expert load EWMAs, Sieve-style)
  paging     host page allocator for the paged KV pool (reservations,
             lazy grow, null page, refcounted copy-on-write sharing) +
             the page-aligned radix PrefixIndex (prompt prefixes -> shared
             physical pages + cached prefill artifacts)
  pool       fixed-width slot pool owning the pooled decode state —
             dense per-slot KV rows or the paged block-table pool
  engine     jitted masked decode step; admit -> prefill (one-shot,
             chunked, or skipped via prefix sharing) -> decode -> retire;
             request-lifecycle fault domain (deadlines, cancel,
             preemption/resume, NaN quarantine)
  chaos      seeded fault injector (REPRO_CHAOS lane) + crash classes
             (REPRO_CRASH lane: SIGKILL / torn journal / uncommitted
             snapshot)
  journal    fsync'd write-ahead journal of request lifecycle events +
             atomic engine snapshots; ServingEngine.recover replays it
             into a bit-identical resume of every live stream
"""
from repro.serving.chaos import Chaos, ChaosError
from repro.serving.engine import ServingEngine
from repro.serving.journal import EngineJournal, JournalError
from repro.serving.paging import PageAllocator, PrefixIndex
from repro.serving.pool import SlotPool
from repro.serving.scheduler import (ExpertAwareScheduler, FIFOScheduler,
                                     QueueFull, Request, RequestStatus,
                                     RequestTooLarge, TERMINAL_STATUSES)

__all__ = ["ServingEngine", "SlotPool", "FIFOScheduler",
           "ExpertAwareScheduler", "Request", "PageAllocator", "PrefixIndex",
           "RequestStatus", "TERMINAL_STATUSES", "QueueFull",
           "RequestTooLarge", "Chaos", "ChaosError", "EngineJournal",
           "JournalError"]
