"""Continuous-batching serving over the paper's KV + GO cache pool.

  scheduler  priority-heap admission (FIFO within a level) +
             max-slots/max-tokens policy (host-side)
  paging     host page allocator for the paged KV pool (reservations,
             lazy grow, null page)
  pool       fixed-width slot pool owning the pooled decode state —
             dense per-slot KV rows or the paged block-table pool
  engine     jitted masked decode step; admit -> prefill (one-shot or
             chunked) -> decode -> retire; request-lifecycle fault domain
             (deadlines, cancel, preemption/resume, NaN quarantine)
  chaos      seeded fault injector (REPRO_CHAOS lane)
"""
from repro.serving.chaos import Chaos, ChaosError
from repro.serving.engine import ServingEngine
from repro.serving.paging import PageAllocator
from repro.serving.pool import SlotPool
from repro.serving.scheduler import (FIFOScheduler, QueueFull, Request,
                                     RequestStatus, RequestTooLarge,
                                     TERMINAL_STATUSES)

__all__ = ["ServingEngine", "SlotPool", "FIFOScheduler", "Request",
           "PageAllocator", "RequestStatus", "TERMINAL_STATUSES",
           "QueueFull", "RequestTooLarge", "Chaos", "ChaosError"]
