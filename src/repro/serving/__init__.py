"""Continuous-batching serving over the paper's KV + GO cache pool.

  scheduler  FIFO admission queue + max-slots/max-tokens policy (host-side)
  pool       fixed-width slot pool owning the pooled decode state
  engine     jitted masked decode step; admit -> prefill -> decode -> retire
"""
from repro.serving.engine import ServingEngine
from repro.serving.pool import SlotPool
from repro.serving.scheduler import FIFOScheduler, Request

__all__ = ["ServingEngine", "SlotPool", "FIFOScheduler", "Request"]
