"""Seeded fault injection for the serving engine (the chaos lane).

The engine's failure model is only trustworthy if something exercises it:
this injector forces the faults the fault domain claims to survive —
transient tick failures (the supervisor must retry), admission pressure
(the scheduler must delay, not reorder), forced preemptions (the
snapshot/restore path must stay bit-identical), and poisoned decode state
(the NaN quarantine must fail ONE slot without touching its cohabitants).

Everything is driven by one seeded numpy Generator, so a chaos run is
exactly reproducible from its seed — a failing CI lane replays locally.

The ENV-DRIVEN lane (`REPRO_CHAOS=1`, read by the engine at construction)
must be SEMANTICS-PRESERVING: the whole serving test suite runs under it
unmodified, so the default injections only perturb *when* work happens
(retried ticks, delayed admissions, evict-then-resume) — never *what* the
streams contain. NaN poisoning is NOT semantics-preserving (it turns
streams into FAILED quarantines), so its env default is 0; dedicated tests
construct `Chaos(nan=...)` explicitly or call `SlotPool.poison_slot`.

CRASH-CLASS faults (the REPRO_CRASH lane) go past the in-process fault
domain and kill the PROCESS: `crash_event` fires on a journaled engine tick
and the engine then dies by SIGKILL — straight away ("kill"), after tearing
the journal's last record mid-write ("torn"), or after materializing the
next snapshot WITHOUT its COMMITTED marker ("snap"). These are not
semantics-preserving inside one process by design; the thing they prove is
the recovery contract (ServingEngine.recover + the supervisor harness
brings every stream back bit-identically). `crash_step` pins the crash to
one deterministic tick and fires at most once per PROCESS — the restarted
generation (REPRO_SUPERVISE_GENERATION) sails past it, so supervised runs
terminate. Malformed numeric env values fail fast with the offending
name/value, and the engine seed-logs `describe()` once at start so a chaos
CI failure is reproducible from the log line.

Env knobs (floats are per-tick probabilities):
  REPRO_CHAOS             master switch (off unless truthy)
  REPRO_CHAOS_SEED        generator seed                     (default 0)
  REPRO_CHAOS_TICK        P(transient decode-tick failure)   (default 0.05)
  REPRO_CHAOS_PRESS       P(admissions skipped this tick)    (default 0.05)
  REPRO_CHAOS_PREEMPT     P(force-evict a random active slot)(default 0.05)
  REPRO_CHAOS_NAN         P(poison a random active slot)     (default 0.0)
  REPRO_CHAOS_CRASH       P(crash the process this tick)     (default 0.0)
  REPRO_CHAOS_CRASH_STEP  crash deterministically AT this engine tick
                          (default -1 = off; fires once per process)
  REPRO_CHAOS_CRASH_CLASS kill | torn | snap | mix           (default kill)
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np


class ChaosError(RuntimeError):
    """An injected transient tick failure. RuntimeError so the serving
    supervisor's default retry_on catches it — exactly the class of error
    retry exists for."""


_CRASH_CLASSES = ("kill", "torn", "snap")


@dataclass
class Chaos:
    """Seeded fault injector; all rates are per-tick probabilities."""

    seed: int = 0
    tick_fail: float = 0.0    # transient decode-tick failures (retried)
    pressure: float = 0.0     # skip this tick's admissions (delay only)
    preempt: float = 0.0      # force-evict a random active slot
    nan: float = 0.0          # poison a random active slot's decode state
    crash: float = 0.0        # kill the process (journaled engines only)
    crash_step: int = -1      # deterministic crash AT this tick (-1 = off)
    crash_class: str = "kill"  # kill | torn | snap | mix (seeded pick)
    # never inject more consecutive tick failures than the supervisor will
    # retry — chaos proves the fault domain, it doesn't DoS it
    max_consecutive_faults: int = 2
    injected: dict = field(default_factory=lambda: {
        "tick_faults": 0, "pressure": 0, "preempts": 0, "nans": 0,
        "crashes": 0})

    def __post_init__(self):
        if self.crash_class not in _CRASH_CLASSES + ("mix",):
            raise ValueError(
                f"crash_class={self.crash_class!r} not in "
                f"{_CRASH_CLASSES + ('mix',)}")
        self._rng = np.random.default_rng(self.seed)
        self._consecutive = 0
        self._crash_fired = False

    @classmethod
    def from_env(cls) -> "Chaos | None":
        """The CI lane's constructor: None unless REPRO_CHAOS is truthy.
        Malformed numeric values fail fast naming the variable — a typo'd
        knob must not silently run the lane with a default rate."""
        if os.environ.get("REPRO_CHAOS", "").strip().lower() in \
                ("", "0", "false", "no"):
            return None

        def num(name, default, cast):
            raw = os.environ.get(name)
            if raw is None or raw == "":
                return default
            try:
                return cast(raw)
            except ValueError:
                raise ValueError(
                    f"malformed chaos env knob {name}={raw!r} "
                    f"(expected {cast.__name__})") from None

        def f(name, default):
            return num(name, default, float)

        return cls(seed=num("REPRO_CHAOS_SEED", 0, int),
                   tick_fail=f("REPRO_CHAOS_TICK", 0.05),
                   pressure=f("REPRO_CHAOS_PRESS", 0.05),
                   preempt=f("REPRO_CHAOS_PREEMPT", 0.05),
                   nan=f("REPRO_CHAOS_NAN", 0.0),
                   crash=f("REPRO_CHAOS_CRASH", 0.0),
                   crash_step=num("REPRO_CHAOS_CRASH_STEP", -1, int),
                   crash_class=os.environ.get(
                       "REPRO_CHAOS_CRASH_CLASS", "kill").strip() or "kill")

    def describe(self) -> str:
        """One reproducibility line: everything needed to replay this
        config locally. The engine logs it once at start."""
        return (f"chaos seed={self.seed} tick={self.tick_fail} "
                f"press={self.pressure} preempt={self.preempt} "
                f"nan={self.nan} crash={self.crash} "
                f"crash_step={self.crash_step} "
                f"crash_class={self.crash_class}")

    # ----------------------------------------------------------------- events

    def maybe_tick_fault(self, step: int) -> None:
        """Raise ChaosError with probability tick_fail, capped at
        max_consecutive_faults in a row so the supervisor always wins."""
        if self.tick_fail > 0 and \
                self._consecutive < self.max_consecutive_faults and \
                self._rng.random() < self.tick_fail:
            self._consecutive += 1
            self.injected["tick_faults"] += 1
            raise ChaosError(f"injected transient tick failure @ step {step}")
        self._consecutive = 0

    def pressure_event(self) -> bool:
        """Should this tick's admissions be skipped (allocator pressure)?"""
        hit = self.pressure > 0 and self._rng.random() < self.pressure
        if hit:
            self.injected["pressure"] += 1
        return hit

    def preempt_victim(self, slots: list[int]) -> int | None:
        """Pick a slot to force-evict this tick, or None."""
        if not slots or self.preempt <= 0 or \
                self._rng.random() >= self.preempt:
            return None
        self.injected["preempts"] += 1
        return slots[int(self._rng.integers(len(slots)))]

    def nan_victim(self, slots: list[int]) -> int | None:
        """Pick a slot whose decode state gets poisoned, or None."""
        if not slots or self.nan <= 0 or self._rng.random() >= self.nan:
            return None
        self.injected["nans"] += 1
        return slots[int(self._rng.integers(len(slots)))]

    def crash_event(self, step: int) -> str | None:
        """Should the PROCESS die at this engine tick? Returns the crash
        class ("kill" | "torn" | "snap") or None. A pinned `crash_step`
        fires exactly once per process (the recovered generation must run
        past the same tick number without re-dying); the probabilistic rate
        has no such cap — the supervisor's restart budget bounds it."""
        hit = (step == self.crash_step and not self._crash_fired) or \
            (self.crash > 0 and self._rng.random() < self.crash)
        if not hit:
            return None
        self._crash_fired = True
        self.injected["crashes"] += 1
        if self.crash_class == "mix":
            return _CRASH_CLASSES[int(self._rng.integers(
                len(_CRASH_CLASSES)))]
        return self.crash_class

    def torn_cut(self, record_bytes: int) -> int:
        """How many bytes of the journal's last record the torn-write crash
        truncates: seeded in [1, record_bytes] so every replay of the seed
        tears the same byte."""
        return 1 + int(self._rng.integers(max(1, record_bytes)))
