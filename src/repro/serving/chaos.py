"""Seeded fault injection for the serving engine (the chaos lane).

The engine's failure model is only trustworthy if something exercises it:
this injector forces the faults the fault domain claims to survive —
transient tick failures (the supervisor must retry), admission pressure
(the scheduler must delay, not reorder), forced preemptions (the
snapshot/restore path must stay bit-identical), and poisoned decode state
(the NaN quarantine must fail ONE slot without touching its cohabitants).

Everything is driven by one seeded numpy Generator, so a chaos run is
exactly reproducible from its seed — a failing CI lane replays locally.

The ENV-DRIVEN lane (`REPRO_CHAOS=1`, read by the engine at construction)
must be SEMANTICS-PRESERVING: the whole serving test suite runs under it
unmodified, so the default injections only perturb *when* work happens
(retried ticks, delayed admissions, evict-then-resume) — never *what* the
streams contain. NaN poisoning is NOT semantics-preserving (it turns
streams into FAILED quarantines), so its env default is 0; dedicated tests
construct `Chaos(nan=...)` explicitly or call `SlotPool.poison_slot`.

Env knobs (floats are per-tick probabilities):
  REPRO_CHAOS         master switch (off unless truthy)
  REPRO_CHAOS_SEED    generator seed                     (default 0)
  REPRO_CHAOS_TICK    P(transient decode-tick failure)   (default 0.05)
  REPRO_CHAOS_PRESS   P(admissions skipped this tick)    (default 0.05)
  REPRO_CHAOS_PREEMPT P(force-evict a random active slot)(default 0.05)
  REPRO_CHAOS_NAN     P(poison a random active slot)     (default 0.0)
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np


class ChaosError(RuntimeError):
    """An injected transient tick failure. RuntimeError so the serving
    supervisor's default retry_on catches it — exactly the class of error
    retry exists for."""


@dataclass
class Chaos:
    """Seeded fault injector; all rates are per-tick probabilities."""

    seed: int = 0
    tick_fail: float = 0.0    # transient decode-tick failures (retried)
    pressure: float = 0.0     # skip this tick's admissions (delay only)
    preempt: float = 0.0      # force-evict a random active slot
    nan: float = 0.0          # poison a random active slot's decode state
    # never inject more consecutive tick failures than the supervisor will
    # retry — chaos proves the fault domain, it doesn't DoS it
    max_consecutive_faults: int = 2
    injected: dict = field(default_factory=lambda: {
        "tick_faults": 0, "pressure": 0, "preempts": 0, "nans": 0})

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._consecutive = 0

    @classmethod
    def from_env(cls) -> "Chaos | None":
        """The CI lane's constructor: None unless REPRO_CHAOS is truthy."""
        if os.environ.get("REPRO_CHAOS", "").strip().lower() in \
                ("", "0", "false", "no"):
            return None

        def f(name, default):
            return float(os.environ.get(name, default))

        return cls(seed=int(os.environ.get("REPRO_CHAOS_SEED", "0")),
                   tick_fail=f("REPRO_CHAOS_TICK", 0.05),
                   pressure=f("REPRO_CHAOS_PRESS", 0.05),
                   preempt=f("REPRO_CHAOS_PREEMPT", 0.05),
                   nan=f("REPRO_CHAOS_NAN", 0.0))

    # ----------------------------------------------------------------- events

    def maybe_tick_fault(self, step: int) -> None:
        """Raise ChaosError with probability tick_fail, capped at
        max_consecutive_faults in a row so the supervisor always wins."""
        if self.tick_fail > 0 and \
                self._consecutive < self.max_consecutive_faults and \
                self._rng.random() < self.tick_fail:
            self._consecutive += 1
            self.injected["tick_faults"] += 1
            raise ChaosError(f"injected transient tick failure @ step {step}")
        self._consecutive = 0

    def pressure_event(self) -> bool:
        """Should this tick's admissions be skipped (allocator pressure)?"""
        hit = self.pressure > 0 and self._rng.random() < self.pressure
        if hit:
            self.injected["pressure"] += 1
        return hit

    def preempt_victim(self, slots: list[int]) -> int | None:
        """Pick a slot to force-evict this tick, or None."""
        if not slots or self.preempt <= 0 or \
                self._rng.random() >= self.preempt:
            return None
        self.injected["preempts"] += 1
        return slots[int(self._rng.integers(len(slots)))]

    def nan_victim(self, slots: list[int]) -> int | None:
        """Pick a slot whose decode state gets poisoned, or None."""
        if not slots or self.nan <= 0 or self._rng.random() >= self.nan:
            return None
        self.injected["nans"] += 1
        return slots[int(self._rng.integers(len(slots)))]
