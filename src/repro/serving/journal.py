"""Durable request journal + atomic engine snapshots (crash-tolerant serving).

The GO cache is the paper's thesis made literal — expert-choice GO rows are
TopKUpdate HISTORY, not recomputable from the prompt — so a process crash
without durability silently destroys every in-flight stream and forgets
which requests were ever admitted. This module is the durability layer the
recovery path (`ServingEngine.recover`) replays:

  Journal        an fsync'd append-only log of length-prefixed, CRC-guarded
                 records. A crash mid-write leaves a TORN TAIL (short header,
                 short payload, or CRC mismatch); `read_records` stops at the
                 first bad record and returns the valid prefix — replay never
                 crashes on a torn journal (pinned byte-by-byte by the
                 hypothesis property test in tests/test_journal.py).

  EngineJournal  the engine-facing layer: one journal SEGMENT per snapshot
                 generation plus periodic whole-engine snapshots committed
                 with the checkpoint/ckpt.py pattern — write everything into
                 `snap_<seq>.tmp/`, fsync, drop an empty COMMITTED marker
                 LAST, rename into place. A snapshot without COMMITTED is a
                 crash artifact and recovery skips it in favor of the
                 previous committed one. Committing a snapshot opens segment
                 `journal_<seq>.log`, so recovery = latest committed snapshot
                 + replay of exactly one segment's tail.

Event kinds written by the engine (serving/engine.py):

  submit    full request record (prompt, budgets, sampling seed, priority,
            submit order) — everything needed to rebuild the Request
  install   a request's FIRST token, emitted at admission from the prefill
            logits (cold, cached, prefix-extension, or chunk completion)
  tick      the per-tick token watermark: {request id: token} for every slot
            that decoded this tick
  terminal  a request reached a terminal status (DONE/TIMEOUT/CANCELLED/
            FAILED) — replay re-applies CANCELLED; the rest are recomputed
            bit-identically by resuming decode from the restored state

What is durable: request identity/parameters, admission watermarks, emitted
tokens, terminal statuses, and (via snapshots) the live KV pages + GO rows +
decode cursors + per-slot PRNG keys + scheduler EWMAs/skip counters + the
prefix-index tree with its shared page contents. What is NOT durable:
wall-clock anchors (deadlines re-anchor at recovery), chaos RNG position,
and per-request extras (cross-attn memory is rejected at submit when
journaling). See docs/architecture.md "Durability & crash recovery".
"""
from __future__ import annotations

import os
import pickle
import re
import shutil
import struct
import time
import zlib

import numpy as np

from repro.serving.scheduler import Request, RequestStatus

# one record = HEADER (payload length + CRC32 of payload) + pickle payload.
# The length field is what makes torn tails DETECTABLE (a short read can
# never parse as a record); the CRC is what makes them UNAMBIGUOUS (a
# truncation landing inside the next record's bytes cannot fake a record).
_HEADER = struct.Struct("<II")
_SEGMENT_MAGIC = b"REPROJNL"
_SNAP_RE = re.compile(r"^snap_(\d{8})$")
_SEG_RE = re.compile(r"^journal_(\d{8})\.log$")


class JournalError(RuntimeError):
    """A journal directory is unusable for recovery (no committed snapshot
    at all — distinct from a torn tail, which replay tolerates)."""


# --------------------------------------------------------------- record log


def append_record(f, obj) -> int:
    """Append one durable record to open file `f`: length + CRC + payload,
    flushed and fsync'd so a SIGKILL after return can never lose it.
    Returns the record's full on-disk size in bytes."""
    payload = pickle.dumps(obj, protocol=4)
    f.write(_HEADER.pack(len(payload), zlib.crc32(payload)))
    f.write(payload)
    f.flush()
    os.fsync(f.fileno())
    return _HEADER.size + len(payload)


def read_records(path: str) -> list:
    """Replay a journal segment, tolerating a torn tail: records are yielded
    until the first short header, short payload, or CRC mismatch — whatever
    a crash mid-append left behind is silently dropped, and everything
    BEFORE it is returned intact (a valid prefix, never garbage)."""
    out = []
    if not os.path.exists(path):
        return out
    with open(path, "rb") as f:
        if f.read(len(_SEGMENT_MAGIC)) != _SEGMENT_MAGIC:
            return out                       # foreign or torn-at-birth file
        while True:
            head = f.read(_HEADER.size)
            if len(head) < _HEADER.size:
                return out                   # torn tail: short header
            length, crc = _HEADER.unpack(head)
            payload = f.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                return out                   # torn tail: short/corrupt payload
            try:
                out.append(pickle.loads(payload))
            except Exception:
                return out                   # CRC-valid but unloadable: stop


# ------------------------------------------------------- request (de)serde


def request_record(req: Request, *, runtime: bool = False) -> dict:
    """Pickle-friendly snapshot of a Request. `runtime=True` additionally
    captures lifecycle state (emitted tokens, status, admission steps) for
    engine snapshots; submit events only need the identity fields."""
    rec = {
        "rid": req.request_id,
        "prompt": np.asarray(req.prompt, np.int32),
        "max_new_tokens": req.max_new_tokens,
        "eos_id": req.eos_id,
        "arrival_step": req.arrival_step,
        "priority": req.priority,
        "temperature": req.temperature,
        "top_p": req.top_p,
        "seed": req.seed,
        "deadline_s": req.deadline_s,
        "max_wall_s": req.max_wall_s,
        "seq": req.seq,
        "times_skipped": req.times_skipped,
        "expert_sig": (None if req.expert_sig is None
                       else np.asarray(req.expert_sig, bool)),
    }
    if runtime:
        rec.update(tokens=list(req.tokens), status=req.status.value,
                   fail_reason=req.fail_reason, admit_step=req.admit_step,
                   finish_step=req.finish_step, preemptions=req.preemptions,
                   slot=req.slot)
    return rec


def request_from_record(rec: dict) -> Request:
    """Rebuild a Request from `request_record`. Wall-clock anchors re-anchor
    at NOW — deadline budgets are wall time, which a dead process cannot
    have been spending; restarting them is the only non-lying option (the
    alternative, expiring everything that out-waited the outage, would turn
    every recovery into a mass TIMEOUT)."""
    req = Request(
        request_id=rec["rid"],
        prompt=np.asarray(rec["prompt"], np.int32),
        max_new_tokens=rec["max_new_tokens"],
        eos_id=rec["eos_id"],
        arrival_step=rec["arrival_step"],
        priority=rec["priority"],
        temperature=rec["temperature"],
        top_p=rec["top_p"],
        seed=rec["seed"],
        deadline_s=rec["deadline_s"],
        max_wall_s=rec["max_wall_s"],
    )
    req.seq = rec["seq"]
    req.times_skipped = rec["times_skipped"]
    req.expert_sig = rec["expert_sig"]
    now = time.monotonic()
    req.arrival_time = req.submit_time = now
    if "status" in rec:
        req.status = RequestStatus(rec["status"])
        req.fail_reason = rec["fail_reason"]
        req.tokens = list(rec["tokens"])
        req.admit_step = rec["admit_step"]
        req.finish_step = rec["finish_step"]
        req.preemptions = rec["preemptions"]
        req.slot = rec["slot"]
        if req.admit_step >= 0:
            req.admit_time = now             # max_wall_s re-anchors too
    return req


# ----------------------------------------------------------- engine journal


class EngineJournal:
    """Snapshot-segmented write-ahead journal for one ServingEngine.

    Layout under `directory`:
        snap_<seq>/state.pkl + COMMITTED   atomic engine snapshot
        journal_<seq>.log                  events SINCE snapshot <seq>

    `commit_snapshot` is the generation boundary: snapshot seq N commits
    (ckpt.py pattern — marker last, rename into place), THEN segment N opens
    and subsequent events land there. A crash between the two leaves a
    committed snapshot with a missing segment, which replays as an empty
    tail — never a stale one. Old generations are pruned to `keep`
    committed snapshots; uncommitted crash leftovers older than the newest
    committed snapshot are swept on the next commit."""

    def __init__(self, directory: str, *, snapshot_every: int = 32,
                 keep: int = 2):
        if snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        self.dir = directory
        self.snapshot_every = int(snapshot_every)
        self.keep = int(keep)
        os.makedirs(directory, exist_ok=True)
        self._seq = -1
        self._f = None
        self._last_record_bytes = 0
        self.bytes_written = 0
        self.events_written = 0
        self.snapshots_committed = 0
        self.last_snapshot_step = 0

    # ------------------------------------------------------------- appending

    def append(self, kind: str, **payload) -> None:
        """Durably append one event to the current segment."""
        assert self._f is not None, "no open segment — commit_snapshot first"
        self._last_record_bytes = append_record(self._f, (kind, payload))
        self.bytes_written += self._last_record_bytes
        self.events_written += 1

    # ------------------------------------------------------------- snapshots

    def _snap_dir(self, seq: int) -> str:
        return os.path.join(self.dir, f"snap_{seq:08d}")

    def _seg_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"journal_{seq:08d}.log")

    def _write_snapshot_files(self, target: str, payload: dict,
                              committed: bool) -> None:
        tmp = target + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "state.pkl"), "wb") as f:
            pickle.dump(payload, f, protocol=4)
            f.flush()
            os.fsync(f.fileno())
        if committed:
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, target)
        self._fsync_dir(self.dir)

    @staticmethod
    def _fsync_dir(path: str) -> None:
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:                       # platforms without dir fds
            return
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def commit_snapshot(self, payload: dict, step: int) -> int:
        """Atomically commit an engine snapshot (marker written LAST) and
        open the next journal segment. Returns the new generation seq."""
        seq = self._seq + 1 if self._seq >= 0 else _next_seq(self.dir)
        self._write_snapshot_files(self._snap_dir(seq), payload,
                                   committed=True)
        if self._f is not None:
            self._f.close()
        self._f = open(self._seg_path(seq), "wb")
        self._f.write(_SEGMENT_MAGIC)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._seq = seq
        self.snapshots_committed += 1
        self.last_snapshot_step = int(step)
        self._last_record_bytes = 0
        self._prune(seq)
        return seq

    def write_uncommitted_snapshot(self, payload: dict) -> None:
        """Chaos hook: materialize the NEXT snapshot's files WITHOUT the
        COMMITTED marker — exactly what a crash between the data write and
        the marker leaves behind. Recovery must skip it (pinned in
        tests/test_crash_recovery.py)."""
        self._write_snapshot_files(self._snap_dir(self._seq + 1), payload,
                                   committed=False)

    def tear_tail(self, cut_bytes: int) -> None:
        """Chaos hook: truncate the current segment `cut_bytes` into its
        LAST record — the torn-write crash class. The cut is clamped so at
        least one byte of the record is lost and the preceding records stay
        intact (replay must recover exactly them)."""
        if self._f is None or self._last_record_bytes == 0:
            return
        cut = max(1, min(int(cut_bytes), self._last_record_bytes))
        self._f.flush()
        size = self._f.tell()
        self._f.truncate(size - cut)
        self._f.flush()
        os.fsync(self._f.fileno())

    def _prune(self, newest: int) -> None:
        """Keep the last `keep` committed generations; sweep everything
        older, plus uncommitted snapshot leftovers and stale .tmp dirs from
        crashed commits (any generation < newest that never committed is an
        orphan by construction)."""
        committed = sorted(s for s in _snapshot_seqs(self.dir)
                           if os.path.exists(
                               os.path.join(self._snap_dir(s), "COMMITTED")))
        drop = set(committed[:-self.keep])
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
                continue
            m = _SNAP_RE.match(name)
            if m:
                seq = int(m.group(1))
                uncommitted = not os.path.exists(
                    os.path.join(self.dir, name, "COMMITTED"))
                if seq in drop or (uncommitted and seq < newest):
                    shutil.rmtree(os.path.join(self.dir, name),
                                  ignore_errors=True)
                continue
            m = _SEG_RE.match(name)
            if m and int(m.group(1)) in drop:
                try:
                    os.unlink(os.path.join(self.dir, name))
                except OSError:
                    pass

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    # -------------------------------------------------------------- recovery

    @staticmethod
    def recoverable(directory: str) -> bool:
        """Does `directory` hold at least one committed snapshot?"""
        return EngineJournal.latest_committed(directory) is not None

    @staticmethod
    def latest_committed(directory: str):
        """(seq, snapshot payload) of the newest COMMITTED and loadable
        snapshot, or None. A snapshot missing its marker is a crash artifact
        and is skipped; a committed-but-unloadable one (disk corruption) is
        also skipped in favor of the previous generation — recovery prefers
        older-but-consistent over newer-but-broken."""
        if not os.path.isdir(directory):
            return None
        for seq in sorted(_snapshot_seqs(directory), reverse=True):
            d = os.path.join(directory, f"snap_{seq:08d}")
            if not os.path.exists(os.path.join(d, "COMMITTED")):
                continue
            try:
                with open(os.path.join(d, "state.pkl"), "rb") as f:
                    return seq, pickle.load(f)
            except Exception:
                continue
        return None

    @staticmethod
    def read_tail(directory: str, seq: int) -> list:
        """The events journaled since snapshot `seq` (torn tail dropped).
        A missing segment (crash between snapshot commit and segment open)
        is an empty tail, not an error."""
        return read_records(os.path.join(directory, f"journal_{seq:08d}.log"))


def _snapshot_seqs(directory: str) -> list[int]:
    out = []
    for name in os.listdir(directory):
        m = _SNAP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return out


def _next_seq(directory: str) -> int:
    seqs = _snapshot_seqs(directory)
    return max(seqs) + 1 if seqs else 0
