"""Continuous-batching serving engine over the pooled KV + GO cache state.

The paper's GO cache makes each decode step O(1) per request; this engine
makes the REQUEST schedule dynamic too. One jitted decode step runs over a
fixed slot array with an active mask:

  admit    a queued request prefills into a free slot — its KV rows and
           per-layer GO cache entries are written in place (write_decode_slot)
           while the other slots keep decoding between engine ticks;
  decode   every tick advances ALL occupied slots one token in a single
           batched serve_step — slots sit at different positions thanks to
           the per-slot `t` vector, so nothing recompiles and nobody stalls;
  retire   a slot frees on EOS or length; its caches are reset
           (init_decode_slot) and the row is immediately reusable.

Greedy decoding is the default and is bit-identical per request to the
static-batch `repro.launch.serve.generate` path (tests/test_serving.py):
the same compiled kernels run in both, and every batched op is row-wise
independent. `submit(..., temperature=, top_p=, seed=)` switches a request
to temperature/top-p sampling — per-slot PRNG keys live in the pool, the
sampled step variant compiles only once a sampling request is active, and
greedy rows inside a sampling pool stay bit-identical.

Compile surface: the decode step compiles ONCE per (pool width, max_tokens)
and sampling mode; prefill compiles once per distinct prompt length — or
once per power-of-two BUCKET with `prompt_buckets=True`, which right-pads
prompts and threads the true length through prefill as a traced valid_len
(expert-choice routing masks the pads, so the GO cache stays clean).

The MoE execution backend rides in through cfg.moe.backend: with "pallas"
the batched decode tick runs the selected-experts static-capacity decode
plan (~2*B*k/E rows per expert with an exact overflow fallback, instead of
B*E dense FFNs — kernels/ops.py:go_selected_ffn) and prefill flattens the
whole pool's FFN pairs into one packed tile plan. Streams stay
bit-identical to the static generate() path because both run the same
kernels (pinned with backend="pallas" in tests/test_serving.py).

With a `mesh`, the pool state is sharded by `launch/sharding.py` (slot rows
across the data-parallel replicas, KV sequence / GO expert dims over
"model") and every decode tick runs inside the mesh context, so GSPMD
partitions the batched step — including the selected-experts grouped GEMM —
across the replicas. Admission prefill stays batch-1 (replicated) and is
splatted into the sharded row; streams remain bit-identical to the
unsharded engine (pinned in tests/test_moe_mesh.py).
"""
from __future__ import annotations

import itertools
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import prefill, serve_step
from repro.serving.pool import SlotPool
from repro.serving.scheduler import FIFOScheduler, Request


@partial(jax.jit, static_argnames="cfg")
def _decode_step(params, state, tokens, active, cfg):
    """One batched decode tick. Retired slots still flow through the math
    (masking beats reshaping — shapes never change) but their position is
    pinned to 0 so they stay inside max_tokens until the next admission."""
    logits, state = serve_step(params, state, tokens, cfg)
    state["t"] = jnp.where(active, state["t"], 0)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), state


def _sample_tokens(logits, keys, temps, top_ps):
    """Per-row temperature/top-p sampling over [B, V] logits; rows with
    temperature <= 0 take the greedy argmax (bit-identical to the greedy
    engine). top_p keeps the smallest prefix of the probability-sorted
    vocabulary whose mass reaches top_p — as top_p -> 0 only the argmax
    survives, so sampling degenerates to greedy exactly."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def row(lg, key, temp, tp):
        lg = (lg / jnp.maximum(temp, 1e-6)).astype(jnp.float32)
        srt, idx = jax.lax.top_k(lg, lg.shape[-1])
        probs = jax.nn.softmax(srt)
        keep = (jnp.cumsum(probs) - probs) < tp     # first token always kept
        filt = jnp.where(keep, srt, -jnp.inf)
        return idx[jax.random.categorical(key, filt)].astype(jnp.int32)

    sampled = jax.vmap(row)(logits, keys, temps, top_ps)
    return jnp.where(temps > 0, sampled, greedy)


@partial(jax.jit, static_argnames="cfg")
def _decode_step_sampled(params, state, tokens, active, temps, top_ps, keys,
                         cfg):
    """Sampling variant of the decode tick: compiled only once at least one
    active request asks for temperature > 0, so pure-greedy serving never
    pays the per-row vocab sort."""
    logits, state = serve_step(params, state, tokens, cfg)
    state["t"] = jnp.where(active, state["t"], 0)
    split = jax.vmap(jax.random.split)(keys)        # [B, 2, 2]
    tok = _sample_tokens(logits, split[:, 0], temps, top_ps)
    return tok, state, split[:, 1]


# prefill compiles once per (prompt length, max_len) and is shared across
# engine instances — module-level so benchmark sweeps don't recompile it.
# With prompt bucketing the padded length is a power-of-two bucket and the
# true length rides in as a TRACED valid_len, so one compile per bucket.
_jit_prefill = jax.jit(prefill, static_argnames=("cfg", "max_len"))


class ServingEngine:
    """Continuous-batching engine: submit requests any time, run ticks."""

    def __init__(self, params, cfg, *, num_slots: int = 8,
                 max_tokens: int = 256, max_queue: int = 0,
                 extras: dict | None = None, mesh=None,
                 prompt_buckets: bool = False):
        self.params = params
        self.cfg = cfg
        self.mesh = mesh
        self.pool = SlotPool(cfg, num_slots, max_tokens, extras, mesh=mesh)
        self.scheduler = FIFOScheduler(num_slots, max_tokens, max_queue)
        self.step_count = 0
        self.finished: dict[int, Request] = {}
        self._ids = itertools.count()
        # pad prompts up to power-of-two buckets so prefill compiles once
        # per BUCKET instead of once per distinct prompt length (attention
        # families only — recurrent archs prefill step-by-step). Dense archs
        # reproduce the unbucketed streams exactly; MoE capacity constants
        # derive from the BUCKET length (ec_capacity(bucket) >
        # ec_capacity(true len)), so MoE streams are deterministic per
        # bucket but may differ from the unbucketed engine's.
        self.prompt_buckets = bool(
            prompt_buckets and cfg.block == "attn"
            and cfg.encoder_layers == 0 and cfg.cross_attn_every == 0)
        self.prefill_lengths: set[int] = set()

    # ------------------------------------------------------------- submission

    def submit(self, prompt, max_new_tokens: int, *, eos_id: int | None = None,
               extras: dict | None = None, arrival_step: int = 0,
               request_id: int | None = None, temperature: float = 0.0,
               top_p: float = 1.0, seed: int | None = None) -> int:
        """Queue a request. `arrival_step` > current step defers arrival to
        that engine tick (trace replay). `temperature` > 0 switches the
        request's rows to temperature/top-p sampling (greedy rows in the
        same pool stay bit-identical). Returns the request id."""
        rid = request_id if request_id is not None else next(self._ids)
        req = Request(
            request_id=rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=int(max_new_tokens),
            eos_id=eos_id,
            extras=extras,
            arrival_step=arrival_step,
            temperature=float(temperature),
            top_p=float(top_p),
            seed=seed,
        )
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not (0.0 < req.top_p <= 1.0):
            raise ValueError("top_p must be in (0, 1]")
        req.arrival_time = time.monotonic()
        self.scheduler.submit(req, now_step=self.step_count)
        return rid

    # ------------------------------------------------------------------ ticks

    def step(self) -> list[Request]:
        """One engine tick: admit due+queued requests into free slots, then
        advance every occupied slot one token. Returns requests finished on
        this tick."""
        done: list[Request] = []

        for req in self.scheduler.poll(self.step_count):
            req.arrival_time = time.monotonic()

        free = self.pool.free_slots()
        while free:
            req = self.scheduler.next_admission(self.pool.num_active())
            if req is None:
                break
            self._admit(free.pop(0), req, done)

        if self.pool.any_active():
            toks, state = self._run_decode_step()
            self.pool.state = self.pool._pin(state)
            toks = np.asarray(toks)
            self.step_count += 1
            for slot, req in enumerate(self.pool.owner):
                if req is None:
                    continue
                tok = int(toks[slot])
                req.tokens.append(tok)
                self.pool.pending[slot] = tok
                self.pool.remaining[slot] -= 1
                if self.pool.remaining[slot] <= 0 or \
                        (req.eos_id is not None and tok == req.eos_id):
                    self._finish(slot, done)
        else:
            # idle tick — jump straight to the next trace arrival
            nxt = self.scheduler.next_arrival_step()
            self.step_count = max(self.step_count + 1,
                                  nxt if nxt is not None else 0)
        return done

    def run(self) -> dict[int, Request]:
        """Tick until queue, trace and pool drain; returns finished requests
        keyed by request id (token streams in Request.tokens)."""
        while self.scheduler.has_pending() or self.pool.any_active():
            self.step()
        return self.finished

    # -------------------------------------------------------------- internals

    def _run_decode_step(self):
        """One jitted decode tick, inside the mesh context when sharded (the
        jit cache keys on the ambient mesh, so the sharded and unsharded
        variants coexist in one process). Pure-greedy pools run the lean
        greedy step; a pool with any sampling request runs the sampling
        variant (greedy rows inside it stay bit-identical)."""
        sampling = bool((self.pool.temps > 0).any())
        args = (self.params, self.pool.state, jnp.asarray(self.pool.pending),
                jnp.asarray(self.pool.active_mask()))
        if sampling:
            args += (jnp.asarray(self.pool.temps),
                     jnp.asarray(self.pool.top_ps),
                     jnp.asarray(self.pool.keys))
        fn = _decode_step_sampled if sampling else _decode_step
        if self.mesh is None:
            out = fn(*args, self.cfg)
        else:
            with self.mesh:
                out = fn(*args, self.cfg)
        if sampling:
            toks, state, new_keys = out
            self.pool.keys = np.array(new_keys, dtype=np.uint32)
            return toks, state
        return out

    def _bucketed(self, prompt: np.ndarray):
        """Pad the prompt up to its power-of-two bucket (capped at the
        pool's max_tokens); returns (padded [S_b], valid_len or None)."""
        n = int(prompt.shape[0])
        b = 8
        while b < n:
            b *= 2
        b = min(b, self.pool.max_tokens)
        if b <= n:
            return prompt, None
        return np.pad(prompt, (0, b - n)), n

    def _admit(self, slot: int, req: Request, done: list[Request]) -> None:
        """Prefill a request into `slot` mid-flight: fills that row's KV and
        GO cache entries and emits the request's first token (from the
        prefill logits — exactly what static generate() emits first; sampled
        from them when the request asks for temperature > 0)."""
        prompt, valid_len = (self._bucketed(req.prompt) if self.prompt_buckets
                             else (req.prompt, None))
        self.prefill_lengths.add(int(prompt.shape[0]))
        slot_state, logits = _jit_prefill(
            self.params, jnp.asarray(prompt, jnp.int32)[None, :],
            self.cfg, req.extras or {}, self.pool.max_tokens,
            None if valid_len is None else jnp.asarray(valid_len, jnp.int32))
        key_next = None
        if req.temperature > 0:
            seed = req.seed if req.seed is not None else req.request_id
            k_use, key_next = jax.random.split(jax.random.PRNGKey(seed))
            first = int(_sample_tokens(
                logits, k_use[None],
                jnp.full((1,), req.temperature, jnp.float32),
                jnp.full((1,), req.top_p, jnp.float32))[0])
        else:
            first = int(jnp.argmax(logits, axis=-1)[0])
        req.admit_step = self.step_count
        req.tokens.append(first)
        self.pool.admit(slot, req, slot_state, first, key=key_next)
        if self.pool.remaining[slot] <= 0 or \
                (req.eos_id is not None and first == req.eos_id):
            self._finish(slot, done)

    def _finish(self, slot: int, done: list[Request]) -> None:
        req = self.pool.retire(slot)
        req.finish_step = self.step_count
        req.finish_time = time.monotonic()
        self.finished[req.request_id] = req
        done.append(req)

    # ------------------------------------------------------------------ stats

    def stats(self) -> dict:
        from repro.core.moe import resolve_backend
        reqs = self.finished.values()
        return {
            "steps": self.step_count,
            "admitted": self.pool.admitted_total,
            "finished": len(self.finished),
            "queued": len(self.scheduler.queue),
            "active": self.pool.num_active(),
            "tokens_out": sum(len(r.tokens) for r in reqs),
            "moe_backend": (resolve_backend(self.cfg.moe)
                            if self.cfg.moe is not None else None),
            "mesh": dict(self.mesh.shape) if self.mesh is not None else None,
            "prefill_lengths": sorted(self.prefill_lengths),
        }
