"""Continuous-batching serving engine over the pooled KV + GO cache state.

The paper's GO cache makes each decode step O(1) per request; this engine
makes the REQUEST schedule dynamic too. One jitted decode step runs over a
fixed slot array with an active mask:

  admit    a queued request prefills into a free slot — its KV rows and
           per-layer GO cache entries are written in place (write_decode_slot)
           while the other slots keep decoding between engine ticks;
  decode   every tick advances ALL occupied slots one token in a single
           batched serve_step — slots sit at different positions thanks to
           the per-slot `t` vector, so nothing recompiles and nobody stalls;
  retire   a slot frees on EOS or length; its caches are reset
           (init_decode_slot) and the row is immediately reusable.

Greedy decoding is the default and is bit-identical per request to the
static-batch `repro.launch.serve.generate` path (tests/test_serving.py):
the same compiled kernels run in both, and every batched op is row-wise
independent. `submit(..., temperature=, top_p=, seed=)` switches a request
to temperature/top-p sampling — per-slot PRNG keys live in the pool, the
sampled step variant compiles only once a sampling request is active, and
greedy rows inside a sampling pool stay bit-identical.

PAGED POOL (`paged=True`): the per-slot KV rows become a shared page pool
with per-slot block tables (serving/pool.py + serving/paging.py). The
persistent KV residency is then bounded by `num_pages * page_size` tokens
instead of `num_slots * max_tokens` (the decode gather still materializes
a transient dense layout per layer — see the pool docstring), so a fixed
cache budget admits strictly more concurrent
streams whenever requests need less than max_tokens; admission asks the
allocator "pages reservable?" instead of only "slot free?". Greedy streams
stay bit-identical to the dense engine (the gathered pages reproduce the
dense layout exactly; pinned in tests/test_serving.py). Setting the
REPRO_FORCE_PAGED env var turns paging on for every engine whose config
supports it — the CI matrix uses it to run the whole serving suite paged.

CHUNKED PREFILL (`prefill_chunk=N` tokens): prompts longer than N are
admitted as page-granular chunks, one chunk per engine tick, interleaved
with the decode ticks of the in-flight slots — a long prompt no longer
stalls every stream for its full prefill. Dense archs stream identically to
one-shot prefill; expert-choice MoE routes each chunk at the CHUNK's
capacity and merges GO caches (go_cache_merge), so its streams are
deterministic per chunking but may differ from the one-shot engine's (the
prompt-bucketing caveat). At most one chunk run is in flight, and it holds
a claimed slot + reserved pages from the start, so completion can never
deadlock.

The MoE execution backend rides in through cfg.moe.backend: with "pallas"
the batched decode tick runs the selected-experts static-capacity decode
plan (~2*B*k/E rows per expert with an exact overflow fallback, instead of
B*E dense FFNs — kernels/ops.py:go_selected_ffn) and prefill flattens the
whole pool's FFN pairs into one packed tile plan. Streams stay
bit-identical to the static generate() path because both run the same
kernels (pinned with backend="pallas" in tests/test_serving.py).

With a `mesh`, the pool state is sharded by `launch/sharding.py` (slot rows
across the data-parallel replicas, KV sequence / GO expert dims over
"model"; paged pools shard the page dim over data-parallel and the page
interior over "model", block tables replicated) and every decode tick runs
inside the mesh context, so GSPMD partitions the batched step — including
the selected-experts grouped GEMM — across the replicas. Admission prefill
stays batch-1 (replicated) and is splatted into the sharded row; streams
remain bit-identical to the unsharded engine (pinned in
tests/test_moe_mesh.py).

FAULT DOMAIN: every request ends in a typed terminal status (Request.status
— DONE | TIMEOUT | CANCELLED | FAILED; see serving/scheduler.py). Requests
carry wall budgets (`deadline_s` from submit, `max_wall_s` from first
admission) checked at every tick; `cancel(rid)` retires a request wherever
it is (queued, mid-chunk-prefill, active, or parked preempted). With
`preemption=True` (paged pools only) a blocked higher-priority admission
EVICTS the lowest-priority active stream: its live KV pages + GO rows are
snapshotted host-side, its pages freed, and it resumes later via
block-table surgery into fresh pages — bit-identical to never evicting
(recompute-by-re-prefill is neither bit-exact for KV nor possible at all
for the expert-choice GO decode history; see SlotPool.snapshot). The jitted
decode tick runs under a StepSupervisor (runtime/fault.py — the training
loop's retry/telemetry pattern, same determinism argument), slots producing
non-finite logits are quarantined to FAILED without touching cohabiting
rows, and `REPRO_AUDIT=1` sweeps allocator + pool invariants every tick.
`serving/chaos.py` injects seeded faults into all of it (REPRO_CHAOS=1 is
the CI lane).
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
import signal
import sys
import time
from collections import Counter
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.models.model import (init_decode_state, paged_supported, prefill,
                                prefill_chunk as _model_prefill_chunk,
                                serve_step)
from repro.runtime.fault import StepSupervisor
from repro.serving.chaos import Chaos
from repro.serving.journal import (EngineJournal, JournalError,
                                   request_from_record, request_record)
from repro.serving.paging import PrefixIndex
from repro.serving.pool import SlotPool
from repro.serving.scheduler import (ExpertAwareScheduler, FIFOScheduler,
                                     QueueFull, Request, RequestStatus,
                                     RequestTooLarge)

# chaos configs already seed-logged by THIS process — one reproducibility
# line per distinct config, not one per engine (benchmark sweeps build many)
_chaos_logged: set[str] = set()


@partial(jax.jit, static_argnames="cfg")
def _decode_step(params, state, tokens, active, cfg):
    """One batched decode tick. Retired slots still flow through the math
    (masking beats reshaping — shapes never change) but their position is
    pinned to 0 so they stay inside max_tokens until the next admission.
    Also returns per-row `ok` (all logits finite) — the engine quarantines
    rows that went non-finite without touching their cohabitants."""
    logits, state = serve_step(params, state, tokens, cfg)
    state["t"] = jnp.where(active, state["t"], 0)
    ok = jnp.isfinite(logits).all(axis=-1)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), state, ok


def _sample_tokens(logits, keys, temps, top_ps):
    """Per-row temperature/top-p sampling over [B, V] logits; rows with
    temperature <= 0 take the greedy argmax (bit-identical to the greedy
    engine). top_p keeps the smallest prefix of the probability-sorted
    vocabulary whose mass reaches top_p — as top_p -> 0 only the argmax
    survives, so sampling degenerates to greedy exactly."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def row(lg, key, temp, tp):
        lg = (lg / jnp.maximum(temp, 1e-6)).astype(jnp.float32)
        srt, idx = jax.lax.top_k(lg, lg.shape[-1])
        probs = jax.nn.softmax(srt)
        keep = (jnp.cumsum(probs) - probs) < tp     # first token always kept
        filt = jnp.where(keep, srt, -jnp.inf)
        return idx[jax.random.categorical(key, filt)].astype(jnp.int32)

    sampled = jax.vmap(row)(logits, keys, temps, top_ps)
    return jnp.where(temps > 0, sampled, greedy)


@partial(jax.jit, static_argnames="cfg")
def _decode_step_sampled(params, state, tokens, active, temps, top_ps, keys,
                         cfg):
    """Sampling variant of the decode tick: compiled only once at least one
    active request asks for temperature > 0, so pure-greedy serving never
    pays the per-row vocab sort."""
    logits, state = serve_step(params, state, tokens, cfg)
    state["t"] = jnp.where(active, state["t"], 0)
    ok = jnp.isfinite(logits).all(axis=-1)
    split = jax.vmap(jax.random.split)(keys)        # [B, 2, 2]
    tok = _sample_tokens(logits, split[:, 0], temps, top_ps)
    return tok, state, ok, split[:, 1]


# prefill compiles once per (prompt length, max_len) and is shared across
# engine instances — module-level so benchmark sweeps don't recompile it.
# With prompt bucketing the padded length is a power-of-two bucket and the
# true length rides in as a TRACED valid_len, so one compile per bucket.
_jit_prefill = jax.jit(prefill, static_argnames=("cfg", "max_len"))
# chunk start/valid_len are traced: ONE compile per chunk length serves
# every chunk of every prompt.
_jit_prefill_chunk = jax.jit(_model_prefill_chunk, static_argnames="cfg")


def _env_on(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() not in \
        ("", "0", "false", "no")


# the submit-time gate probe examines at most this many leading prompt
# tokens, zero-padded to exactly this length: ONE probe compile per config
# instead of one per distinct prompt length (a per-length retrace would
# spike submit() latency on varied-length workloads)
_PROBE_TOKENS = 64


@partial(jax.jit, static_argnames="cfg")
def _gate_probe(params, tokens, valid, cfg):
    """Layer-0 router probe over raw prompt EMBEDDINGS: which experts would
    each token's top_k pick if the gate saw the embedding directly? A cheap
    [P, d] @ [d, E] over a FIXED [_PROBE_TOKENS] leading slice (pad rows
    masked out of the scatter) — no attention, no layers, one compile — so
    the scheduler can fingerprint a prompt at submit time. It is a
    HEURISTIC twice over (the real gate input is the post-attention hidden
    state, deeper layers route independently, and tokens past the probe
    window are unseen), which is fine: the signature only steers admission
    order, never any compute, so a wrong prediction costs batch composition
    quality, not correctness. Expert-choice archs refine it at admission
    from the actually-observed GO rows."""
    x = params["embed"][tokens].astype(jnp.float32)           # [P, d]
    gate = params["layers"]["moe"]["gate"][0]                 # layer 0 [d, E]
    _, idx = jax.lax.top_k(x @ gate.astype(jnp.float32), cfg.moe.top_k)
    # pad rows scatter to index E — out of range, dropped
    idx = jnp.where((jnp.arange(tokens.shape[0]) < valid)[:, None],
                    idx, cfg.moe.num_experts)
    return jnp.zeros((cfg.moe.num_experts,), bool).at[
        idx.reshape(-1)].set(True, mode="drop")


def expert_signature(params, prompt, cfg) -> np.ndarray:
    """Predicted expert footprint of a prompt: bool [num_experts], from its
    first _PROBE_TOKENS tokens."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)[:_PROBE_TOKENS]
    valid = int(prompt.shape[0])
    if valid < _PROBE_TOKENS:
        prompt = np.pad(prompt, (0, _PROBE_TOKENS - valid))
    return np.asarray(_gate_probe(
        params, jnp.asarray(prompt, jnp.int32),
        jnp.asarray(valid, jnp.int32), cfg))


@dataclass
class _ChunkJob:
    """One in-flight chunked prefill: a claimed slot, reserved pages, and a
    private batch-1 decode state that fills one chunk per tick. Dense pools
    carry private KV rows in `state`; paged pools instead carry the claimed
    block-table row (`page_row`) and thread the pool's page store through
    each chunk run — the prefill scatters straight into the pool's pages."""
    req: Request
    slot: int
    state: dict
    prompt: np.ndarray            # right-padded to a chunk multiple
    pos: int = 0                  # next chunk start
    logits: object = None         # last chunk's logits
    page_row: np.ndarray | None = None


class ServingEngine:
    """Continuous-batching engine: submit requests any time, run ticks."""

    def __init__(self, params, cfg, *, num_slots: int = 8,
                 max_tokens: int = 256, max_queue: int = 0,
                 extras: dict | None = None, mesh=None,
                 prompt_buckets: bool = False, paged: bool = False,
                 page_size: int = 16, num_pages: int | None = None,
                 kv_quant: str | None = None,
                 prefill_chunk: int = 0, preemption: bool = False,
                 chaos: Chaos | None = None,
                 prefix_share: bool | None = None,
                 expert_aware: bool | None = None,
                 journal_dir: str | bool | None = None,
                 snapshot_every: int = 0):
        self.params = params
        self.mesh = mesh
        force = _env_on("REPRO_FORCE_PAGED") or \
            _env_on("REPRO_FORCE_PAGED_KERNEL")
        if not paged and force and paged_supported(cfg):
            # CI knob: run any supporting engine paged. Snap the page size
            # to a common divisor of max_tokens (and prefill_chunk, when
            # chunking is on — chunks must stay page-granular) so arbitrary
            # test pools stay legal; if no usable divisor exists, leave the
            # engine dense rather than crash a config that is valid unforced.
            g = math.gcd(page_size, max_tokens)
            if prefill_chunk:
                g = math.gcd(g, prefill_chunk)
            if g >= 4:
                paged = True
                page_size = g
        # Paged-attention realization knobs resolve into cfg HERE, before
        # anything jit-keyed on cfg is built: cfg is the static compile key,
        # so env reads at trace time would silently split/miss caches.
        # REPRO_FORCE_PAGED_KERNEL is the CI lane (paged pool + Pallas
        # kernel everywhere); REPRO_PAGED_GATHER is the escape hatch back to
        # the dense-gather path and wins when both are set.
        if _env_on("REPRO_FORCE_PAGED_KERNEL") and paged_supported(cfg):
            cfg = cfg.with_overrides(paged_attn="kernel")
        if _env_on("REPRO_PAGED_GATHER"):
            cfg = cfg.with_overrides(paged_attn="gather")
        # Quantized decode state resolves into cfg the same way (cfg is the
        # static compile key). The REPRO_KV_QUANT env lane silently no-ops
        # where the pool won't be paged or the page geometry can't tile int8
        # pages; the explicit kwarg is an API contract — SlotPool raises a
        # typed error when it can't honor it.
        if kv_quant is None:
            if _env_on("REPRO_KV_QUANT") and paged and paged_supported(cfg) \
                    and page_size % 8 == 0:
                cfg = cfg.with_overrides(kv_quant="int8")
        else:
            cfg = cfg.with_overrides(kv_quant=kv_quant)
        self.cfg = cfg
        self.pool = SlotPool(cfg, num_slots, max_tokens, extras, mesh=mesh,
                             paged=paged, page_size=page_size,
                             num_pages=num_pages)
        # --- prefix sharing / expert-aware admission knobs ---
        # resolved ONCE here (REPRO_FORCE_PAGED pattern): the env knobs are
        # semantics-preserving CI lanes, so they silently no-op on engines
        # whose shape can't support them; the explicit kwargs are API
        # contracts and raise instead.
        if prefix_share is None:
            prefix_share = _env_on("REPRO_PREFIX_SHARE") and self.pool.paged
        elif prefix_share and not self.pool.paged:
            raise ValueError("prefix sharing needs a paged pool (it is "
                             "copy-on-write block-table surgery)")
        self.prefix_share = bool(prefix_share)
        # expert-aware admission needs observable routing: a plain-attention
        # MoE stack (the gate probe reads the stacked layer-0 gate)
        moe_ok = (cfg.moe is not None and cfg.block == "attn"
                  and cfg.encoder_layers == 0 and cfg.cross_attn_every == 0)
        if expert_aware is None:
            expert_aware = _env_on("REPRO_EXPERT_AWARE") and moe_ok
        elif expert_aware and not moe_ok:
            raise ValueError("expert-aware admission needs a plain-attention "
                             "MoE config (it scores routing overlap)")
        self.expert_aware = bool(expert_aware)
        self.scheduler = (
            ExpertAwareScheduler(num_slots, max_tokens, max_queue,
                                 num_experts=cfg.moe.num_experts)
            if self.expert_aware
            else FIFOScheduler(num_slots, max_tokens, max_queue))
        self.prefix_index = (
            PrefixIndex(self.pool.alloc, self.pool.page_size)
            if self.prefix_share else None)
        self.prefix_hits = 0
        self.pages_shared = 0
        self.prefill_tokens_skipped = 0
        self.step_count = 0
        self.finished: dict[int, Request] = {}
        # monotone id assignment that survives recovery (itertools.count
        # can't be snapshotted; a recycled id would collide in the journal)
        self._next_id = 0
        if prefill_chunk:
            if not paged_supported(cfg):
                raise ValueError("chunked prefill is attention-family only")
            if max_tokens % prefill_chunk:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must divide "
                    f"max_tokens={max_tokens}")
            if paged and prefill_chunk % self.pool.page_size:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be page-granular "
                    f"(page_size={self.pool.page_size})")
        self.prefill_chunk = int(prefill_chunk)
        self._chunk_job: _ChunkJob | None = None
        self.chunk_ticks = 0
        # peak simultaneously-occupied engine capacity — occupied slots plus
        # the chunk-run lane — sampled at every admission and again after
        # the admission loop, BEFORE retirements. This is the
        # concurrent-stream count the paged-vs-dense benchmark gates on
        # (sampling after step() would miss streams that decode and retire,
        # or admit and instantly finish, on the same tick)
        self.peak_active = 0
        # pad prompts up to power-of-two buckets so prefill compiles once
        # per BUCKET instead of once per distinct prompt length (attention
        # families only — recurrent archs prefill step-by-step). Dense archs
        # reproduce the unbucketed streams exactly; MoE capacity constants
        # derive from the BUCKET length (ec_capacity(bucket) >
        # ec_capacity(true len)), so MoE streams are deterministic per
        # bucket but may differ from the unbucketed engine's.
        self.prompt_buckets = bool(
            prompt_buckets and cfg.block == "attn"
            and cfg.encoder_layers == 0 and cfg.cross_attn_every == 0)
        self.prefill_lengths: set[int] = set()
        # --- fault domain ---
        # explicit injector wins; otherwise the REPRO_CHAOS env lane
        self.chaos = chaos if chaos is not None else Chaos.from_env()
        if self.chaos is not None and self.chaos.preempt > 0 \
                and self.pool.paged:
            preemption = True      # forced evictions need the resume path
        if preemption and not self.pool.paged:
            raise ValueError("preemption needs a paged pool (eviction "
                             "snapshots are block-table surgery)")
        self.preemption = bool(preemption)
        # decode-tick supervisor: same determinism-makes-retry-safe argument
        # as the training loop's. max_retries must exceed the chaos
        # injector's max consecutive faults or the lane DoSes itself.
        self.supervisor = StepSupervisor(max_retries=3)
        self._preempted: dict[int, dict] = {}   # rid -> eviction snapshot
        self.preempted_total = 0
        self.resumed_total = 0
        self.rejected_full = 0
        self.rejected_oversized = 0
        self.audit_every_tick = _env_on("REPRO_AUDIT")
        if self.chaos is not None:
            # one reproducibility line per distinct config: a chaos CI
            # failure must be replayable from the log alone
            desc = self.chaos.describe()
            if desc not in _chaos_logged:
                _chaos_logged.add(desc)
                print(f"[repro.serving] {desc}", file=sys.stderr)
        # --- durability (serving/journal.py) ---
        # journal_dir=False disables even the env pickup (recover() builds
        # its engine first and attaches the journal after replay); the
        # REPRO_JOURNAL_DIR env lane follows the REPRO_FORCE_PAGED pattern
        # (silently no-ops on engines journaling can't support), while the
        # explicit kwarg is an API contract and raises instead.
        self.journal: EngineJournal | None = None
        self.recoveries = 0
        self.replayed_events = 0
        self.recovered_info: dict | None = None
        self.restart_count = int(
            os.environ.get("REPRO_SUPERVISE_GENERATION", "0") or 0)
        self._replay_expect: dict[int, list[int]] = {}
        self._tick_toks: dict[int, int] = {}
        self._heartbeat = os.environ.get("REPRO_HEARTBEAT") or None
        self._engine_extras = extras
        self._engine_kw = dict(
            num_slots=num_slots, max_tokens=self.pool.max_tokens,
            max_queue=max_queue, paged=self.pool.paged,
            page_size=self.pool.page_size, num_pages=self.pool.num_pages,
            kv_quant=self.cfg.kv_quant,
            prefill_chunk=self.prefill_chunk, preemption=self.preemption,
            prompt_buckets=self.prompt_buckets,
            prefix_share=self.prefix_share, expert_aware=self.expert_aware)
        if journal_dir is None and journal_dir is not False:
            env_dir = os.environ.get("REPRO_JOURNAL_DIR", "").strip()
            if env_dir and self.pool.paged and extras is None:
                # unique per engine: one journal describes ONE engine's
                # lifecycle (sweeps build many engines per process)
                journal_dir = os.path.join(
                    env_dir, f"engine_{os.getpid()}_{id(self):x}")
        if isinstance(journal_dir, str):
            self._attach_journal(journal_dir, snapshot_every)

    # ------------------------------------------------------------- submission

    def submit(self, prompt, max_new_tokens: int, *, eos_id: int | None = None,
               extras: dict | None = None, arrival_step: int = 0,
               request_id: int | None = None, temperature: float = 0.0,
               top_p: float = 1.0, seed: int | None = None,
               priority: int = 0, deadline_s: float | None = None,
               max_wall_s: float | None = None) -> int:
        """Queue a request. `arrival_step` > current step defers arrival to
        that engine tick (trace replay). `temperature` > 0 switches the
        request's rows to temperature/top-p sampling (greedy rows in the
        same pool stay bit-identical). `priority` orders admission (lower =
        earlier; FIFO within a level). `deadline_s`/`max_wall_s` bound the
        request's wall clock from submission / first admission — exceeded
        budgets retire it with status TIMEOUT. Raises RequestTooLarge for a
        request that could never fit the pool and QueueFull (carrying the
        backlog depth) at max_queue — both counted in stats()["rejected"].
        Returns the request id."""
        if self.journal is not None and extras is not None:
            raise ValueError(
                "journaled engines reject per-request extras: cross-attn "
                "memory is neither journaled nor snapshotted, so a "
                "recovered re-prefill could not reproduce the stream")
        rid = request_id if request_id is not None else self._next_id
        self._next_id = max(self._next_id, rid + 1)
        req = Request(
            request_id=rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=int(max_new_tokens),
            eos_id=eos_id,
            extras=extras,
            arrival_step=arrival_step,
            priority=int(priority),
            temperature=float(temperature),
            top_p=float(top_p),
            seed=seed,
            deadline_s=deadline_s,
            max_wall_s=max_wall_s,
        )
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not (0.0 < req.top_p <= 1.0):
            raise ValueError("top_p must be in (0, 1]")
        if self.pool.paged:
            # the paged analogue of the max_tokens check: a request whose
            # worst case exceeds the whole page pool could NEVER reserve,
            # so admission would stall the queue forever
            need = self.pool.pages_needed(req)
            usable = self.pool.num_pages - 1          # page 0 is the null page
            if need > usable:
                self.rejected_oversized += 1
                raise RequestTooLarge(
                    f"request {rid}: prompt({req.prompt_len}) + "
                    f"max_new_tokens({req.max_new_tokens}) needs {need} "
                    f"pages of {self.pool.page_size} tokens, but the pool "
                    f"only has {usable} usable pages")
        if self.expert_aware:
            if self.mesh is None:
                req.expert_sig = expert_signature(
                    self.params, req.prompt, self.cfg)
            else:
                with self.mesh:
                    req.expert_sig = expert_signature(
                        self.params, req.prompt, self.cfg)
        req.arrival_time = req.submit_time = time.monotonic()
        try:
            self.scheduler.submit(req, now_step=self.step_count)
        except QueueFull:
            self.rejected_full += 1
            raise
        except RequestTooLarge:
            self.rejected_oversized += 1
            raise
        if self.journal is not None:
            # journaled AFTER scheduler acceptance: a rejected request has
            # no lifecycle to recover
            self.journal.append("submit", req=request_record(req))
        return rid

    def cancel(self, rid: int) -> bool:
        """Retire request `rid` wherever it is — queued (or trace-pending),
        parked preempted, mid-chunk-prefill, or actively decoding — freeing
        its slot/pages and marking it CANCELLED (partial tokens kept in
        Request.tokens). Returns False if the id is unknown or already
        terminal."""
        if rid in self.finished:
            return False
        done: list[Request] = []
        req = self.scheduler.remove(rid)
        if req is not None:
            self._preempted.pop(rid, None)
            self._mark_finished(req, RequestStatus.CANCELLED, done,
                                reason="cancelled")
            return True
        job = self._chunk_job
        if job is not None and job.req.request_id == rid:
            self.pool.release_pages(rid)   # claimed chunk pages + reservation
            self._chunk_job = None
            self._mark_finished(job.req, RequestStatus.CANCELLED, done,
                                reason="cancelled")
            return True
        for slot, owner in enumerate(self.pool.owner):
            if owner is not None and owner.request_id == rid:
                self._retire_slot(slot, RequestStatus.CANCELLED, done,
                                  reason="cancelled")
                return True
        return False

    # ------------------------------------------------------------------ ticks

    def step(self) -> list[Request]:
        """One engine tick: expire blown deadlines, advance the
        chunked-prefill job (if any) by one chunk, admit due+queued requests
        into free slots (evicting lower-priority streams under page
        pressure when preemption is on), then advance every occupied slot
        one token under the tick supervisor. Returns requests finished on
        this tick."""
        done: list[Request] = []

        self._expire(time.monotonic(), done)

        for req in self.scheduler.poll(self.step_count):
            req.arrival_time = time.monotonic()

        if self._chunk_job is not None:
            self._advance_chunk_job(done)

        # admission loop; a chaos pressure event skips it for one tick
        # (delays admissions without reordering them)
        if self.chaos is None or not self.chaos.pressure_event():
            while True:
                free = self.pool.free_slots()
                if self._chunk_job is not None and \
                        self._chunk_job.slot in free:
                    free.remove(self._chunk_job.slot)
                busy = self.pool.num_active() + \
                    (1 if self._chunk_job is not None else 0)
                if self.expert_aware:
                    # refresh the cost model's view of the active batch —
                    # each admission changes it, so re-note every iteration
                    self.scheduler.note_active(
                        [o.expert_sig for o in self.pool.owner
                         if o is not None])
                req = self.scheduler.next_admission(
                    busy, can_admit=self._can_admit)
                if req is None:
                    # blocked head + preemption on: evict a lower-priority
                    # active stream and retry the admission
                    if self.preemption and self._preempt_for_head():
                        continue
                    break
                if req.request_id in self._preempted:
                    self._resume(free[0], req)
                elif self.prefill_chunk and \
                        req.prompt_len > self.prefill_chunk and \
                        self._full_hit(req) is None and \
                        self._ext_hit(req) is None:
                    # long prompt with no cached prefix: chunked prefill.
                    # A prefix hit skips (part of) the prefill, so it takes
                    # the synchronous admission path below instead of
                    # queueing behind the single chunk lane.
                    self._start_chunk_job(free[0], req)
                else:
                    self._admit_any(free[0], req, done)

        self._note_occupancy()

        if self.chaos is not None:
            self._inject_state_faults()

        if self.pool.any_active():
            self.pool.grow_active()
            toks, state, ok, new_keys = self._supervised_decode()
            self.pool.state = self.pool._pin(state)
            if new_keys is not None:
                # keys advance only after the tick COMMITS — a supervisor
                # retry must re-run with the same keys or sampled streams
                # would silently fork
                self.pool.keys = np.array(new_keys, dtype=np.uint32)
            self.pool.note_decoded()
            toks = np.asarray(toks)
            ok = np.asarray(ok)
            self.step_count += 1
            for slot, req in enumerate(self.pool.owner):
                if req is None:
                    continue
                if not ok[slot]:
                    # quarantine: this row's logits went non-finite; retire
                    # it FAILED (no garbage token appended) — cohabiting
                    # rows are untouched (every batched op is row-wise
                    # independent)
                    self._retire_slot(slot, RequestStatus.FAILED, done,
                                      reason="non-finite logits")
                    continue
                tok = int(toks[slot])
                req.tokens.append(tok)
                self._journal_token(req, tok)
                self.pool.pending[slot] = tok
                self.pool.remaining[slot] -= 1
                if self.pool.remaining[slot] <= 0 or \
                        (req.eos_id is not None and tok == req.eos_id):
                    self._finish(slot, done)
        elif self._chunk_job is not None:
            self.step_count += 1              # prefill-only tick
        else:
            # idle tick — jump straight to the next trace arrival
            nxt = self.scheduler.next_arrival_step()
            self.step_count = max(self.step_count + 1,
                                  nxt if nxt is not None else 0)

        if self.journal is not None:
            if self._tick_toks:
                # ONE durable record per decode tick — the token watermark
                # every recovered stream is prefix-asserted against
                self.journal.append("tick", step=self.step_count,
                                    toks=dict(self._tick_toks))
                self._tick_toks.clear()
            if self.step_count - self.journal.last_snapshot_step >= \
                    self.journal.snapshot_every:
                self.journal.commit_snapshot(self._snapshot_payload(),
                                             self.step_count)
        if self._heartbeat:
            # liveness signal for the process supervisor (mtime staleness)
            with open(self._heartbeat, "a"):
                os.utime(self._heartbeat, None)
        self._maybe_crash()

        if self.audit_every_tick:
            self._audit()
        return done

    def has_work(self) -> bool:
        """Anything left to do — queued/deferred requests, occupied slots,
        or an in-flight chunked prefill. The run() drain condition, public
        so external tick loops (benchmarks) stay in sync with it."""
        return self.scheduler.has_pending() or self.pool.any_active() \
            or self._chunk_job is not None

    def run(self) -> dict[int, Request]:
        """Tick until queue, trace, chunk run and pool drain; returns
        finished requests keyed by request id (token streams in
        Request.tokens). Draining also flushes the prefix index: run() means
        "this workload is over", so the cache's page pins are dropped and a
        fully-retired pool again holds zero pages (open-ended tick loops —
        `while has_work(): step()` — keep the cache warm across requests,
        which is where prefix sharing actually pays)."""
        while self.has_work():
            self.step()
        if self.prefix_index is not None:
            self.pool.scrub_released(self.prefix_index.flush())
        return self.finished

    # -------------------------------------------------------------- internals

    def _note_occupancy(self) -> None:
        """Record peak engine occupancy: occupied slots + the in-flight
        chunk run (it holds a claimed slot and reserved pages)."""
        self.peak_active = max(
            self.peak_active,
            self.pool.num_active() + (1 if self._chunk_job is not None else 0))

    def _can_admit(self, req: Request) -> bool:
        """Admission gate with page-pressure cache reclaim: the prefix
        index's node pins are OPPORTUNISTIC, a blocked admission is not —
        if the head doesn't fit, evict LRU prefix-cache entries (scrubbing
        the freed pages) until it does or the cache is dry. Reclaim happens
        before live-stream preemption ever gets consulted, and is gated on
        `pool.can_admit` so a chunk-lane wait (not page pressure) never
        drains the cache."""
        ok = self._can_admit_now(req)
        while not ok and self.prefix_index is not None \
                and len(self.prefix_index) and not self.pool.can_admit(req):
            self.pool.scrub_released(self.prefix_index.reclaim_one())
            ok = self._can_admit_now(req)
        return ok

    def _can_admit_now(self, req: Request) -> bool:
        """One admission-gate evaluation: pages must be reservable (paged
        pool), and a to-be-chunked prompt must wait for the single
        chunk-run lane. A blocked head blocks the queue — overtaking would
        break the starvation-freedom the priority heap guarantees. A
        PREEMPTED head resumes from its snapshot: it needs only its
        remaining worst case and never re-prefills, so the chunk lane is
        irrelevant to it. A prefix-index hit discounts the shared pages
        from the gate — copy-on-write references consume nothing from the
        free list, so an admission the cache mostly covers squeezes in
        where a cold one couldn't."""
        if req.request_id in self._preempted:
            return self.pool.can_resume(self._preempted[req.request_id])
        if self.prefix_share:
            entry = self._full_hit(req)
            if entry is not None:
                return self.pool.alloc.can_reserve(
                    self.pool.pages_needed(req) - len(entry["nodes"]))
            shared = self._ext_hit(req)
            if shared is not None:
                return self.pool.alloc.can_reserve(
                    self.pool.pages_needed(req) - len(shared))
        if self.prefill_chunk and req.prompt_len > self.prefill_chunk \
                and self._chunk_job is not None:
            return False
        return self.pool.can_admit(req)

    # -------------------------------------------------------------- preemption

    def _preempt_for_head(self) -> bool:
        """The head of the admission heap is blocked on slots or pages:
        evict ONE active stream of strictly lower priority (greatest
        priority value; ties broken toward the most recent admission —
        least work lost) and report whether anything was evicted. The
        admission loop retries after each eviction, so exactly as many
        victims fall as the head needs. An ExpertAwareScheduler remembers
        WHICH candidate its cost model chose before the page gate blocked it
        (`last_blocked`) — pages are freed for that request, not for the
        arrival-order head it may have skipped; within a priority class the
        victim with the most experts UNIQUE to it falls first (evicting it
        shrinks the tick's expert set the most)."""
        if not (self.pool.paged and self.scheduler.queue):
            return False
        head = getattr(self.scheduler, "last_blocked", None) or \
            self.scheduler.queue[0][2]
        if head.request_id not in self._preempted and self.prefill_chunk \
                and head.prompt_len > self.prefill_chunk \
                and self._full_hit(head) is None \
                and self._ext_hit(head) is None \
                and self._chunk_job is not None:
            return False     # blocked on the chunk LANE — eviction can't help
        victims = [(owner.priority, self._victim_rank(slot),
                    owner.admit_step, slot)
                   for slot, owner in enumerate(self.pool.owner)
                   if owner is not None and owner.priority > head.priority]
        if not victims:
            return False
        self._preempt(max(victims)[3])
        return True

    def _victim_rank(self, slot: int) -> int:
        """Preemption cost model (expert-aware engines): victims touching
        more experts nobody else needs rank higher. 0 under plain FIFO, so
        the historical (priority, admit_step) order is unchanged."""
        if not self.expert_aware:
            return 0
        others = [o.expert_sig for s, o in enumerate(self.pool.owner)
                  if o is not None and s != slot]
        return self.scheduler.victim_bonus(
            self.pool.owner[slot].expert_sig, others)

    def _preempt(self, slot: int) -> None:
        """Evict the stream in `slot`: host-snapshot its live pages + GO
        rows + cursor, free its pages, park it PREEMPTED, and put it back
        in the admission heap under its original submit order."""
        req = self.pool.owner[slot]
        snap = self.pool.snapshot(slot)
        self.pool.retire(slot)
        req.slot = -1
        req.status = RequestStatus.PREEMPTED
        req.preemptions += 1
        self._preempted[req.request_id] = snap
        self.scheduler.requeue(req)
        self.preempted_total += 1

    def _resume(self, slot: int, req: Request) -> None:
        """Un-park a preempted stream into a free slot via block-table
        surgery (SlotPool.restore) — no re-prefill, bit-identical to an
        uninterrupted run."""
        snap = self._preempted.pop(req.request_id)
        self.pool.restore(slot, req, snap)
        req.status = RequestStatus.ACTIVE
        self.resumed_total += 1
        self._note_occupancy()

    # ------------------------------------------------------ faults & deadlines

    def _expire(self, now: float, done: list[Request]) -> None:
        """Retire every request whose wall budget ran out, wherever it is:
        queued/pending/preempted (scheduler heaps), mid-chunk-prefill, or
        actively decoding."""
        for req in self.scheduler.expire(now):
            self._preempted.pop(req.request_id, None)
            self._mark_finished(req, RequestStatus.TIMEOUT, done,
                                reason="deadline exceeded before admission"
                                if req.admit_time == 0 else
                                "deadline exceeded while preempted")
        job = self._chunk_job
        if job is not None and job.req.expired(now):
            self.pool.release_pages(job.req.request_id)
            self._chunk_job = None
            self._mark_finished(job.req, RequestStatus.TIMEOUT, done,
                                reason="deadline exceeded during prefill")
        for slot, req in enumerate(self.pool.owner):
            if req is not None and req.expired(now):
                self._retire_slot(slot, RequestStatus.TIMEOUT, done,
                                  reason="deadline exceeded")

    def _inject_state_faults(self) -> None:
        """Chaos state-level injections for this tick: a forced eviction
        (exercises the snapshot/restore path — semantics-preserving) and/or
        a poisoned slot (NaN KV -> the quarantine path, off by default in
        the env lane)."""
        active = [s for s, o in enumerate(self.pool.owner) if o is not None]
        if self.preemption and self.pool.paged:
            victim = self.chaos.preempt_victim(active)
            if victim is not None:
                self._preempt(victim)
                active.remove(victim)
        victim = self.chaos.nan_victim(active)
        if victim is not None:
            self.pool.poison_slot(victim)

    def _supervised_decode(self):
        """Run the jitted decode tick under the StepSupervisor: injected or
        real transient errors are retried with IDENTICAL inputs (the tick is
        functional — pool state and sampling keys are only committed after
        success), hard failures raise RestartRequired."""
        def tick():
            if self.chaos is not None:
                self.chaos.maybe_tick_fault(self.step_count)
            return self._run_decode_step()
        return self.supervisor.run(tick, step=self.step_count)

    def _run_decode_step(self):
        """One jitted decode tick, inside the mesh context when sharded (the
        jit cache keys on the ambient mesh, so the sharded and unsharded
        variants coexist in one process). Pure-greedy pools run the lean
        greedy step; a pool with any sampling request runs the sampling
        variant (greedy rows inside it stay bit-identical). Returns
        (tokens, state, ok, new_keys-or-None) WITHOUT committing anything
        to the pool — the caller commits, so a supervisor retry is pure."""
        sampling = bool((self.pool.temps > 0).any())
        args = (self.params, self.pool.state, jnp.asarray(self.pool.pending),
                jnp.asarray(self.pool.active_mask()))
        if sampling:
            args += (jnp.asarray(self.pool.temps),
                     jnp.asarray(self.pool.top_ps),
                     jnp.asarray(self.pool.keys))
        fn = _decode_step_sampled if sampling else _decode_step
        if self.mesh is None:
            out = fn(*args, self.cfg)
        else:
            with self.mesh:
                out = fn(*args, self.cfg)
        if sampling:
            return out                       # (toks, state, ok, new_keys)
        toks, state, ok = out
        return toks, state, ok, None

    def _bucketed(self, prompt: np.ndarray):
        """Pad the prompt up to its power-of-two bucket (capped at the
        pool's max_tokens); returns (padded [S_b], valid_len or None)."""
        n = int(prompt.shape[0])
        b = 8
        while b < n:
            b *= 2
        b = min(b, self.pool.max_tokens)
        if b <= n:
            return prompt, None
        return np.pad(prompt, (0, b - n)), n

    def _first_token(self, req: Request, logits):
        """The request's first output token from its prefill logits — argmax,
        or sampled when the request asks for temperature > 0. Returns
        (token, advanced PRNG key or None)."""
        if req.temperature > 0:
            seed = req.seed if req.seed is not None else req.request_id
            k_use, key_next = jax.random.split(jax.random.PRNGKey(seed))
            first = int(_sample_tokens(
                logits, k_use[None],
                jnp.full((1,), req.temperature, jnp.float32),
                jnp.full((1,), req.top_p, jnp.float32))[0])
            return first, key_next
        return int(jnp.argmax(logits, axis=-1)[0]), None

    def _admit(self, slot: int, req: Request, done: list[Request]) -> None:
        """Prefill a request into `slot` mid-flight: fills that row's KV and
        GO cache entries and emits the request's first token (from the
        prefill logits — exactly what static generate() emits first; sampled
        from them when the request asks for temperature > 0)."""
        prompt, valid_len = (self._bucketed(req.prompt) if self.prompt_buckets
                             else (req.prompt, None))
        self.prefill_lengths.add(int(prompt.shape[0]))
        slot_state, logits = _jit_prefill(
            self.params, jnp.asarray(prompt, jnp.int32)[None, :],
            self.cfg, req.extras or {}, self.pool.max_tokens,
            None if valid_len is None else jnp.asarray(valid_len, jnp.int32))
        self._install(slot, req, slot_state, logits, done)

    def _install(self, slot: int, req: Request, slot_state, logits,
                 done: list[Request], page_row=None, *,
                 deposit: bool = True) -> None:
        """Shared tail of one-shot, prefix-extension and chunked admission:
        emit the first token, splat the prefilled state into the pool row,
        handle an immediate EOS/length finish. `page_row` marks a paged run
        whose pages are already claimed and filled. Non-finite prefill
        logits quarantine the request to FAILED before it ever occupies the
        slot. With prefix sharing on, the freshly-admitted prompt deposits
        its prefill artifacts into the prefix index (`deposit=False` for
        chunk runs — a chunked expert-choice prefill routes at per-chunk
        capacities, so its GO rows and logits are not the one-shot
        artifacts the cache promises)."""
        if not bool(np.isfinite(np.asarray(logits)).all()):
            if page_row is not None and self.pool.paged:
                self.pool.release_pages(req.request_id)  # claimed run pages
            self._mark_finished(req, RequestStatus.FAILED, done,
                                reason="non-finite prefill logits")
            return
        first, key_next = self._first_token(req, logits)
        req.admit_step = self.step_count
        req.admit_time = time.monotonic()
        req.status = RequestStatus.ACTIVE
        req.tokens.append(first)
        self._journal_token(req, first, install=True)
        self.pool.admit(slot, req, slot_state, first, key=key_next,
                        page_row=page_row)
        if self.expert_aware:
            self._refine_sig(slot, req)
            self.scheduler.observe(req.expert_sig)
        if deposit:
            self._deposit(slot, req, logits)
        self._note_occupancy()       # before a possible instant retirement
        if self.pool.remaining[slot] <= 0 or \
                (req.eos_id is not None and first == req.eos_id):
            self._finish(slot, done)

    def _refine_sig(self, slot: int, req: Request) -> None:
        """Replace the submit-time gate-probe prediction with the routing
        the prefill actually OBSERVED, where observable: an expert-choice
        arch's GO cache records exactly which (layer, expert, token) pairs
        were kept — union over layers/capacity beats any probe. Unless the
        union SATURATES: expert-choice hands every expert its capacity of
        tokens whenever the prompt is long enough, and an all-experts
        signature carries no grouping signal — keep the sparse layer-0
        probe instead (the scheduler only needs a consistent fingerprint,
        not ground truth)."""
        if "go" not in self.pool.state:
            return
        tid = np.asarray(self.pool.state["go"].token_ids[:, slot])  # [L,E,k]
        sig = (tid >= 0).any(axis=(0, 2))
        if not sig.all():
            req.expert_sig = sig

    # --------------------------------------------------------- prefix sharing

    def _full_hit(self, req: Request):
        """Exact full-prompt prefix-index entry for `req`, or None. Requests
        with per-request extras (cross-attn memory) never hit: their prefill
        state depends on more than the prompt tokens."""
        if self.prefix_index is None or req.extras is not None:
            return None
        return self.prefix_index.lookup_full(req.prompt)

    def _ext_hit(self, req: Request):
        """Shared page chain for a page-aligned PREFIX of `req`'s prompt, or
        None. DENSE archs only: an MoE prefill routes with whole-sequence
        competition (expert-choice capacity, batch-level token ranks), so a
        prefix's KV under a longer prompt is not the KV this prompt's
        prefill would produce — only the full-prompt exact match (where the
        donor ran the identical prefill) is reusable for MoE. For dense
        attention the prefix KV is position-local and exact, and the repo
        pins chunked==one-shot prefill, so resuming prefill past the prefix
        stays bit-identical."""
        if self.prefix_index is None or req.extras is not None \
                or self.cfg.moe is not None:
            return None
        shared = self.prefix_index.lookup_prefix(req.prompt)
        ps = self.pool.page_size
        while shared and len(shared) * ps >= req.prompt_len:
            # the whole prompt is covered but no full entry exists (evicted,
            # or the match is a prefix of a LONGER cached prompt): re-prefill
            # the last page so the admission has prefill logits to emit from
            shared.pop()
        if not shared:
            return None
        if self.prefill_chunk and \
                req.prompt_len - len(shared) * ps > self.prefill_chunk:
            return None    # remainder is still a long prompt: chunk lane
        return shared

    def _admit_any(self, slot: int, req: Request,
                   done: list[Request]) -> None:
        """Admission dispatch: full-prompt cache hit (zero prefill), dense
        prefix-extension hit (prefill only the remainder), or cold one-shot
        prefill."""
        entry = self._full_hit(req)
        if entry is not None:
            self._admit_from_cache(slot, req, entry, done)
            return
        shared = self._ext_hit(req)
        if shared is not None:
            self._admit_prefix_ext(slot, req, shared, done)
            return
        self._admit(slot, req, done)

    def _admit_from_cache(self, slot: int, req: Request, entry: dict,
                          done: list[Request]) -> None:
        """Zero-compute admission from a full-prompt prefix-index entry:
        O(1) block-table surgery instead of O(prompt) prefill. The first
        token comes from the entry's cached prefill logits — the SAME
        logits the donor's prefill emitted, so greedy streams are
        bit-identical to a cold admission (and sampling requests draw from
        the exact distribution under their own temperature/seed). The
        donor's finite-logits check already vetted the entry."""
        shared = self.prefix_index.entry_pages(entry)
        first, key_next = self._first_token(req, jnp.asarray(entry["logits"]))
        req.admit_step = self.step_count
        req.admit_time = time.monotonic()
        req.status = RequestStatus.ACTIVE
        req.tokens.append(first)
        self._journal_token(req, first, install=True)
        self.pool.admit_from_prefix(slot, req, shared, entry, first,
                                    key=key_next)
        if req.expert_sig is None and entry["sig"] is not None:
            req.expert_sig = entry["sig"]
        if self.expert_aware:
            self.scheduler.observe(req.expert_sig)
        self.prefix_hits += 1
        self.pages_shared += len(shared)
        self.prefill_tokens_skipped += req.prompt_len
        self._note_occupancy()
        if self.pool.remaining[slot] <= 0 or \
                (req.eos_id is not None and first == req.eos_id):
            self._finish(slot, done)

    def _admit_prefix_ext(self, slot: int, req: Request, shared,
                          done: list[Request]) -> None:
        """Dense prefix-extension admission: map the cached prefix's pages
        copy-on-write and prefill ONLY the remainder of the prompt in one
        paged chunk run (prefill_chunk starting past the prefix, attending
        over the shared pages — the same machinery chunked prefill uses,
        minus the chunks the cache already holds)."""
        ps = self.pool.page_size
        start = len(shared) * ps
        row = self.pool.claim_prefix_ext_pages(req, shared)
        rem = req.prompt_len - start
        padded = -(-rem // ps) * ps
        chunk = np.pad(req.prompt[start:], (0, padded - rem))
        # quantized pools: the batch-1 skeleton stays UNQUANTIZED (chunk-run
        # GO rows are f32 by the chunk-lane contract — write_decode_slot
        # quantizes them once at the final splat); only the pool's int8 page
        # store + its scales thread through the run
        quant = self.pool.quant
        skel_cfg = (self.cfg.with_overrides(kv_quant="none")
                    if quant else self.cfg)
        state = init_decode_state(skel_cfg, 1, self.pool.max_tokens,
                                  req.extras or {},
                                  paged=(1, ps))
        del state["k_pages"], state["v_pages"]
        state["block_table"] = jnp.asarray(row, jnp.int32)[None, :]
        state["k_pages"] = self.pool.state["k_pages"]
        state["v_pages"] = self.pool.state["v_pages"]
        if quant:
            state["k_scales"] = self.pool.state["k_scales"]
            state["v_scales"] = self.pool.state["v_scales"]
        args = (self.params, state, jnp.asarray(chunk, jnp.int32)[None, :],
                self.cfg, jnp.asarray(start, jnp.int32),
                jnp.asarray(rem, jnp.int32))
        if self.mesh is not None:
            with self.mesh:
                state, logits = _jit_prefill_chunk(*args)
        else:
            state, logits = _jit_prefill_chunk(*args)
        self.pool.state["k_pages"] = state.pop("k_pages")
        self.pool.state["v_pages"] = state.pop("v_pages")
        if quant:
            self.pool.state["k_scales"] = state.pop("k_scales")
            self.pool.state["v_scales"] = state.pop("v_scales")
        self.pool.state = self.pool._pin(self.pool.state)
        self.prefix_hits += 1
        self.pages_shared += len(shared)
        self.prefill_tokens_skipped += start
        self._install(slot, req, state, logits, done, page_row=row)

    def _deposit(self, slot: int, req: Request, logits) -> None:
        """Record a freshly-admitted prompt in the prefix index: pin its
        full pages as radix nodes (refcount bump — nothing moves) and cache
        the artifacts pages alone can't give a future consumer — the tail
        KV past the last full page (it sits in this request's PRIVATE page,
        which its decode will overwrite), the GO rows (TopKUpdate history —
        not recomputable), and the prefill logits (the consumer's first
        token without a forward pass). Deposited at ADMISSION, so the entry
        serves consumers while the donor is still live AND after it retires
        (the node refcounts keep the pages alive — "recently-retired"
        donors cost nothing extra)."""
        idx = self.prefix_index
        if idx is None or req.extras is not None:
            return
        ps = self.pool.page_size
        row = self.pool.block_table[slot]
        n_full = req.prompt_len // ps
        tail = req.prompt_len - n_full * ps
        tail_k = tail_v = tail_ks = tail_vs = None
        if tail:
            pid = int(row[n_full])
            tail_k = np.asarray(self.pool.state["k_pages"][:, pid, :tail])
            tail_v = np.asarray(self.pool.state["v_pages"][:, pid, :tail])
            if self.pool.quant:
                # int8 tail bytes are meaningless without their page scales
                tail_ks = np.asarray(self.pool.state["k_scales"][:, pid])
                tail_vs = np.asarray(self.pool.state["v_scales"][:, pid])
        go = None
        if "go" in self.pool.state:
            go = jax.tree.map(lambda a: np.asarray(a[:, slot]),
                              self.pool.state["go"])
        go_scales = None
        if "go_scales" in self.pool.state:
            go_scales = np.asarray(self.pool.state["go_scales"][:, slot])
        released = idx.deposit(
            req.prompt, row[:n_full], tail_k=tail_k, tail_v=tail_v, go=go,
            logits=np.asarray(logits, np.float32).reshape(1, -1),
            sig=req.expert_sig, tail_ks=tail_ks, tail_vs=tail_vs,
            go_scales=go_scales)
        self.pool.scrub_released(released)

    # ---------------------------------------------------------- chunk prefill

    def _start_chunk_job(self, slot: int, req: Request) -> None:
        """Claim `slot` and the request's worst-case pages, then begin
        filling one chunk per tick. Dense pools fill a private batch-1
        state; paged pools claim the request's pages up front
        (claim_chunk_pages) and prefill straight into the pool's page store
        — no dense [1, max_tokens] KV copy ever exists."""
        Cs = self.prefill_chunk
        padded = -(-req.prompt_len // Cs) * Cs
        prompt = np.pad(req.prompt, (0, padded - req.prompt_len))
        page_row = None
        if self.pool.paged:
            page_row = self.pool.claim_chunk_pages(req)
            # batch-1 paged view: position/GO/block-table only — the page
            # store itself is threaded in from the pool at each chunk tick.
            # Quantized pools keep the skeleton UNQUANTIZED: its GO rows
            # accumulate in f32 across chunks (go_cache_merge reads float
            # outputs) and quantize once at the final write_decode_slot
            # splat; the pool's int8 pages + scales thread through per tick.
            skel_cfg = (self.cfg.with_overrides(kv_quant="none")
                        if self.pool.quant else self.cfg)
            state = init_decode_state(skel_cfg, 1, self.pool.max_tokens,
                                      req.extras or {},
                                      paged=(1, self.pool.page_size))
            del state["k_pages"], state["v_pages"]
            state["block_table"] = jnp.asarray(page_row, jnp.int32)[None, :]
        else:
            state = init_decode_state(self.cfg, 1, self.pool.max_tokens,
                                      req.extras or {})
            self.pool.reserve_pages(req)
        self._chunk_job = _ChunkJob(req=req, slot=slot, state=state,
                                    prompt=prompt, page_row=page_row)
        self._advance_chunk_job_once()

    def _advance_chunk_job(self, done: list[Request]) -> None:
        self._advance_chunk_job_once()
        job = self._chunk_job
        if job is not None and job.pos >= len(job.prompt):
            self._chunk_job = None
            self._install(job.slot, job.req, job.state, job.logits, done,
                          page_row=job.page_row, deposit=False)

    def _advance_chunk_job_once(self) -> None:
        job = self._chunk_job
        Cs = self.prefill_chunk
        chunk = job.prompt[job.pos:job.pos + Cs]
        valid = min(Cs, job.req.prompt_len - job.pos)
        paged = job.page_row is not None
        if paged:
            # thread the pool's page store through the chunk run: the chunk
            # scatters its KV into the job's claimed pages (disjoint from
            # every active slot's), interleaved decode ticks touch only
            # other pages, so ownership transfers cleanly back each tick
            job.state["k_pages"] = self.pool.state["k_pages"]
            job.state["v_pages"] = self.pool.state["v_pages"]
            if self.pool.quant:
                job.state["k_scales"] = self.pool.state["k_scales"]
                job.state["v_scales"] = self.pool.state["v_scales"]
        args = (self.params, job.state,
                jnp.asarray(chunk, jnp.int32)[None, :], self.cfg,
                jnp.asarray(job.pos, jnp.int32), jnp.asarray(valid, jnp.int32))
        if paged and self.mesh is not None:
            with self.mesh:
                job.state, job.logits = _jit_prefill_chunk(*args)
        else:
            job.state, job.logits = _jit_prefill_chunk(*args)
        if paged:
            self.pool.state["k_pages"] = job.state.pop("k_pages")
            self.pool.state["v_pages"] = job.state.pop("v_pages")
            if self.pool.quant:
                self.pool.state["k_scales"] = job.state.pop("k_scales")
                self.pool.state["v_scales"] = job.state.pop("v_scales")
            self.pool.state = self.pool._pin(self.pool.state)
        job.pos += Cs
        self.chunk_ticks += 1

    def _finish(self, slot: int, done: list[Request]) -> None:
        self._retire_slot(slot, RequestStatus.DONE, done)

    def _retire_slot(self, slot: int, status: RequestStatus,
                     done: list[Request], reason: str | None = None) -> None:
        """Retire an ACTIVE slot into terminal `status`: frees the slot
        (pages back to the allocator, GO rows to -inf) and records the
        outcome. A FAILED retirement is a quarantine — its decode state is
        non-finite, so its pages are scrubbed before the allocator can hand
        them to another stream (NaN survives 0-weight masking)."""
        req = self.pool.retire(slot, scrub=status is RequestStatus.FAILED)
        self._mark_finished(req, status, done, reason=reason)

    def _mark_finished(self, req: Request, status: RequestStatus,
                       done: list[Request], reason: str | None = None) -> None:
        req.status = status
        req.fail_reason = reason
        req.finish_step = self.step_count
        req.finish_time = time.monotonic()
        self.finished[req.request_id] = req
        done.append(req)
        if self.journal is not None:
            self.journal.append("terminal", rid=req.request_id,
                                status=status.value, reason=reason)

    # -------------------------------------------------------------- durability

    def _attach_journal(self, directory: str, snapshot_every: int = 0) -> None:
        """Open the engine's write-ahead journal and commit the initial
        snapshot. Journaling rides on the paged pool's host-side snapshot
        contract (SlotPool.snapshot) — dense pools and engines with extras
        (cross-attn memory is not snapshotted) refuse it."""
        if not self.pool.paged:
            raise ValueError("journaling needs a paged pool (engine "
                             "snapshots are SlotPool.snapshot block-table "
                             "surgery)")
        if self._engine_extras is not None:
            raise ValueError("journaling rejects engine extras: cross-attn "
                             "memory is not part of the snapshot payload")
        self.journal = EngineJournal(
            directory, snapshot_every=snapshot_every or 32)
        self.journal.commit_snapshot(self._snapshot_payload(),
                                     self.step_count)

    def _journal_token(self, req: Request, tok: int, *,
                       install: bool = False) -> None:
        """Journal one emitted token and check it against the recovery
        oracle: tokens the CRASHED process journaled are a prefix-assertion
        on the recovered streams — re-decoded output must reproduce every
        watermarked token bit-for-bit before producing anything new."""
        exp = self._replay_expect.get(req.request_id)
        if exp:
            want = exp.pop(0)
            if not exp:
                del self._replay_expect[req.request_id]
            assert tok == want, (
                f"recovery divergence: request {req.request_id} emitted "
                f"token {tok} where the journal watermark says {want}")
        if self.journal is None:
            return
        if install:
            self.journal.append("install", rid=req.request_id,
                                step=self.step_count, token=tok)
        else:
            self._tick_toks[req.request_id] = tok

    def _maybe_crash(self) -> None:
        """Chaos crash-class injection: die by SIGKILL at this tick —
        straight away ("kill"), after tearing the journal's last record
        mid-write ("torn"), or after materializing the next snapshot
        WITHOUT its COMMITTED marker ("snap"). Journaled engines only: the
        whole point is proving recover() undoes the damage."""
        if self.journal is None or self.chaos is None:
            return
        crash = self.chaos.crash_event(self.step_count)
        if crash is None:
            return
        if crash == "torn":
            self.journal.tear_tail(
                self.chaos.torn_cut(self.journal._last_record_bytes))
        elif crash == "snap":
            self.journal.write_uncommitted_snapshot(self._snapshot_payload())
        os.kill(os.getpid(), signal.SIGKILL)

    def _snapshot_payload(self) -> dict:
        """Whole-engine state at this tick, host-side and picklable: every
        live slot's SlotPool.snapshot (pages + GO rows + cursor + PRNG key),
        the scheduler heaps, parked preemption snapshots, the prefix index
        (structure + pinned page contents), scheduler EWMAs, and counters.
        The chunk job is recorded as its REQUEST only — recovery re-queues
        it and re-runs the chunked prefill from scratch, which is
        deterministic per chunking. The PageAllocator is not serialized:
        restore() re-reserves and re-allocates, which reproduces its
        semantics under fresh physical ids (ids are invisible to streams)."""
        slots = []
        for slot, req in enumerate(self.pool.owner):
            if req is not None:
                slots.append((slot, request_record(req, runtime=True),
                              self.pool.snapshot(slot)))
        job = self._chunk_job
        reqs = ([r for _, _, r in self.scheduler.queue] +
                [r for _, _, r in self.scheduler._pending] +
                [o for o in self.pool.owner if o is not None] +
                list(self.finished.values()) +
                ([job.req] if job is not None else []))
        prefix = None
        if self.prefix_index is not None:
            prefix = self.prefix_index.snapshot_state()
            ids = sorted({p for _, p, _ in prefix["nodes"]})
            if ids:
                jids = jnp.asarray(ids, jnp.int32)
                prefix["page_contents"] = {
                    "ids": ids,
                    "k": np.asarray(self.pool.state["k_pages"][:, jids]),
                    "v": np.asarray(self.pool.state["v_pages"][:, jids]),
                }
                if self.pool.quant:
                    prefix["page_contents"]["ks"] = np.asarray(
                        self.pool.state["k_scales"][:, jids])
                    prefix["page_contents"]["vs"] = np.asarray(
                        self.pool.state["v_scales"][:, jids])
        return {
            "meta": {
                "step": self.step_count,
                "recoveries": self.recoveries,
                "next_id": self._next_id,
                "seq_next": max((r.seq for r in reqs), default=-1) + 1,
                "snapshot_every": (self.journal.snapshot_every
                                   if self.journal is not None else 32),
            },
            "engine_kw": dict(self._engine_kw),
            "slots": slots,
            "queued": [request_record(r, runtime=True)
                       for _, _, r in self.scheduler.queue],
            "pending": [request_record(r, runtime=True)
                        for _, _, r in self.scheduler._pending],
            "chunk_req": (request_record(job.req, runtime=True)
                          if job is not None else None),
            "preempted": dict(self._preempted),
            "finished": [request_record(r, runtime=True)
                         for r in self.finished.values()],
            "prefix": prefix,
            "sched_load": (self.scheduler.load.copy()
                           if self.expert_aware else None),
            "counters": {
                "admitted_total": self.pool.admitted_total,
                "preempted_total": self.preempted_total,
                "resumed_total": self.resumed_total,
                "rejected_full": self.rejected_full,
                "rejected_oversized": self.rejected_oversized,
                "peak_active": self.peak_active,
                "chunk_ticks": self.chunk_ticks,
                "prefix_hits": self.prefix_hits,
                "pages_shared": self.pages_shared,
                "prefill_tokens_skipped": self.prefill_tokens_skipped,
            },
        }

    def _restore_prefix_index(self, pstate: dict) -> None:
        """Rebuild the prefix index from a snapshot: allocate fresh physical
        pages under a temporary owner, scatter the saved page contents back,
        hand the pins over to the radix nodes, release the temporary owner.
        The cache is performance state — if the pool can't cover it at
        recovery (it always can when geometry is unchanged, but overrides
        may shrink it), recovery proceeds cold instead of failing."""
        contents = pstate.get("page_contents")
        if contents is None:
            return
        ids = [int(p) for p in contents["ids"]]
        tmp = -(10 ** 9)        # disjoint from request ids and node rids
        try:
            self.pool.alloc.reserve(tmp, len(ids))
        except RuntimeError:
            return
        fresh = self.pool.alloc.alloc(tmp, len(ids))
        jids = jnp.asarray(fresh, jnp.int32)
        self.pool.state["k_pages"] = self.pool.state["k_pages"].at[
            :, jids].set(jnp.asarray(contents["k"]).astype(
                self.pool.state["k_pages"].dtype))
        self.pool.state["v_pages"] = self.pool.state["v_pages"].at[
            :, jids].set(jnp.asarray(contents["v"]).astype(
                self.pool.state["v_pages"].dtype))
        if self.pool.quant:
            self.pool.state["k_scales"] = self.pool.state["k_scales"].at[
                :, jids].set(jnp.asarray(contents["ks"]))
            self.pool.state["v_scales"] = self.pool.state["v_scales"].at[
                :, jids].set(jnp.asarray(contents["vs"]))
        self.pool.state = self.pool._pin(self.pool.state)
        self.prefix_index.restore_state(pstate, dict(zip(ids, fresh)))
        self.pool.alloc.free(tmp)   # node pins keep every page alive

    @classmethod
    def recover(cls, journal_dir: str, params, cfg, *, mesh=None,
                chaos: Chaos | None = None, snapshot_every: int = 0,
                **overrides) -> "ServingEngine":
        """Rebuild a crashed engine from its journal directory: restore the
        latest COMMITTED snapshot (uncommitted crash artifacts are skipped),
        replay the journal tail, and commit a fresh post-recovery snapshot.

        Live-at-snapshot streams resume via SlotPool.restore — decode is
        deterministic given the restored state (pages + GO rows + cursor +
        per-slot PRNG key), so greedy AND sampled streams continue
        bit-identically to the uninterrupted run. Requests admitted after
        the snapshot are re-queued and re-prefilled (deterministic again).
        Tokens the dead process journaled past the snapshot become a
        prefix-assertion oracle: the recovered streams must re-emit exactly
        them before producing anything new. Terminal events replay only
        CANCELLED (an external decision the engine can't recompute); DONE /
        TIMEOUT / FAILED outcomes are recomputed by simply running — wall
        budgets re-anchor at recovery time."""
        t0 = time.monotonic()
        latest = EngineJournal.latest_committed(journal_dir)
        if latest is None:
            raise JournalError(
                f"no committed snapshot under {journal_dir!r} — nothing to "
                "recover from")
        seq, payload = latest
        kw = dict(payload["engine_kw"])
        kw.update(overrides)
        eng = cls(params, cfg, mesh=mesh, chaos=chaos, journal_dir=False,
                  **kw)
        meta = payload["meta"]
        eng.step_count = meta["step"]
        eng.recoveries = meta["recoveries"] + 1
        eng._next_id = meta["next_id"]
        eng.scheduler._seq = itertools.count(meta["seq_next"])
        for rec in payload["finished"]:
            req = request_from_record(rec)
            eng.finished[req.request_id] = req
        for rec in payload["queued"]:
            req = request_from_record(rec)
            heapq.heappush(eng.scheduler.queue,
                           (req.priority, req.seq, req))
        for rec in payload["pending"]:
            req = request_from_record(rec)
            heapq.heappush(eng.scheduler._pending,
                           (req.arrival_step, req.seq, req))
        if payload["chunk_req"] is not None:
            # the interrupted chunk run re-prefills from scratch — its heap
            # position (original seq) keeps the admission order
            req = request_from_record(payload["chunk_req"])
            req.status = RequestStatus.QUEUED
            heapq.heappush(eng.scheduler.queue,
                           (req.priority, req.seq, req))
        eng._preempted = dict(payload["preempted"])
        for slot, rec, snap in payload["slots"]:
            req = request_from_record(rec)
            eng.pool.restore(slot, req, snap)
        if eng.prefix_index is not None and payload["prefix"] is not None:
            eng._restore_prefix_index(payload["prefix"])
        if eng.expert_aware and payload["sched_load"] is not None \
                and len(payload["sched_load"]) == len(eng.scheduler.load):
            eng.scheduler.load[:] = payload["sched_load"]
        for name, val in payload["counters"].items():
            if name == "admitted_total":
                eng.pool.admitted_total = val   # pool.restore bumped it
            else:
                setattr(eng, name, val)
        # --- replay the journal tail (torn tail already dropped) ---
        events = EngineJournal.read_tail(journal_dir, seq)
        cancelled: list[int] = []
        for kind, p in events:
            if kind == "submit":
                req = request_from_record(p["req"])
                if req.arrival_step > eng.step_count:
                    heapq.heappush(eng.scheduler._pending,
                                   (req.arrival_step, req.seq, req))
                else:
                    heapq.heappush(eng.scheduler.queue,
                                   (req.priority, req.seq, req))
            elif kind == "install":
                eng._replay_expect.setdefault(p["rid"], []).append(p["token"])
            elif kind == "tick":
                for rid, tok in p["toks"].items():
                    eng._replay_expect.setdefault(rid, []).append(tok)
            elif kind == "terminal" and \
                    p["status"] == RequestStatus.CANCELLED.value:
                cancelled.append(p["rid"])
        eng.replayed_events = len(events)
        # committing a fresh snapshot collapses the replayed tail: a second
        # crash during recovery re-runs from HERE, never from the torn log
        eng._attach_journal(journal_dir,
                            snapshot_every or meta["snapshot_every"])
        for rid in cancelled:
            eng.cancel(rid)
        eng.recovered_info = {
            "snapshot_seq": seq,
            "events": len(events),
            "wall_ms": (time.monotonic() - t0) * 1000.0,
        }
        return eng

    def _audit(self) -> None:
        """REPRO_AUDIT=1 invariant sweep, every tick: pool/allocator
        consistency (SlotPool.audit) plus the engine-level cross-checks —
        the chunk lane's claimed slot stays unoccupied and parked preempted
        requests are neither active nor finished."""
        self.pool.audit()
        if self.pool.paged:
            # refcount invariant: the allocator's page refcounts must equal
            # the LIVE references — slot block-table entries, the chunk
            # run's claimed row, and the prefix index's node pins. A page
            # freed while referenced (or referenced while free) shows up
            # here as a count mismatch.
            refs: Counter[int] = Counter()
            for slot, owner in enumerate(self.pool.owner):
                if owner is not None:
                    r = self.pool.block_table[slot]
                    refs.update(int(p) for p in r[r != 0])
            job_row = (self._chunk_job.page_row
                       if self._chunk_job is not None else None)
            if job_row is not None:
                refs.update(int(p) for p in job_row[job_row != 0])
            if self.prefix_index is not None:
                refs.update(self.prefix_index.node_pages())
            rc = Counter(self.pool.alloc.refcounts())
            assert refs == rc, \
                f"page refcounts != live references: {rc - refs} over, " \
                f"{refs - rc} under"
        job = self._chunk_job
        if job is not None:
            assert self.pool.owner[job.slot] is None, \
                "chunk job's claimed slot was given away"
        for rid in self._preempted:
            assert all(o is None or o.request_id != rid
                       for o in self.pool.owner), \
                f"preempted request {rid} also occupies a slot"
            assert rid not in self.finished, \
                f"preempted request {rid} already finished"

    # ------------------------------------------------------------------ stats

    def stats(self) -> dict:
        from repro.core.moe import resolve_backend
        reqs = self.finished.values()
        return {
            "steps": self.step_count,
            "admitted": self.pool.admitted_total,
            "finished": len(self.finished),
            "queued": len(self.scheduler.queue),
            "active": self.pool.num_active(),
            "tokens_out": sum(len(r.tokens) for r in reqs),
            "moe_backend": (resolve_backend(self.cfg.moe)
                            if self.cfg.moe is not None else None),
            "mesh": dict(self.mesh.shape) if self.mesh is not None else None,
            "prefill_lengths": sorted(self.prefill_lengths),
            "peak_active": self.peak_active,
            "paged": self.pool.paged,
            "page_size": self.pool.page_size if self.pool.paged else None,
            "num_pages": self.pool.num_pages,
            "pages_in_use": (self.pool.alloc.pages_in_use
                             if self.pool.paged else None),
            "chunk_ticks": self.chunk_ticks,
            # --- quantized decode state ---
            "kv_quant_dtype": (self.cfg.kv_quant
                               if self.cfg.kv_quant != "none" else None),
            "kv_bytes_per_token": (
                Q.kv_bytes_per_token(self.cfg, self.pool.page_size)
                if self.pool.paged else None),
            "dequant_max_abs_err": (self.pool.dequant_max_abs_err
                                    if self.pool.quant else None),
            # --- prefix sharing / expert-aware admission ---
            "prefix_share": self.prefix_share,
            "expert_aware": self.expert_aware,
            "prefix_hits": self.prefix_hits,
            "pages_shared": self.pages_shared,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            # --- fault domain ---
            "statuses": dict(Counter(r.status.value for r in reqs)),
            "preemptions": self.preempted_total,
            "resumes": self.resumed_total,
            "preempted_waiting": len(self._preempted),
            "rejected": {"queue_full": self.rejected_full,
                         "oversized": self.rejected_oversized},
            "tick_retries": self.supervisor.stats.retries,
            "tick_ms_median": round(self.supervisor.stats.median() * 1e3, 3),
            "tick_stragglers": [
                {"step": s, "wall_ms": round(dt * 1e3, 3),
                 "median_ms": round(med * 1e3, 3)}
                for s, dt, med in self.supervisor.stats.stragglers],
            "chaos": (dict(self.chaos.injected)
                      if self.chaos is not None else None),
            # --- durability ---
            "recoveries": self.recoveries,
            "restart_count": self.restart_count,
            "replayed_events": self.replayed_events,
            "journal_bytes": (self.journal.bytes_written
                              if self.journal is not None else 0),
            "snapshots": (self.journal.snapshots_committed
                          if self.journal is not None else 0),
            "snapshot_age_ticks": (
                self.step_count - self.journal.last_snapshot_step
                if self.journal is not None else None),
        }
