"""Continuous-batching serving engine over the pooled KV + GO cache state.

The paper's GO cache makes each decode step O(1) per request; this engine
makes the REQUEST schedule dynamic too. One jitted decode step runs over a
fixed slot array with an active mask:

  admit    a queued request prefills into a free slot — its KV rows and
           per-layer GO cache entries are written in place (write_decode_slot)
           while the other slots keep decoding between engine ticks;
  decode   every tick advances ALL occupied slots one token in a single
           batched serve_step — slots sit at different positions thanks to
           the per-slot `t` vector, so nothing recompiles and nobody stalls;
  retire   a slot frees on EOS or length; its caches are reset
           (init_decode_slot) and the row is immediately reusable.

Greedy decoding is the default and is bit-identical per request to the
static-batch `repro.launch.serve.generate` path (tests/test_serving.py):
the same compiled kernels run in both, and every batched op is row-wise
independent.

Compile surface: the decode step compiles ONCE per (pool width, max_tokens);
prefill compiles once per distinct prompt length (pad prompts to buckets in
front of the engine if that matters for your trace).

The MoE execution backend rides in through cfg.moe.backend: with "pallas"
the batched decode tick runs the selected-experts grouped GEMM (~B*k rows
per MoE layer instead of B*E dense FFNs — kernels/ops.py:go_selected_ffn)
and prefill flattens the whole pool's FFN pairs into one tile plan. Streams
stay bit-identical to the static generate() path because both run the same
kernels (pinned with backend="pallas" in tests/test_serving.py).

With a `mesh`, the pool state is sharded by `launch/sharding.py` (slot rows
across the data-parallel replicas, KV sequence / GO expert dims over
"model") and every decode tick runs inside the mesh context, so GSPMD
partitions the batched step — including the selected-experts grouped GEMM —
across the replicas. Admission prefill stays batch-1 (replicated) and is
splatted into the sharded row; streams remain bit-identical to the
unsharded engine (pinned in tests/test_moe_mesh.py).
"""
from __future__ import annotations

import itertools
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import prefill, serve_step
from repro.serving.pool import SlotPool
from repro.serving.scheduler import FIFOScheduler, Request


@partial(jax.jit, static_argnames="cfg")
def _decode_step(params, state, tokens, active, cfg):
    """One batched decode tick. Retired slots still flow through the math
    (masking beats reshaping — shapes never change) but their position is
    pinned to 0 so they stay inside max_tokens until the next admission."""
    logits, state = serve_step(params, state, tokens, cfg)
    state["t"] = jnp.where(active, state["t"], 0)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), state


# prefill compiles once per (prompt length, max_len) and is shared across
# engine instances — module-level so benchmark sweeps don't recompile it
_jit_prefill = jax.jit(prefill, static_argnames=("cfg", "max_len"))


class ServingEngine:
    """Continuous-batching engine: submit requests any time, run ticks."""

    def __init__(self, params, cfg, *, num_slots: int = 8,
                 max_tokens: int = 256, max_queue: int = 0,
                 extras: dict | None = None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.mesh = mesh
        self.pool = SlotPool(cfg, num_slots, max_tokens, extras, mesh=mesh)
        self.scheduler = FIFOScheduler(num_slots, max_tokens, max_queue)
        self.step_count = 0
        self.finished: dict[int, Request] = {}
        self._ids = itertools.count()

    # ------------------------------------------------------------- submission

    def submit(self, prompt, max_new_tokens: int, *, eos_id: int | None = None,
               extras: dict | None = None, arrival_step: int = 0,
               request_id: int | None = None) -> int:
        """Queue a request. `arrival_step` > current step defers arrival to
        that engine tick (trace replay). Returns the request id."""
        rid = request_id if request_id is not None else next(self._ids)
        req = Request(
            request_id=rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=int(max_new_tokens),
            eos_id=eos_id,
            extras=extras,
            arrival_step=arrival_step,
        )
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req.arrival_time = time.monotonic()
        self.scheduler.submit(req, now_step=self.step_count)
        return rid

    # ------------------------------------------------------------------ ticks

    def step(self) -> list[Request]:
        """One engine tick: admit due+queued requests into free slots, then
        advance every occupied slot one token. Returns requests finished on
        this tick."""
        done: list[Request] = []

        for req in self.scheduler.poll(self.step_count):
            req.arrival_time = time.monotonic()

        free = self.pool.free_slots()
        while free:
            req = self.scheduler.next_admission(self.pool.num_active())
            if req is None:
                break
            self._admit(free.pop(0), req, done)

        if self.pool.any_active():
            toks, state = self._run_decode_step()
            self.pool.state = self.pool._pin(state)
            toks = np.asarray(toks)
            self.step_count += 1
            for slot, req in enumerate(self.pool.owner):
                if req is None:
                    continue
                tok = int(toks[slot])
                req.tokens.append(tok)
                self.pool.pending[slot] = tok
                self.pool.remaining[slot] -= 1
                if self.pool.remaining[slot] <= 0 or \
                        (req.eos_id is not None and tok == req.eos_id):
                    self._finish(slot, done)
        else:
            # idle tick — jump straight to the next trace arrival
            nxt = self.scheduler.next_arrival_step()
            self.step_count = max(self.step_count + 1,
                                  nxt if nxt is not None else 0)
        return done

    def run(self) -> dict[int, Request]:
        """Tick until queue, trace and pool drain; returns finished requests
        keyed by request id (token streams in Request.tokens)."""
        while self.scheduler.has_pending() or self.pool.any_active():
            self.step()
        return self.finished

    # -------------------------------------------------------------- internals

    def _run_decode_step(self):
        """One jitted decode tick, inside the mesh context when sharded (the
        jit cache keys on the ambient mesh, so the sharded and unsharded
        variants coexist in one process)."""
        args = (self.params, self.pool.state, jnp.asarray(self.pool.pending),
                jnp.asarray(self.pool.active_mask()), self.cfg)
        if self.mesh is None:
            return _decode_step(*args)
        with self.mesh:
            return _decode_step(*args)

    def _admit(self, slot: int, req: Request, done: list[Request]) -> None:
        """Prefill a request into `slot` mid-flight: fills that row's KV and
        GO cache entries and emits the request's first token (from the
        prefill logits — exactly what static generate() emits first)."""
        slot_state, logits = _jit_prefill(
            self.params, jnp.asarray(req.prompt, jnp.int32)[None, :],
            self.cfg, req.extras or {}, self.pool.max_tokens)
        first = int(jnp.argmax(logits, axis=-1)[0])
        req.admit_step = self.step_count
        req.tokens.append(first)
        self.pool.admit(slot, req, slot_state, first)
        if self.pool.remaining[slot] <= 0 or \
                (req.eos_id is not None and first == req.eos_id):
            self._finish(slot, done)

    def _finish(self, slot: int, done: list[Request]) -> None:
        req = self.pool.retire(slot)
        req.finish_step = self.step_count
        req.finish_time = time.monotonic()
        self.finished[req.request_id] = req
        done.append(req)

    # ------------------------------------------------------------------ stats

    def stats(self) -> dict:
        from repro.core.moe import resolve_backend
        reqs = self.finished.values()
        return {
            "steps": self.step_count,
            "admitted": self.pool.admitted_total,
            "finished": len(self.finished),
            "queued": len(self.scheduler.queue),
            "active": self.pool.num_active(),
            "tokens_out": sum(len(r.tokens) for r in reqs),
            "moe_backend": (resolve_backend(self.cfg.moe)
                            if self.cfg.moe is not None else None),
            "mesh": dict(self.mesh.shape) if self.mesh is not None else None,
        }
