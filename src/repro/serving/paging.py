"""Host-side page allocator + prefix index for the paged KV(+GO) pool.

The device holds ONE fixed page pool (`k_pages`/`v_pages`,
[L, num_pages, page_size, h, hd]); this allocator decides which physical
pages back which request. Pure host bookkeeping (no jax): the engine calls
it at admission / growth / retirement and mirrors the resulting block
tables into the jitted state.

Page 0 is the reserved NULL page: it backs every unallocated block-table
entry and absorbs the decode-step writes of retired slots, so its contents
are trash by design and it is never handed out.

Deadlock freedom comes from RESERVATIONS, not preemption: admission
reserves a request's worst-case page count (ceil((prompt + max_new) /
page_size)) up front, while physical pages are still handed out lazily —
`grow()` as the sequence crosses page boundaries. A reserved-but-unused
page cannot be promised to a second request, so an admitted request can
always grow to its declared maximum, and `can_reserve` is the scheduler's
"pages available?" admission question. Retirement returns every owned page
and drops the reservation in one call (`free`), which is also where the
slot's GO-cache rows are reset by the pool.

REFCOUNTED SHARING (copy-on-write prefix pages): pages are refcounted, so
several owners can map the SAME physical page (`share` — e.g. requests
whose prompts share a page-aligned prefix, plus the prefix-index nodes
that keep a retired donor's pages alive). A shared page counts as OWNED by
each sharer but consumes nothing from the free list; `free` only releases
a page once its last reference drops. Divergent writes go through `fork`:
the writer swaps its reference for a fresh private page (the caller copies
the contents) and the donors never see the write. Scrub marks
(`mark_scrub`/`pop_dirty`) defer PR 7's NaN-scrub to the page's LAST free:
a quarantined request may share clean prefix pages with live streams, so
zeroing must wait until nobody maps the page.
"""
from __future__ import annotations

import itertools
from collections import Counter, OrderedDict


class PageAllocator:
    """Fixed-pool free-list allocator with worst-case reservations and
    refcounted (copy-on-write) page sharing."""

    def __init__(self, num_pages: int, page_size: int,
                 max_tokens: int | None = None):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if max_tokens is not None and max_tokens % page_size:
            # fail fast: a ragged last page would make every worst-case
            # reservation (ceil((prompt + max_new) / page_size)) silently
            # over- or under-count — deadlock freedom rests on those counts
            raise ValueError(
                f"max_tokens={max_tokens} is not a multiple of "
                f"page_size={page_size}: the worst-case page reservation "
                "would miscount the last partial page")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list (page 1 handed out first — keeps smoke traces easy
        # to read); page 0 never enters it.
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}     # request id -> pages held
        self._reserved: dict[int, int] = {}        # request id -> max pages
        self._refcnt: dict[int, int] = {}          # page -> live references
        self._dirty: set[int] = set()              # scrub due at last free

    # ---------------------------------------------------------------- queries

    @property
    def free_pages(self) -> int:
        """Physically unallocated pages (ignores reservations)."""
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def owned(self, rid: int) -> list[int]:
        return list(self._owned.get(rid, ()))

    def refcount(self, page: int) -> int:
        return self._refcnt.get(page, 0)

    def refcounts(self) -> dict[int, int]:
        """Copy of the page -> reference-count map (audit cross-checks it
        against the live block-table references)."""
        return dict(self._refcnt)

    def _outstanding(self) -> int:
        """Pages promised to admitted requests but not yet handed out.
        Shared pages count as handed out: a sharer's remaining free-list
        demand is its worst case MINUS everything it already maps."""
        return sum(max(0, n - len(self._owned.get(r, ())))
                   for r, n in self._reserved.items())

    def can_reserve(self, n: int) -> bool:
        """Would a new request needing `n` pages at worst still be admissible
        without ever deadlocking the in-flight ones?"""
        return n <= len(self._free) - self._outstanding()

    # -------------------------------------------------------------- lifecycle

    def reserve(self, rid: int, n: int) -> None:
        """Promise `rid` up to `n` pages total. Re-reserving (e.g. a chunked
        prefill whose reservation predates admission) keeps the larger
        promise. Pages `rid` already maps — including SHARED prefix pages
        (`share` before `reserve`) — count as held, so only the remainder
        must be coverable by the free list."""
        have = max(self._reserved.get(rid, 0), len(self._owned.get(rid, ())))
        if n > have and not self.can_reserve(n - have):
            raise RuntimeError(
                f"page pool over-committed: request {rid} wants {n} pages, "
                f"{len(self._free)} free / {self._outstanding()} promised")
        self._reserved[rid] = max(n, self._reserved.get(rid, 0))
        self._owned.setdefault(rid, [])

    def alloc(self, rid: int, n: int) -> list[int]:
        """Hand `rid` `n` physical pages (admission: the pages covering the
        prompt and the first decode write). Like grow(), alloc is capped by
        the request's reservation — every hand-out path honours the
        promises `can_reserve` was answered against, or deadlock freedom is
        fiction."""
        have = len(self._owned.get(rid, ()))
        if have + n > self._reserved.get(rid, 0):
            raise RuntimeError(
                f"request {rid} asked {n} pages over a reservation of "
                f"{self._reserved.get(rid, 0)} (holds {have}) — reserve "
                "before allocating")
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: request {rid} asked {n}, "
                f"{len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refcnt[p] = 1
        self._owned.setdefault(rid, []).extend(pages)
        return pages

    def share(self, rid: int, pages: list[int]) -> None:
        """Map already-allocated `pages` into `rid` copy-on-write: each
        page's refcount rises by one and `rid` owns it like any other page,
        but nothing leaves the free list. The sharer must never WRITE a
        shared page (fork first) — the engine guarantees it structurally
        (a consumer's first write lands past the shared full-page prefix)."""
        owned = self._owned.setdefault(rid, [])
        for p in pages:
            p = int(p)
            if self._refcnt.get(p, 0) < 1:
                raise RuntimeError(
                    f"request {rid} cannot share unallocated page {p}")
            if p in owned:
                raise RuntimeError(
                    f"request {rid} already maps page {p}")
            self._refcnt[p] += 1
            owned.append(p)

    def fork(self, rid: int, page: int) -> int:
        """Copy-on-write fork: swap `rid`'s reference to SHARED `page` for a
        fresh private page and return it (the caller copies the contents
        before diverging). Fork draws from the free list OUTSIDE the
        reservation accounting, so it can fail under pressure — the engine
        never needs it (consumers never write shared pages); it exists for
        explicit divergent writers (chaos poison) and the property tests."""
        owned = self._owned.get(rid)
        if owned is None or page not in owned:
            raise KeyError(f"request {rid} does not map page {page}")
        if self._refcnt.get(page, 0) < 2:
            raise RuntimeError(
                f"page {page} is not shared — fork would leak its twin")
        if not self._free:
            raise RuntimeError("page pool exhausted on fork")
        new = self._free.pop()
        self._refcnt[new] = 1
        owned[owned.index(page)] = new
        self._refcnt[page] -= 1
        return new

    def can_grow(self, rid: int) -> bool:
        return rid in self._owned and \
            len(self._owned[rid]) < self._reserved.get(rid, 0)

    def grow(self, rid: int) -> int:
        """Hand `rid` one more page (decode crossed a page boundary). The
        reservation cap is ENFORCED here: a request can never grow past the
        maximum it declared at admission, so it can never steal a page
        promised to another in-flight request — which is exactly what makes
        in-reservation growth infallible (free >= outstanding promises is a
        `reserve`-time invariant)."""
        if rid not in self._owned:
            raise KeyError(f"request {rid} owns no pages")
        if len(self._owned[rid]) >= self._reserved.get(rid, 0):
            raise RuntimeError(
                f"request {rid} is at its reservation cap "
                f"({self._reserved.get(rid, 0)} pages) — growing past it "
                "would steal pages promised to other requests")
        if not self._free:
            raise RuntimeError("page pool exhausted on grow — admission "
                               "reservations make this unreachable")
        page = self._free.pop()
        self._refcnt[page] = 1
        self._owned[rid].append(page)
        return page

    def free(self, rid: int) -> list[int]:
        """Retirement: drop every reference `rid` holds and its reservation.
        Returns the pages actually RELEASED — those whose last reference
        this was (shared pages survive until their other owners free them).
        Callers owning device state must route released pages through
        `pop_dirty` and zero the marked ones (deferred NaN scrub)."""
        pages = self._owned.pop(rid, [])
        self._reserved.pop(rid, None)
        released = []
        for p in pages:
            self._refcnt[p] -= 1
            if self._refcnt[p] == 0:
                del self._refcnt[p]
                released.append(p)
        self._free.extend(reversed(released))
        return released

    # ------------------------------------------------------- deferred scrub

    def mark_scrub(self, rid: int) -> None:
        """Flag every page `rid` maps for a zero-on-last-free scrub (PR 7's
        NaN quarantine): pages released right now are zeroed right now, but
        a page still shared with live owners is zeroed only when the LAST
        reference drops — scrubbing earlier would wipe state someone is
        still reading; scrubbing never would leak NaN to a future stream."""
        self._dirty.update(self._owned.get(rid, ()))

    def pop_dirty(self, pages: list[int]) -> list[int]:
        """Consume the scrub marks among just-released `pages`; the caller
        zeroes exactly these on device. Marks on still-live pages stay."""
        out = [p for p in pages if p in self._dirty]
        self._dirty.difference_update(out)
        return out

    # ------------------------------------------------------------- invariants

    def check(self) -> None:
        """Internal-consistency assertions (used by the property tests):
        every page is either free or allocated — never both; an allocated
        page's refcount equals the number of owners mapping it (no page
        freed while referenced, no reference without a refcount); no page
        leaks; scrub marks only on live pages; page 0 touches none of it."""
        owners: Counter[int] = Counter()
        for rid, pages in self._owned.items():
            assert len(set(pages)) == len(pages), \
                f"request {rid} maps a page twice"
            owners.update(pages)
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list holds duplicates"
        for p in free_set:
            assert 0 < p < self.num_pages, f"bad page id {p}"
            assert p not in owners, f"page {p} both free and owned"
            assert p not in self._refcnt, f"freed page {p} keeps a refcount"
        for p, n in owners.items():
            assert 0 < p < self.num_pages, f"bad page id {p}"
            assert self._refcnt.get(p) == n, \
                f"page {p}: refcount {self._refcnt.get(p)} != {n} owners"
        assert set(self._refcnt) == set(owners), "refcount on unowned page"
        assert len(free_set) + len(owners) == self.num_pages - 1, \
            f"leaked {self.num_pages - 1 - len(free_set) - len(owners)} pages"
        assert self._dirty <= set(owners), \
            "scrub mark on a released page (scrub must fire ON last free)"


def pages_for_tokens(num_tokens: int, page_size: int) -> int:
    return -(-num_tokens // page_size)


class PrefixIndex:
    """Page-aligned radix index over prompt tokens: maps prompt prefixes to
    the PHYSICAL pages already holding their KV, plus the per-prompt prefill
    artifacts a zero-compute admission needs.

    Structure: one radix NODE per full page of prompt tokens, keyed by
    (parent node, that page's token tuple) — so walking a new prompt's
    leading pages yields the longest page-aligned shared prefix in O(pages).
    Each node pins one physical page via the allocator's refcounts under a
    per-node synthetic owner id (negative, so it can never collide with a
    request id); donors may retire freely — the node keeps the page alive,
    which is what "live or RECENTLY-RETIRED stream" means here.

    A node is only REUSED by a later deposit when its pinned page IS the
    depositor's page for those positions (same physical page == same bytes,
    by COW construction). Same tokens backed by a DIFFERENT page means the
    two prompts prefilled those positions independently — under MoE's
    whole-sequence routing the KV differs even though the tokens match —
    so the depositor pins its own page under a PRIVATE node (key None,
    unreachable from `_walk`): its full-prompt entry chains its own bytes,
    never another prompt's, which is what keeps exact-match hits bit-exact.

    A full-prompt ENTRY (deposited at admission, LRU-bounded by `capacity`)
    additionally carries what page sharing alone cannot reproduce:

      tail KV   the prompt positions past the last full page (host copy —
                they live in the donor's PRIVATE page, which decode writes
                into, so consumers get a copy-on-write copy up front);
      GO rows   the expert-choice GO cache after prefill — TopKUpdate
                history, NOT recomputable from the shared pages (the exact
                problem the paper's GO cache solves);
      logits    the prefill logits, so the consumer's first token (greedy
                or sampled under ITS temperature/seed) needs no forward.

    The index is pure host bookkeeping; page release flows back through the
    pool so deferred scrub marks are honoured — every mutating method
    returns the physical pages it RELEASED for exactly that reason."""

    def __init__(self, alloc: PageAllocator, page_size: int,
                 capacity: int = 32):
        self.alloc = alloc
        self.page_size = page_size
        self.capacity = capacity
        self._ids = itertools.count()
        self._children: dict[tuple, int] = {}   # (parent, tokens) -> node id
        self._nodes: dict[int, dict] = {}       # id -> {page, key, uses}
        self._entries: OrderedDict[tuple, dict] = OrderedDict()
        self.hits = 0
        self.partial_hits = 0
        self.deposits = 0
        self.evictions = 0

    @staticmethod
    def node_rid(node_id: int) -> int:
        """Synthetic allocator owner id for a node's page pin (negative —
        disjoint from every request id by construction)."""
        return -(node_id + 1)

    def __len__(self) -> int:
        return len(self._entries)

    def node_pages(self) -> list[int]:
        """Physical pages pinned by the index (one per node) — the audit
        counts these as live block-table references."""
        return [n["page"] for n in self._nodes.values()]

    def _walk(self, prompt) -> list[int]:
        """Node chain matching the prompt's leading FULL pages."""
        ps = self.page_size
        chain, parent = [], -1
        for i in range(len(prompt) // ps):
            key = (parent, tuple(int(t) for t in prompt[i * ps:(i + 1) * ps]))
            nid = self._children.get(key)
            if nid is None:
                break
            chain.append(nid)
            parent = nid
        return chain

    # ----------------------------------------------------------------- lookup

    def lookup_full(self, prompt) -> dict | None:
        """Exact full-prompt entry (zero-prefill admission) or None."""
        key = tuple(int(t) for t in prompt)
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
        return entry

    def entry_pages(self, entry: dict) -> list[int]:
        return [self._nodes[n]["page"] for n in entry["nodes"]]

    def lookup_prefix(self, prompt) -> list[int]:
        """Physical pages backing the longest page-aligned prefix of
        `prompt` present in the index (possibly empty)."""
        return [self._nodes[n]["page"] for n in self._walk(prompt)]

    # ---------------------------------------------------------------- deposit

    def deposit(self, prompt, page_ids, *, tail_k, tail_v, go, logits,
                sig=None, tail_ks=None, tail_vs=None,
                go_scales=None) -> list[int]:
        """Record an admitted prompt: pin its full pages as radix nodes
        (sharing the donor's physical `page_ids` — no data moves) and cache
        the tail KV / GO rows / logits under the full-prompt key. Returns
        pages RELEASED by any capacity eviction (caller scrubs them)."""
        key = tuple(int(t) for t in prompt)
        if key in self._entries:
            self._entries.move_to_end(key)
            return []
        ps = self.page_size
        n_full = len(key) // ps
        assert len(page_ids) >= n_full, "deposit needs the full-page ids"
        parent, chain = -1, []
        for i in range(n_full):
            ck = (parent, key[i * ps:(i + 1) * ps])
            nid = self._children.get(ck)
            if nid is not None and \
                    self._nodes[nid]["page"] != int(page_ids[i]):
                # same tokens, different physical page: the existing node
                # pins ANOTHER prompt's prefill of these positions (MoE
                # whole-sequence routing makes that KV non-interchangeable
                # even though the tokens match). Chaining through it would
                # hand a future exact-match consumer the other prompt's
                # bytes — pin the depositor's own page under a private
                # node instead.
                nid, ck = None, None
            if nid is None:
                nid = next(self._ids)
                self.alloc.share(self.node_rid(nid), [int(page_ids[i])])
                if ck is not None:
                    self._children[ck] = nid
                self._nodes[nid] = {"page": int(page_ids[i]), "key": ck,
                                    "uses": 0}
            chain.append(nid)
            parent = nid
        for nid in chain:
            self._nodes[nid]["uses"] += 1
        self._entries[key] = {
            "nodes": chain, "tail_k": tail_k, "tail_v": tail_v,
            "go": go, "logits": logits, "sig": sig, "prompt_len": len(key),
            # quantized pools: per-page scales for the tail pages and the
            # depositor's GO row scales — int8 pages without their scales
            # are meaningless bytes (None under kv_quant="none")
            "tail_ks": tail_ks, "tail_vs": tail_vs, "go_scales": go_scales,
        }
        self.deposits += 1
        released: list[int] = []
        while len(self._entries) > self.capacity:
            released += self._evict_one()
        return released

    def _evict_one(self) -> list[int]:
        """Drop the least-recently-used entry; release the pages of nodes no
        surviving entry walks through (a chain always references every
        ancestor, so uses==0 implies no live descendants either)."""
        _, entry = self._entries.popitem(last=False)
        self.evictions += 1
        released: list[int] = []
        for nid in reversed(entry["nodes"]):
            node = self._nodes[nid]
            node["uses"] -= 1
            if node["uses"] == 0:
                if node["key"] is not None:     # private nodes never registered
                    del self._children[node["key"]]
                del self._nodes[nid]
                released += self.alloc.free(self.node_rid(nid))
        return released

    # ------------------------------------------------------------ durability

    def snapshot_state(self) -> dict:
        """Structural host snapshot for the engine journal: radix topology,
        node page pins (PHYSICAL ids — the engine captures those pages'
        device contents separately, since the index only knows numbers),
        and the LRU-ordered entries with their host artifacts. Everything
        is host data, picklable as-is."""
        return {
            "children": [(parent, list(toks), nid)
                         for (parent, toks), nid in self._children.items()],
            "nodes": [(nid, n["page"], n["uses"])
                      for nid, n in self._nodes.items()],
            "entries": [(list(key), dict(entry))
                        for key, entry in self._entries.items()],
        }

    def restore_state(self, snap: dict, page_map: dict[int, int]) -> None:
        """Rebuild THIS (empty) index from a `snapshot_state` payload, with
        every old physical page id remapped through `page_map` (recovery
        scatters the saved contents into freshly allocated pages first,
        owned by a temporary rid). Each node re-pins its page via the
        allocator refcounts exactly as deposit() did — once the caller
        frees the temporary owner, the node pins alone keep the pages
        alive, mirroring a retired donor."""
        assert not self._entries and not self._nodes, \
            "restore_state needs an empty index"
        max_nid = -1
        for nid, page, uses in snap["nodes"]:
            self.alloc.share(self.node_rid(nid), [page_map[page]])
            self._nodes[nid] = {"page": page_map[page], "key": None,
                                "uses": uses}
            max_nid = max(max_nid, nid)
        for parent, toks, nid in snap["children"]:
            key = (parent, tuple(toks))
            self._children[key] = nid
            self._nodes[nid]["key"] = key
        for key, entry in snap["entries"]:
            self._entries[tuple(key)] = entry
        self._ids = itertools.count(max_nid + 1)

    def reclaim_one(self) -> list[int]:
        """Page-pressure hook: drop the LRU entry on demand (the engine
        calls this when a blocked admission could use the pinned pages —
        cache pins are opportunistic, a stalled request is not). Returns
        the released pages for scrubbing."""
        return self._evict_one() if self._entries else []

    def flush(self) -> list[int]:
        """Drop every entry and node, releasing all pinned pages (the
        engine flushes on drain so a fully-retired pool holds zero pages).
        Returns the released pages for scrubbing."""
        released: list[int] = []
        while self._entries:
            released += self._evict_one()
        assert not self._nodes and not self._children, \
            "prefix index leaked nodes past its entries"
        return released
