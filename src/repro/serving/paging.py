"""Host-side page allocator for the paged KV(+GO) decode pool.

The device holds ONE fixed page pool (`k_pages`/`v_pages`,
[L, num_pages, page_size, h, hd]); this allocator decides which physical
pages back which request. Pure host bookkeeping (no jax): the engine calls
it at admission / growth / retirement and mirrors the resulting block
tables into the jitted state.

Page 0 is the reserved NULL page: it backs every unallocated block-table
entry and absorbs the decode-step writes of retired slots, so its contents
are trash by design and it is never handed out.

Deadlock freedom comes from RESERVATIONS, not preemption: admission
reserves a request's worst-case page count (ceil((prompt + max_new) /
page_size)) up front, while physical pages are still handed out lazily —
`grow()` as the sequence crosses page boundaries. A reserved-but-unused
page cannot be promised to a second request, so an admitted request can
always grow to its declared maximum, and `can_reserve` is the scheduler's
"pages available?" admission question. Retirement returns every owned page
and drops the reservation in one call (`free`), which is also where the
slot's GO-cache rows are reset by the pool.
"""
from __future__ import annotations


class PageAllocator:
    """Fixed-pool free-list allocator with worst-case reservations."""

    def __init__(self, num_pages: int, page_size: int,
                 max_tokens: int | None = None):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if max_tokens is not None and max_tokens % page_size:
            # fail fast: a ragged last page would make every worst-case
            # reservation (ceil((prompt + max_new) / page_size)) silently
            # over- or under-count — deadlock freedom rests on those counts
            raise ValueError(
                f"max_tokens={max_tokens} is not a multiple of "
                f"page_size={page_size}: the worst-case page reservation "
                "would miscount the last partial page")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list (page 1 handed out first — keeps smoke traces easy
        # to read); page 0 never enters it.
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}     # request id -> pages held
        self._reserved: dict[int, int] = {}        # request id -> max pages

    # ---------------------------------------------------------------- queries

    @property
    def free_pages(self) -> int:
        """Physically unallocated pages (ignores reservations)."""
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def owned(self, rid: int) -> list[int]:
        return list(self._owned.get(rid, ()))

    def _outstanding(self) -> int:
        """Pages promised to admitted requests but not yet handed out."""
        return sum(max(0, n - len(self._owned.get(r, ())))
                   for r, n in self._reserved.items())

    def can_reserve(self, n: int) -> bool:
        """Would a new request needing `n` pages at worst still be admissible
        without ever deadlocking the in-flight ones?"""
        return n <= len(self._free) - self._outstanding()

    # -------------------------------------------------------------- lifecycle

    def reserve(self, rid: int, n: int) -> None:
        """Promise `rid` up to `n` pages total. Re-reserving (e.g. a chunked
        prefill whose reservation predates admission) keeps the larger
        promise."""
        have = self._reserved.get(rid, 0)
        if n > have and not self.can_reserve(n - have):
            raise RuntimeError(
                f"page pool over-committed: request {rid} wants {n} pages, "
                f"{len(self._free)} free / {self._outstanding()} promised")
        self._reserved[rid] = max(n, have)
        self._owned.setdefault(rid, [])

    def alloc(self, rid: int, n: int) -> list[int]:
        """Hand `rid` `n` physical pages (admission: the pages covering the
        prompt and the first decode write). Like grow(), alloc is capped by
        the request's reservation — every hand-out path honours the
        promises `can_reserve` was answered against, or deadlock freedom is
        fiction."""
        have = len(self._owned.get(rid, ()))
        if have + n > self._reserved.get(rid, 0):
            raise RuntimeError(
                f"request {rid} asked {n} pages over a reservation of "
                f"{self._reserved.get(rid, 0)} (holds {have}) — reserve "
                "before allocating")
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: request {rid} asked {n}, "
                f"{len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(pages)
        return pages

    def can_grow(self, rid: int) -> bool:
        return rid in self._owned and \
            len(self._owned[rid]) < self._reserved.get(rid, 0)

    def grow(self, rid: int) -> int:
        """Hand `rid` one more page (decode crossed a page boundary). The
        reservation cap is ENFORCED here: a request can never grow past the
        maximum it declared at admission, so it can never steal a page
        promised to another in-flight request — which is exactly what makes
        in-reservation growth infallible (free >= outstanding promises is a
        `reserve`-time invariant)."""
        if rid not in self._owned:
            raise KeyError(f"request {rid} owns no pages")
        if len(self._owned[rid]) >= self._reserved.get(rid, 0):
            raise RuntimeError(
                f"request {rid} is at its reservation cap "
                f"({self._reserved.get(rid, 0)} pages) — growing past it "
                "would steal pages promised to other requests")
        if not self._free:
            raise RuntimeError("page pool exhausted on grow — admission "
                               "reservations make this unreachable")
        page = self._free.pop()
        self._owned[rid].append(page)
        return page

    def free(self, rid: int) -> list[int]:
        """Retirement: return every page `rid` holds and drop its
        reservation. The freed page ids go back to the free list; the pool
        resets the slot's GO rows (scores to -inf) on this same path."""
        pages = self._owned.pop(rid, [])
        self._reserved.pop(rid, None)
        self._free.extend(reversed(pages))
        return pages

    # ------------------------------------------------------------- invariants

    def check(self) -> None:
        """Internal-consistency assertions (used by the property tests):
        every page is either free or owned by exactly one request, and page
        0 is neither."""
        seen: set[int] = set()
        for pool in [self._free, *self._owned.values()]:
            for p in pool:
                assert 0 < p < self.num_pages, f"bad page id {p}"
                assert p not in seen, f"page {p} aliased"
                seen.add(p)
        assert len(seen) == self.num_pages - 1, \
            f"leaked {self.num_pages - 1 - len(seen)} pages"


def pages_for_tokens(num_tokens: int, page_size: int) -> int:
    return -(-num_tokens // page_size)
