"""Slot pool: owns the pooled per-request KV + GO decode state.

One decode state of `num_slots` batch rows lives on device for the whole
engine lifetime; requests are admitted into free rows and retired out of
them without reshaping anything — so the jitted decode step never
recompiles. Per-slot positions (`state["t"]` as an int32 vector) are what
let rows sit at different sequence offsets (models/model.py per-slot ops).

Host-side metadata (which request owns which row, its next input token, how
many tokens it still owes) stays in numpy; only the cache tensors live in
jax. The GO cache rows ride along with the KV rows: `write_decode_slot`
splats a single-request prefill (KV + per-layer GO entries) into the row,
`init_decode_slot` clears it at retirement (scores back to -inf) so a stale
expert-choice cache can never leak into the next occupant.

With a `mesh`, the pool's tensors are laid out by the rule-based sharder
(`launch/sharding.py::serve_state_shardings`): slot rows over the
data-parallel axes, KV sequence / GO expert dims over "model". Slot writes
and resets land on the sharded arrays in place; after each the state is
pinned back to the canonical shardings so the jitted decode step never sees
a drifted layout (sharding drift means silent recompiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import (init_decode_slot, init_decode_state,
                                write_decode_slot)
from repro.serving.scheduler import Request

# Module-level jits: the slot index is traced, so each op compiles once per
# pool SHAPE — shared across every engine/pool instance of that shape (the
# throughput benchmark spins up one engine per slot count).
_write_slot = jax.jit(write_decode_slot)
_reset_slot = jax.jit(init_decode_slot)


class SlotPool:
    """Fixed-width pool of per-request decode-cache rows."""

    def __init__(self, cfg, num_slots: int, max_tokens: int,
                 extras: dict | None = None, mesh=None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_tokens = max_tokens
        self.mesh = mesh
        # Per-request cross-attn memory arrives batch-1 via each prefill and
        # is splatted in by write_decode_slot — the pool itself always inits
        # the default (zero, [num_slots, ...]) memory rows.
        pool_extras = {k: v for k, v in (extras or {}).items()
                       if k != "memory"}
        self.state = init_decode_state(
            cfg, num_slots, max_tokens, pool_extras, per_slot_t=True)
        self.shardings = None
        if mesh is not None:
            from repro.launch.sharding import serve_state_shardings
            self.shardings = serve_state_shardings(
                cfg, mesh, num_slots, max_tokens, pool_extras)
            self.state = self._pin(self.state)
        # host-side slot metadata
        self.owner: list[Request | None] = [None] * num_slots
        self.pending = np.zeros(num_slots, np.int32)    # next input token
        self.remaining = np.zeros(num_slots, np.int64)  # tokens still owed
        self.admitted_total = 0
        # per-slot sampling state (temperature <= 0 -> greedy row)
        self.temps = np.zeros(num_slots, np.float32)
        self.top_ps = np.ones(num_slots, np.float32)
        self.keys = np.zeros((num_slots, 2), np.uint32)  # PRNG key per slot

    def _pin(self, state: dict) -> dict:
        """Reshard `state` onto the canonical pool layout (no-op without a
        mesh)."""
        if self.shardings is None:
            return state
        return jax.device_put(state, self.shardings)

    # ---------------------------------------------------------------- queries

    def free_slots(self) -> list[int]:
        return [i for i, o in enumerate(self.owner) if o is None]

    def num_active(self) -> int:
        return self.num_slots - len(self.free_slots())

    def any_active(self) -> bool:
        return any(o is not None for o in self.owner)

    def active_mask(self) -> np.ndarray:
        return np.array([o is not None for o in self.owner], bool)

    # -------------------------------------------------------------- lifecycle

    def admit(self, slot: int, req: Request, slot_state: dict,
              first_token: int, key=None) -> None:
        """Install a prefilled request into a free row: write its KV + GO
        cache entries and position in place, arm its first decode input.
        `key` is the slot's sampling PRNG state (already advanced past the
        first token) for temperature > 0 requests."""
        assert self.owner[slot] is None, f"slot {slot} is occupied"
        self.state = self._pin(_write_slot(self.state, slot, slot_state))
        self.owner[slot] = req
        self.pending[slot] = first_token
        self.remaining[slot] = req.max_new_tokens - 1   # first token emitted
        self.admitted_total += 1
        self.temps[slot] = req.temperature
        self.top_ps[slot] = req.top_p
        self.keys[slot] = 0 if key is None else np.asarray(key, np.uint32)
        req.slot = slot

    def retire(self, slot: int) -> Request:
        """Free a row: clear its caches (GO scores to -inf) and return the
        finished request. The row is immediately reusable."""
        req = self.owner[slot]
        assert req is not None, f"slot {slot} is already free"
        self.state = self._pin(_reset_slot(self.state, slot))
        self.owner[slot] = None
        self.pending[slot] = 0
        self.remaining[slot] = 0
        self.temps[slot] = 0.0
        self.top_ps[slot] = 1.0
        self.keys[slot] = 0
        return req
