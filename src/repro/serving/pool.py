"""Slot pool: owns the pooled per-request KV + GO decode state.

One decode state of `num_slots` batch rows lives on device for the whole
engine lifetime; requests are admitted into free rows and retired out of
them without reshaping anything — so the jitted decode step never
recompiles. Per-slot positions (`state["t"]` as an int32 vector) are what
let rows sit at different sequence offsets (models/model.py per-slot ops).

Host-side metadata (which request owns which row, its next input token, how
many tokens it still owes) stays in numpy; only the cache tensors live in
jax. The GO cache rows ride along with the KV rows: `write_decode_slot`
splats a single-request prefill (KV + per-layer GO entries) into the row,
`init_decode_slot` clears it at retirement (scores back to -inf) so a stale
expert-choice cache can never leak into the next occupant.

PAGED mode (`paged=True`) replaces the dense per-slot KV rows with a shared
page pool: `k_pages`/`v_pages` hold `num_pages` fixed-size token blocks and
each slot carries a block table of physical page ids (0 = null page). The
host-side `PageAllocator` (serving/paging.py) reserves each request's
worst-case page count at admission (deadlock freedom) but hands pages out
lazily — `grow_active()` assigns one page as a slot's sequence crosses a
page boundary, right before the decode tick that writes it. The PERSISTENT
KV residency then caps out at `num_pages * page_size` tokens regardless of
num_slots x max_tokens, which is what lets the paged engine run strictly
more concurrent streams than the dense one on the same cache budget. (The
BANDWIDTH win rides on top: the Pallas paged-attention kernel,
kernels/paged_attn.py, walks the block table directly so per-tick traffic
scales with live pages; the gather fallback re-materializes a TRANSIENT
dense-layout K/V per layer per tick.) GO rows stay slot-resident (they are
[E, k]-shaped, not sequence-shaped); their score reset to -inf happens on
the allocator's free path at retirement.

With a `mesh`, the pool's tensors are laid out by the rule-based sharder
(`launch/sharding.py::serve_state_shardings`): slot rows over the
data-parallel axes, KV sequence / GO expert dims over "model" (paged: the
page dim over data-parallel, then the page interior over "model" on the
gather path or kv heads over "model" on the kernel path — the kernel
stages whole pages; block tables replicated). Slot writes and resets land on the sharded arrays in place;
after each the state is pinned back to the canonical shardings so the
jitted decode step never sees a drifted layout (sharding drift means silent
recompiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.models.model import (init_decode_slot, init_decode_state,
                                paged_supported, write_decode_slot)
from repro.serving.paging import PageAllocator, pages_for_tokens
from repro.serving.scheduler import Request

# Module-level jits: the slot index is traced, so each op compiles once per
# pool SHAPE — shared across every engine/pool instance of that shape (the
# throughput benchmark spins up one engine per slot count).
_write_slot = jax.jit(write_decode_slot)
_reset_slot = jax.jit(init_decode_slot)
# Preemption-resume scatter: put a snapshot's live pages back into the page
# store at freshly-allocated physical ids. Compiles once per (store shape,
# live-page count) — page counts are small integers, so the cache stays tiny.
_scatter_pages = jax.jit(
    lambda store, ids, pages: store.at[:, ids].set(pages.astype(store.dtype)))


class SlotPool:
    """Fixed-width pool of per-request decode-cache rows."""

    def __init__(self, cfg, num_slots: int, max_tokens: int,
                 extras: dict | None = None, mesh=None, *,
                 paged: bool = False, page_size: int = 16,
                 num_pages: int | None = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_tokens = max_tokens
        self.mesh = mesh
        self.paged = bool(paged)
        self.page_size = page_size
        self.num_pages = None
        Q.validate_kv_quant(getattr(cfg, "kv_quant", "none"))
        self.quant = cfg.kv_quant != "none"
        self.dequant_max_abs_err = 0.0
        if self.quant and not self.paged:
            # quantized decode state is page-granular by construction —
            # there is no per-page scale to hang off a dense KV row
            raise ValueError(
                f"kv_quant={cfg.kv_quant!r} requires a paged pool (scale "
                "granularity IS page granularity) — enable paging "
                "(paged=True / REPRO_FORCE_PAGED) or set kv_quant='none'")
        if self.paged:
            if not paged_supported(cfg):
                raise ValueError(
                    "paged pool is attention-family only "
                    f"(block={cfg.block!r})")
            if max_tokens % page_size:
                raise ValueError(f"max_tokens={max_tokens} must be a "
                                 f"multiple of page_size={page_size}")
            if self.quant and page_size % 8:
                raise ValueError(
                    f"kv_quant={cfg.kv_quant!r} needs page_size divisible "
                    f"by 8 (int8 pages stage through the paged-attention "
                    f"kernel in 8-row sublane granules); got "
                    f"page_size={page_size}")
            # default: same token capacity as the dense pool, plus the null
            # page — paging then costs nothing and saves whatever requests
            # don't use. A smaller num_pages SIMULATES a tighter HBM budget.
            if num_pages is None:
                num_pages = num_slots * (max_tokens // page_size) + 1
            if mesh is not None:
                # the page dim shards over the data-parallel axes
                # (launch/sharding.py) only when it divides them — round up
                # so the pool actually SHARDS instead of silently
                # replicating the whole page store on every dp replica
                # (which would invert the HBM win the pool exists for)
                from repro.launch.mesh import axis_size, dp_axes
                dpn = 1
                for a in dp_axes(mesh):
                    dpn *= axis_size(mesh, a)
                num_pages += -num_pages % dpn
            self.num_pages = num_pages
            self.alloc = PageAllocator(num_pages, page_size,
                                       max_tokens=max_tokens)
            # host mirror of the device block tables ([B, P] int32)
            self.block_table = np.zeros(
                (num_slots, max_tokens // page_size), np.int32)
            self._bt_dirty = False
        # Per-request cross-attn memory arrives batch-1 via each prefill and
        # is splatted in by write_decode_slot — the pool itself always inits
        # the default (zero, [num_slots, ...]) memory rows.
        pool_extras = {k: v for k, v in (extras or {}).items()
                       if k != "memory"}
        self.state = init_decode_state(
            cfg, num_slots, max_tokens, pool_extras, per_slot_t=True,
            paged=(self.num_pages, page_size) if self.paged else None)
        self.shardings = None
        if mesh is not None:
            from repro.launch.sharding import serve_state_shardings
            self.shardings = serve_state_shardings(
                cfg, mesh, num_slots, max_tokens, pool_extras,
                paged=(self.num_pages, page_size) if self.paged else None)
            self.state = self._pin(self.state)
        # host-side slot metadata
        self.owner: list[Request | None] = [None] * num_slots
        self.pending = np.zeros(num_slots, np.int32)    # next input token
        self.remaining = np.zeros(num_slots, np.int64)  # tokens still owed
        self.t_host = np.zeros(num_slots, np.int64)     # next decode position
        self.admitted_total = 0
        # per-slot sampling state (temperature <= 0 -> greedy row)
        self.temps = np.zeros(num_slots, np.float32)
        self.top_ps = np.ones(num_slots, np.float32)
        self.keys = np.zeros((num_slots, 2), np.uint32)  # PRNG key per slot

    def _pin(self, state: dict) -> dict:
        """Reshard `state` onto the canonical pool layout (no-op without a
        mesh)."""
        if self.shardings is None:
            return state
        return jax.device_put(state, self.shardings)

    # ---------------------------------------------------------------- queries

    def free_slots(self) -> list[int]:
        return [i for i, o in enumerate(self.owner) if o is None]

    def num_active(self) -> int:
        return self.num_slots - len(self.free_slots())

    def any_active(self) -> bool:
        return any(o is not None for o in self.owner)

    def active_mask(self) -> np.ndarray:
        return np.array([o is not None for o in self.owner], bool)

    def pages_needed(self, req: Request) -> int:
        """Worst-case page count: every position the request may ever write
        (prompt + full generation)."""
        return pages_for_tokens(req.prompt_len + req.max_new_tokens,
                                self.page_size)

    def can_admit(self, req: Request) -> bool:
        """The scheduler's admission gate: a dense pool only needs the free
        slot the engine already found; a paged pool additionally needs the
        request's worst-case page count to be reservable."""
        return (not self.paged) or self.alloc.can_reserve(
            self.pages_needed(req))

    # -------------------------------------------------------------- lifecycle

    def reserve_pages(self, req: Request) -> None:
        """Reserve a request's worst-case pages ahead of admission (chunked
        prefill claims its budget when the chunk run STARTS, so decode
        growth can never strand a half-prefilled prompt)."""
        if self.paged:
            self.alloc.reserve(req.request_id, self.pages_needed(req))

    def claim_chunk_pages(self, req: Request) -> np.ndarray:
        """Chunk-run page claim: reserve the request's worst case AND
        allocate the pages covering prompt + first decode write up front,
        so every prefill chunk scatters straight into the pool's pages.
        Returns the request's full block-table row (pass it back through
        `admit(page_row=)` when the run completes)."""
        assert self.paged, "chunk-run page claims are paged-pool only"
        self.reserve_pages(req)
        n0 = pages_for_tokens(req.prompt_len + 1, self.page_size)
        ids = self.alloc.alloc(req.request_id, n0)
        row = np.zeros(self.block_table.shape[1], np.int32)
        row[:n0] = ids
        return row

    def admit(self, slot: int, req: Request, slot_state: dict,
              first_token: int, key=None, *, page_row=None) -> None:
        """Install a prefilled request into a free row: write its KV + GO
        cache entries and position in place, arm its first decode input.
        `key` is the slot's sampling PRNG state (already advanced past the
        first token) for temperature > 0 requests. Paged pools allocate the
        pages covering the prompt and the first decode write here; later
        pages arrive lazily via grow_active(). A chunked-prefill run that
        already claimed its pages (claim_chunk_pages) passes its block-table
        row via `page_row` — its KV sits in the pool's pages, so the write
        splats only position/GO state."""
        assert self.owner[slot] is None, f"slot {slot} is occupied"
        if self.paged:
            if page_row is None:
                self.reserve_pages(req)
                n0 = pages_for_tokens(req.prompt_len + 1, self.page_size)
                ids = self.alloc.alloc(req.request_id, n0)
                row = np.zeros(self.block_table.shape[1], np.int32)
                row[:n0] = ids
            else:
                row = np.asarray(page_row, np.int32)
            self.block_table[slot] = row
            self.state = self._pin(_write_slot(
                self.state, slot, slot_state, jnp.asarray(row)))
            if self.quant:
                self._note_dequant_err(slot_state)
        else:
            self.state = self._pin(_write_slot(self.state, slot, slot_state))
        self.owner[slot] = req
        self.pending[slot] = first_token
        self.remaining[slot] = req.max_new_tokens - 1   # first token emitted
        self.t_host[slot] = req.prompt_len
        self.admitted_total += 1
        self.temps[slot] = req.temperature
        self.top_ps[slot] = req.top_p
        self.keys[slot] = 0 if key is None else np.asarray(key, np.uint32)
        req.slot = slot

    def _note_dequant_err(self, slot_state: dict) -> None:
        """Track the observed quantize->dequantize round-trip error of an
        admission's splatted state (running max, surfaced via engine
        stats()). Recomputes the splat quantization — a pure function of
        the prefill values — so the audit needs no fp32 shadow pool."""
        for srck in ("k", "v"):
            if srck not in slot_state:
                continue
            src = jnp.asarray(slot_state[srck])[:, 0].astype(jnp.float32)
            L = src.shape[0]
            pages = src.reshape(L, -1, self.page_size, *src.shape[2:])
            qp, sc = Q.quantize_pages(pages)
            err = float(jnp.abs(pages - Q.dequantize_pages(qp, sc)).max())
            self.dequant_max_abs_err = max(self.dequant_max_abs_err, err)
        go = slot_state.get("go")
        if go is not None:
            out = jnp.asarray(go.outputs).astype(jnp.float32)
            qo, so = Q.quantize_rows(out)
            err = float(jnp.abs(out - Q.dequantize_rows(qo, so)).max())
            self.dequant_max_abs_err = max(self.dequant_max_abs_err, err)

    # --------------------------------------------------------- prefix sharing

    def claim_prefix_ext_pages(self, req: Request, shared) -> np.ndarray:
        """Prefix-extension page claim: map the cached prefix's physical
        pages copy-on-write as the request's leading block-table entries,
        reserve its worst case, and allocate fresh pages for the rest of
        the prompt + first decode write. The caller prefills ONLY the
        non-shared remainder (prefill_chunk starting past the prefix) into
        the fresh pages; the shared pages are never written — the first
        write lands at position len(shared) * page_size or later."""
        assert self.paged, "prefix sharing is paged-pool only"
        rid = req.request_id
        shared = [int(p) for p in shared]
        self.alloc.share(rid, shared)
        self.reserve_pages(req)
        n0 = pages_for_tokens(req.prompt_len + 1, self.page_size)
        fresh = self.alloc.alloc(rid, n0 - len(shared))
        row = np.zeros(self.block_table.shape[1], np.int32)
        row[:len(shared)] = shared
        row[len(shared):n0] = fresh
        return row

    def admit_from_prefix(self, slot: int, req: Request, shared,
                          entry: dict, first_token: int, key=None) -> None:
        """Zero-prefill admission from a full-prompt prefix-index entry:
        block-table surgery in the style of `restore()`. The prompt's full
        pages map the donor's physical pages copy-on-write (`shared` —
        nothing moves on device); the tail positions past the last full
        page are scattered from the entry's host copy into the request's
        FIRST fresh page (they live in the donor's private page, which its
        decode overwrote); GO rows restore from the entry's snapshot (they
        are TopKUpdate history — not recomputable, the reason the entry
        carries them); the first decode input is the token the engine
        derived from the entry's cached prefill logits. The request writes
        only its fresh pages from here on, so the donor and every other
        sharer stay bit-identical."""
        assert self.paged and self.owner[slot] is None
        rid = req.request_id
        n_sh = len(shared)
        shared = [int(p) for p in shared]
        self.alloc.share(rid, shared)
        self.reserve_pages(req)
        n0 = pages_for_tokens(req.prompt_len + 1, self.page_size)
        fresh = self.alloc.alloc(rid, n0 - n_sh)
        row = np.zeros(self.block_table.shape[1], np.int32)
        row[:n_sh] = shared
        row[n_sh:n0] = fresh
        self.block_table[slot] = row
        tail = req.prompt_len - n_sh * self.page_size
        if tail:
            pid = int(fresh[0])
            self.state["k_pages"] = self.state["k_pages"].at[
                :, pid, :tail].set(jnp.asarray(entry["tail_k"]).astype(
                    self.state["k_pages"].dtype))
            self.state["v_pages"] = self.state["v_pages"].at[
                :, pid, :tail].set(jnp.asarray(entry["tail_v"]).astype(
                    self.state["v_pages"].dtype))
            if self.quant:
                # the tail page's scales travel with its int8 bytes — the
                # consumer's decode grows this page under the donor's
                # exact scale, so shared-prefix streams stay deterministic
                self.state["k_scales"] = self.state["k_scales"].at[
                    :, pid].set(jnp.asarray(entry["tail_ks"]))
                self.state["v_scales"] = self.state["v_scales"].at[
                    :, pid].set(jnp.asarray(entry["tail_vs"]))
        self.state["t"] = self.state["t"].at[slot].set(req.prompt_len)
        if "go" in self.state:
            self.state["go"] = jax.tree.map(
                lambda a, r: a.at[:, slot].set(jnp.asarray(r).astype(a.dtype)),
                self.state["go"], entry["go"])
        if "go_scales" in self.state:
            self.state["go_scales"] = self.state["go_scales"].at[
                :, slot].set(jnp.asarray(entry["go_scales"]))
        self._push_block_table()
        self.state = self._pin(self.state)
        self.owner[slot] = req
        self.pending[slot] = first_token
        self.remaining[slot] = req.max_new_tokens - 1   # first token emitted
        self.t_host[slot] = req.prompt_len
        self.admitted_total += 1
        self.temps[slot] = req.temperature
        self.top_ps[slot] = req.top_p
        self.keys[slot] = 0 if key is None else np.asarray(key, np.uint32)
        req.slot = slot

    def grow_active(self) -> None:
        """Paged pools: make sure every active slot owns the page its NEXT
        decode write lands in (position t_host). Reservations guarantee the
        grow succeeds; call once per engine tick, before the decode step."""
        if not self.paged:
            return
        for slot, req in enumerate(self.owner):
            if req is None:
                continue
            idx = int(self.t_host[slot]) // self.page_size
            if idx < self.block_table.shape[1] and \
                    self.block_table[slot, idx] == 0:
                self.block_table[slot, idx] = self.alloc.grow(req.request_id)
                self._bt_dirty = True
        if self._bt_dirty:
            self._push_block_table()

    def _push_block_table(self) -> None:
        bt = jnp.asarray(self.block_table)
        if self.shardings is not None:
            bt = jax.device_put(bt, self.shardings["block_table"])
        self.state["block_table"] = bt
        self._bt_dirty = False

    def note_decoded(self) -> None:
        """Advance the host mirror of each active slot's position after a
        decode tick (keeps grow_active off the device)."""
        for slot, req in enumerate(self.owner):
            if req is not None:
                self.t_host[slot] += 1

    def release_pages(self, rid: int) -> None:
        """Drop every page reference `rid` holds (request retirement, chunk
        cancellation, prefix-index eviction all route here) and zero the
        scrub-marked pages among those actually RELEASED — shared pages
        survive until their last owner frees them, so the scrub fires
        exactly on last free."""
        if self.paged:
            self.scrub_released(self.alloc.free(rid))

    def scrub_released(self, released) -> None:
        """Zero the deferred-scrub pages among just-released `released`
        (PR 7's NaN quarantine: 0 * NaN is NaN, so a poisoned page must be
        cleaned before any future stream can map it — but not before its
        LAST reference drops, other owners may still be reading it)."""
        if not self.paged or not released:
            return
        changed = False
        if self.quant:
            # EVERY released page returns with zeroed scales (not just the
            # scrub-marked ones): the rescale-on-write contract makes a
            # page's contents a pure function of the tokens written to it
            # ONLY if it starts from scale 0 — an inherited amax would
            # quantize a reused page differently than a fresh one, breaking
            # deterministic preempt/resume parity. (The first write into a
            # scale-0 page also rescales the stale int8 bytes by factor 0,
            # so old contents never survive reuse.)
            ids = jnp.asarray(sorted(released), jnp.int32)
            self.state["k_scales"] = self.state["k_scales"].at[:, ids].set(0)
            self.state["v_scales"] = self.state["v_scales"].at[:, ids].set(0)
            changed = True
        dirty = self.alloc.pop_dirty(released)
        if dirty:
            ids = jnp.asarray(dirty, jnp.int32)
            self.state["k_pages"] = self.state["k_pages"].at[:, ids].set(0)
            self.state["v_pages"] = self.state["v_pages"].at[:, ids].set(0)
            changed = True
        if changed:
            self.state = self._pin(self.state)

    def retire(self, slot: int, *, scrub: bool = False) -> Request:
        """Free a row: clear its caches (GO scores to -inf) and return the
        finished request. The row is immediately reusable. Paged pools
        return the slot's pages to the allocator on this same path — the
        page CONTENTS are normally left as-is (finite garbage is harmless:
        stale positions are score-masked, and 0-weighted FINITE values
        vanish from the attention sum). `scrub=True` marks the pages for a
        zero-on-last-free scrub — required when quarantining a NON-FINITE
        slot, because 0 * NaN is NaN: a poisoned page handed to a future
        stream would leak straight through the mask on the value side.
        (Marked pages still shared with live owners are zeroed when their
        final reference drops; only the slot's PRIVATE pages can actually
        carry NaN — poison_slot forks shared pages before writing.)"""
        req = self.owner[slot]
        assert req is not None, f"slot {slot} is already free"
        if self.paged:
            if scrub:
                self.alloc.mark_scrub(req.request_id)
            self.release_pages(req.request_id)
            self.block_table[slot] = 0
        self.state = self._pin(_reset_slot(self.state, slot))
        self.owner[slot] = None
        self.pending[slot] = 0
        self.remaining[slot] = 0
        self.t_host[slot] = 0
        self.temps[slot] = 0.0
        self.top_ps[slot] = 1.0
        self.keys[slot] = 0
        return req

    # ------------------------------------------------------------- preemption

    def snapshot(self, slot: int) -> dict:
        """Host-side eviction snapshot of an active PAGED slot: the slot's
        LIVE KV pages (device -> host), its GO rows, and its decode cursor /
        sampling state. Restoring this via `restore()` is bit-identical to
        never evicting — unlike recomputing the KV by re-prefilling, which
        is NOT bit-exact (full-sequence prefill matmuls differ bitwise from
        incremental decode ones) and cannot reproduce an expert-choice GO
        cache at all (the decode-time GO rows are TopKUpdate history over
        per-step capacities, not a function of re-routing the sequence)."""
        assert self.paged, "preemption snapshots are paged-pool only"
        req = self.owner[slot]
        assert req is not None, f"slot {slot} is free"
        row = self.block_table[slot]
        n = int((row != 0).sum())
        assert (row[:n] != 0).all(), "block table is not a contiguous prefix"
        ids = row[:n].copy()
        snap = {
            "t": int(self.t_host[slot]),
            "pending": int(self.pending[slot]),
            "remaining": int(self.remaining[slot]),
            "temp": float(self.temps[slot]),
            "top_p": float(self.top_ps[slot]),
            "key": self.keys[slot].copy(),
            "n_pages": n,
            "k": np.asarray(self.state["k_pages"][:, ids]),
            "v": np.asarray(self.state["v_pages"][:, ids]),
        }
        if self.quant:
            snap["ks"] = np.asarray(self.state["k_scales"][:, ids])
            snap["vs"] = np.asarray(self.state["v_scales"][:, ids])
        if "go" in self.state:
            snap["go"] = jax.tree.map(lambda a: np.asarray(a[:, slot]),
                                      self.state["go"])
        if "go_scales" in self.state:
            snap["go_scales"] = np.asarray(self.state["go_scales"][:, slot])
        return snap

    def pages_for_resume(self, snap: dict) -> int:
        """Worst-case page count to finish a snapshotted stream: every
        position it has written plus every token it still owes."""
        return pages_for_tokens(snap["t"] + snap["remaining"], self.page_size)

    def can_resume(self, snap: dict) -> bool:
        return self.alloc.can_reserve(self.pages_for_resume(snap))

    def restore(self, slot: int, req: Request, snap: dict) -> None:
        """Re-admit a preempted request from its eviction snapshot: reserve
        its remaining worst case, allocate fresh physical pages for the live
        prefix, scatter the snapshot back in, and rebuild the slot's block
        table + GO rows + cursor — block-table surgery, no recompute."""
        assert self.paged and self.owner[slot] is None
        rid = req.request_id
        self.alloc.reserve(rid, self.pages_for_resume(snap))
        ids = self.alloc.alloc(rid, snap["n_pages"])
        row = np.zeros(self.block_table.shape[1], np.int32)
        row[:len(ids)] = ids
        self.block_table[slot] = row
        jids = jnp.asarray(ids, jnp.int32)
        self.state["k_pages"] = _scatter_pages(
            self.state["k_pages"], jids, jnp.asarray(snap["k"]))
        self.state["v_pages"] = _scatter_pages(
            self.state["v_pages"], jids, jnp.asarray(snap["v"]))
        if self.quant:
            # int8 pages restore verbatim WITH their scales — resume is
            # bit-identical to never evicting, same as the fp32 pool
            self.state["k_scales"] = _scatter_pages(
                self.state["k_scales"], jids, jnp.asarray(snap["ks"]))
            self.state["v_scales"] = _scatter_pages(
                self.state["v_scales"], jids, jnp.asarray(snap["vs"]))
        self.state["t"] = self.state["t"].at[slot].set(snap["t"])
        if "go" in self.state:
            self.state["go"] = jax.tree.map(
                lambda a, r: a.at[:, slot].set(jnp.asarray(r).astype(a.dtype)),
                self.state["go"], snap["go"])
        if "go_scales" in self.state:
            self.state["go_scales"] = self.state["go_scales"].at[
                :, slot].set(jnp.asarray(snap["go_scales"]))
        self._push_block_table()
        self.state = self._pin(self.state)
        self.owner[slot] = req
        self.pending[slot] = snap["pending"]
        self.remaining[slot] = snap["remaining"]
        self.t_host[slot] = snap["t"]
        self.temps[slot] = snap["temp"]
        self.top_ps[slot] = snap["top_p"]
        self.keys[slot] = snap["key"]
        self.admitted_total += 1
        req.slot = slot

    # --------------------------------------------------------- fault injection

    def poison_slot(self, slot: int) -> None:
        """Chaos hook: corrupt one slot's decode state with NaN (its most
        recently written KV position — always inside the attention window)
        so the NEXT decode tick produces non-finite logits for that row and
        ONLY that row (every batched op is row-wise independent). The engine
        must quarantine the slot without touching its cohabitants."""
        assert self.owner[slot] is not None, f"slot {slot} is free"
        t = max(0, int(self.t_host[slot]) - 1)
        if self.paged:
            idx = t // self.page_size
            page = int(self.block_table[slot, idx])
            if self.alloc.refcount(page) > 1:
                # the target position sits in a SHARED prefix page (this is
                # the divergent write the COW contract forbids in place) —
                # fork a private copy first so the donor and every other
                # sharer keep their clean state. No spare page beyond the
                # in-flight reservations -> skip this fault injection;
                # stealing a promised page would break deadlock freedom.
                if not self.alloc.can_reserve(1):
                    return
                new = self.alloc.fork(
                    self.owner[slot].request_id, page)
                self.state["k_pages"] = self.state["k_pages"].at[:, new].set(
                    self.state["k_pages"][:, page])
                self.state["v_pages"] = self.state["v_pages"].at[:, new].set(
                    self.state["v_pages"][:, page])
                if self.quant:
                    # a forked int8 page is only meaningful WITH its scale
                    self.state["k_scales"] = self.state["k_scales"].at[
                        :, new].set(self.state["k_scales"][:, page])
                    self.state["v_scales"] = self.state["v_scales"].at[
                        :, new].set(self.state["v_scales"][:, page])
                self.block_table[slot, idx] = new
                self._push_block_table()
                page = new
            off = t % self.page_size
            if self.quant:
                # NaN cannot be stored in int8 pages — poison the page's
                # SCALE instead: dequant makes the whole page NaN, which
                # still reaches the next tick's logits for this row only.
                # Quarantine scrubs the scale back to 0 with the page.
                self.state["k_scales"] = \
                    self.state["k_scales"].at[:, page].set(jnp.nan)
            else:
                self.state["k_pages"] = \
                    self.state["k_pages"].at[:, page, off].set(jnp.nan)
        elif "k" in self.state:
            self.state["k"] = self.state["k"].at[:, slot, t].set(jnp.nan)
        else:
            # recurrent archs: no KV rows — poison the slot's carried state
            # (batch axes per key match init_decode_slot: ssm/slstm -> 1,
            # mlstm -> 2); integer leaves are left alone
            def rot(a, batch_axis):
                if not jnp.issubdtype(a.dtype, jnp.floating):
                    return a
                idx = (slice(None),) * batch_axis + (slot,)
                return a.at[idx].set(jnp.nan)
            for key, ax in (("ssm", 1), ("mlstm", 2), ("slstm", 1)):
                if key in self.state:
                    self.state[key] = jax.tree.map(
                        lambda a: rot(a, ax), self.state[key])
        self.state = self._pin(self.state)

    # -------------------------------------------------------------- invariants

    def audit(self) -> None:
        """Pool/slot invariant sweep (REPRO_AUDIT=1 runs it every engine
        tick): allocator consistency, block tables as contiguous prefixes
        matching exactly the allocator's ownership, host/device position
        mirrors in sync, live metadata sane, freed slots fully cleared."""
        if self.paged:
            self.alloc.check()
        dev_t = np.asarray(self.state["t"])
        for slot, req in enumerate(self.owner):
            if req is None:
                assert self.remaining[slot] == 0 and self.t_host[slot] == 0, \
                    f"freed slot {slot} has stale metadata"
                assert dev_t[slot] == 0, \
                    f"freed slot {slot}: device t={dev_t[slot]} not reset"
                if self.paged:
                    assert (self.block_table[slot] == 0).all(), \
                        f"freed slot {slot} still maps pages"
                continue
            assert self.remaining[slot] > 0, \
                f"active slot {slot} owes no tokens"
            t = int(self.t_host[slot])
            assert 0 < t <= self.max_tokens, f"slot {slot}: t={t} out of range"
            assert dev_t[slot] == t, \
                f"slot {slot}: device t={dev_t[slot]} != host t={t}"
            if self.paged:
                row = self.block_table[slot]
                n = int((row != 0).sum())
                assert (row[:n] != 0).all() and (row[n:] == 0).all(), \
                    f"slot {slot}: block table not a contiguous prefix"
                owned = self.alloc.owned(req.request_id)
                assert set(row[:n].tolist()) == set(owned), \
                    f"slot {slot}: block table != allocator ownership"
                assert n >= pages_for_tokens(t, self.page_size), \
                    f"slot {slot}: {n} pages cannot back {t} positions"
        if self.quant:
            # scale hygiene: free pages must carry scale 0 (the
            # rescale-on-write determinism contract — see core/quant.py),
            # and no scale may be inf. NaN is tolerated on LIVE pages only:
            # it is the deliberate poison_slot fault on its way to the
            # engine's quarantine sweep.
            live = set(self.alloc.refcounts())
            ks = np.asarray(self.state["k_scales"])
            vs = np.asarray(self.state["v_scales"])
            free = sorted(set(range(1, self.num_pages)) - live)
            for name, s in (("k_scales", ks), ("v_scales", vs)):
                assert not np.isinf(s).any(), f"{name} has inf entries"
                if free:
                    fs = s[:, free]
                    assert (fs == 0).all(), \
                        f"{name}: freed pages carry non-zero scales " \
                        f"(pages {free[:8]}...) — scrub_released must zero " \
                        f"scales on every release"
            if "go_scales" in self.state:
                # freed slots' GO rows still flow through the masked decode
                # math each tick (exactly like the fp32 pool's — overwritten
                # at the next admission), so only finiteness is asserted
                gs = np.asarray(self.state["go_scales"])
                assert np.isfinite(gs).all(), \
                    "go_scales has non-finite entries"
