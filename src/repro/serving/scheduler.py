"""Admission / retirement scheduling for the continuous-batching engine.

Host-side only (numpy, no jax): the scheduler decides WHICH request enters
the pool next; the pool/engine decide WHERE (free slot / which pages) and do
the device work. Policy knobs:

  max_slots   pool width — at most this many requests in flight at once
  max_tokens  pool sequence capacity — prompt + generation of every request
              must fit (enforced at submit; nothing is silently truncated)
  max_queue   optional backlog bound (0 = unbounded) over queued AND
              not-yet-arrived trace requests; submit raises when the backlog
              is full, the serving analogue of load-shedding

Admission order is a PRIORITY HEAP: requests carry `priority` (int, lower =
admitted earlier, 0 default) and the heap breaks ties by submission order —
FIFO within a priority level, so equal-priority requests can never starve
each other (pinned in tests/test_serving.py). This is the first step toward
Sieve-style expert-aware admission: a cost model only has to assign
priorities, the ordering machinery is already here.

Admission can be gated by a `can_admit` predicate (the paged pool's "are
enough pages reservable?" question). The gate applies to the HEAD of the
heap only — a blocked head blocks everything behind it rather than letting
smaller requests overtake, which keeps the order starvation-free.

Requests may carry an `arrival_step`: the trace-replay hook used by the
staggered-arrival tests and the Poisson-trace throughput benchmark. Such a
request stays in the `pending` list until the engine's step counter reaches
its arrival step, then joins the admission heap (keyed by its SUBMIT order,
so same-tick arrivals stay FIFO).

LIFECYCLE: every request carries a typed `status` and ends in exactly one
terminal state — DONE (EOS/length), TIMEOUT (deadline_s from submission or
max_wall_s from first admission exceeded), CANCELLED (engine.cancel), or
FAILED (non-finite logits quarantined by the engine). PREEMPTED is the one
non-terminal excursion out of ACTIVE: a page-pressure eviction parks the
request back in this heap (requeue — it keeps its original submit order, so
it resumes at the head of its priority class) until its pages are
reservable again.
"""
from __future__ import annotations

import enum
import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np


class RequestStatus(str, enum.Enum):
    """Request lifecycle states. str-mixin so `status == "DONE"` works."""

    QUEUED = "QUEUED"          # waiting for admission (incl. trace-deferred)
    ACTIVE = "ACTIVE"          # occupying a slot (prefilling or decoding)
    PREEMPTED = "PREEMPTED"    # evicted under page pressure, awaiting resume
    DONE = "DONE"              # terminal: EOS or length
    TIMEOUT = "TIMEOUT"        # terminal: deadline_s / max_wall_s exceeded
    CANCELLED = "CANCELLED"    # terminal: engine.cancel(rid)
    FAILED = "FAILED"          # terminal: quarantined (non-finite logits)


TERMINAL_STATUSES = frozenset({
    RequestStatus.DONE, RequestStatus.TIMEOUT,
    RequestStatus.CANCELLED, RequestStatus.FAILED,
})


class QueueFull(RuntimeError):
    """Typed backpressure signal: the admission backlog is at max_queue.
    Carries the observed depth so callers can shed load proportionally."""

    def __init__(self, depth: int, max_queue: int):
        self.depth = depth
        self.max_queue = max_queue
        super().__init__(
            f"admission queue full: depth {depth} >= max_queue {max_queue}")


class RequestTooLarge(ValueError):
    """Typed submit-time rejection: the request could never fit the pool
    (prompt + max_new_tokens over max_tokens, or over the paged pool's
    usable page count), so admitting it would stall the queue forever."""


@dataclass
class Request:
    """One generation request plus its lifecycle bookkeeping."""

    request_id: int
    prompt: np.ndarray               # [T] int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    extras: dict | None = None       # per-request cross-attn memory (vlm/audio)
    arrival_step: int = 0            # engine step at which the request arrives
    priority: int = 0                # admission class: lower = admitted first
    # --- sampling (temperature <= 0 -> greedy, the default) ---
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int | None = None          # None -> derived from request_id
    # --- deadlines (None = unbounded) ---
    deadline_s: float | None = None  # wall budget from submission
    max_wall_s: float | None = None  # wall budget from FIRST admission
    # --- routing fingerprint (expert-aware admission; None = unknown) ---
    # bool [num_experts]: which experts this request's prompt is PREDICTED
    # to touch. Filled by the engine (layer-0 gate probe at submit, refined
    # from the observed GO rows at admission). Purely a scheduling hint —
    # never consulted on any compute path, so a wrong prediction costs
    # batch composition quality, not correctness.
    expert_sig: object = None

    # --- filled in by the scheduler ---
    # times an ExpertAwareScheduler's cost model admitted a different
    # same-priority candidate past this one; at max_skips the request is
    # force-admitted regardless of score (the starvation bound)
    times_skipped: int = 0

    # --- filled in by the engine ---
    status: RequestStatus = RequestStatus.QUEUED
    fail_reason: str | None = None   # set on FAILED/TIMEOUT/CANCELLED
    arrival_time: float = 0.0        # wall-clock when it joined the queue
    submit_time: float = 0.0         # wall-clock at submit (deadline_s anchor)
    admit_time: float = 0.0          # wall-clock at FIRST admission
    admit_step: int = -1
    finish_step: int = -1
    finish_time: float = 0.0
    slot: int = -1                   # slot it was admitted into
    seq: int = -1                    # scheduler submit order (heap tie-break)
    preemptions: int = 0             # times evicted under page pressure
    tokens: list[int] = field(default_factory=list)

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.arrival_time

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def expired(self, now: float) -> bool:
        """Has either wall budget run out? deadline_s counts from submit
        (queue wait included); max_wall_s counts from first admission and
        keeps counting across preemptions (the request is still holding a
        snapshot, i.e. engine memory)."""
        if self.deadline_s is not None and \
                now - self.submit_time > self.deadline_s:
            return True
        if self.max_wall_s is not None and self.admit_time > 0 and \
                now - self.admit_time > self.max_wall_s:
            return True
        return False


class FIFOScheduler:
    """Priority-heap admission (FIFO within a level) with the max-slots /
    max-tokens policy. The historical name survives because priority 0 is
    the default — an all-default workload IS a FIFO queue."""

    def __init__(self, max_slots: int, max_tokens: int, max_queue: int = 0):
        self.max_slots = max_slots
        self.max_tokens = max_tokens
        self.max_queue = max_queue
        self.queue: list[tuple[int, int, Request]] = []      # (prio, seq, req)
        self._pending: list[tuple[int, int, Request]] = []   # arrival-step heap
        self._seq = itertools.count()                        # submit order

    # ------------------------------------------------------------- submission

    def submit(self, req: Request, *, now_step: int = 0) -> None:
        """Queue a request (immediately, or at its arrival_step if later).
        Raises typed rejections: RequestTooLarge for a request that could
        never fit the pool, QueueFull (carrying the depth) at max_queue."""
        need = req.prompt_len + req.max_new_tokens
        if need > self.max_tokens:
            raise RequestTooLarge(
                f"request {req.request_id}: prompt({req.prompt_len}) + "
                f"max_new_tokens({req.max_new_tokens}) = {need} exceeds the "
                f"pool's max_tokens={self.max_tokens}")
        backlog = len(self.queue) + len(self._pending)
        if self.max_queue and backlog >= self.max_queue:
            raise QueueFull(backlog, self.max_queue)
        req.seq = next(self._seq)
        req.status = RequestStatus.QUEUED
        if req.arrival_step > now_step:
            heapq.heappush(self._pending, (req.arrival_step, req.seq, req))
            return
        heapq.heappush(self.queue, (req.priority, req.seq, req))

    def requeue(self, req: Request) -> None:
        """Put a PREEMPTED request back in the admission heap under its
        ORIGINAL submit order: it resumes ahead of everything submitted
        after it in its priority class (no progress lost to overtaking).
        Bypasses max_queue — the request was already admitted once, so
        bouncing it now would turn backpressure into data loss."""
        assert req.seq >= 0, "requeue() is for previously-submitted requests"
        heapq.heappush(self.queue, (req.priority, req.seq, req))

    def poll(self, step: int) -> list[Request]:
        """Move trace-replay requests whose arrival step has come into the
        admission heap; returns the newly arrived requests."""
        arrived = []
        while self._pending and self._pending[0][0] <= step:
            _, seq, req = heapq.heappop(self._pending)
            heapq.heappush(self.queue, (req.priority, seq, req))
            arrived.append(req)
        return arrived

    # -------------------------------------------------------------- admission

    def next_admission(self, num_active: int,
                       can_admit=None) -> Request | None:
        """Pop the next request to admit, or None (empty heap, the pool is
        already at max_slots, or `can_admit` rejects the head — e.g. the
        paged pool cannot reserve its worst-case page count yet)."""
        if not self.queue or num_active >= self.max_slots:
            return None
        head = self.queue[0][2]
        if can_admit is not None and not can_admit(head):
            return None
        return heapq.heappop(self.queue)[2]

    # ------------------------------------------------------ removal / expiry

    def remove(self, rid: int) -> Request | None:
        """Pull a request out of the admission heap / pending trace list by
        id (cancellation before admission). Returns it, or None if it is
        not queued here."""
        for heap in (self.queue, self._pending):
            for i, (_, _, req) in enumerate(heap):
                if req.request_id == rid:
                    heap.pop(i)
                    heapq.heapify(heap)
                    return req
        return None

    def expire(self, now: float) -> list[Request]:
        """Drop every queued/pending request whose wall budget has run out
        (Request.expired) and return them; the engine marks them TIMEOUT.
        Covers PREEMPTED requests parked here awaiting resume."""
        out = [req for _, _, req in self.queue if req.expired(now)]
        out += [req for _, _, req in self._pending if req.expired(now)]
        if out:
            gone = {r.request_id for r in out}
            self.queue = [e for e in self.queue
                          if e[2].request_id not in gone]
            heapq.heapify(self.queue)
            self._pending = [e for e in self._pending
                             if e[2].request_id not in gone]
            heapq.heapify(self._pending)
        return out

    def has_pending(self) -> bool:
        return bool(self.queue) or bool(self._pending)

    def next_arrival_step(self) -> int | None:
        """Earliest future arrival step (None when no trace-replay requests
        remain) — lets an idle engine fast-forward its tick counter."""
        return self._pending[0][0] if self._pending else None


class ExpertAwareScheduler(FIFOScheduler):
    """Admission driven by a routing-overlap cost model instead of pure
    arrival order (Sieve-style: per-expert load EWMAs track expert
    popularity as it evolves; the HD-MoE insight that batch composition
    should key off OBSERVED routing).

    The objective is the planner's occupancy telemetry: a decode tick over
    requests that route to the same few experts packs those experts' tiles
    full, while a batch spread across many experts pays tile setup for
    mostly-empty lanes. So within the head priority class, admission picks
    the candidate whose predicted expert signature

      * overlaps most with the union of the ACTIVE batch's signatures
        (reuses experts the tick already pays for),
      * introduces fewest NEW experts, and
      * avoids hot experts (EWMA load — spreading arrivals away from
        recently-popular experts keeps per-expert queueing bounded as
        popularity drifts).

    STRICT PRIORITY is inherited unchanged: candidates come only from the
    head priority class, so a lower class never overtakes. STARVATION
    within the class is bounded by an explicit AGING CAP, not by the scan
    window (the window bounds the SCAN, not how often a candidate can be
    passed over — an old request with a disjoint signature could otherwise
    be skipped forever while overlapping same-priority arrivals keep
    coming): every time the cost model admits past a scanned candidate its
    `times_skipped` rises, and a candidate at `max_skips` is FORCE-ADMITTED
    (oldest such first) regardless of score. So any request is admitted
    after at most `max_skips` same-class admissions overtake it, no matter
    how the active set churns. Requests with no signature (dense prompts,
    probe disabled) score 0 — an all-None workload degenerates to EXACT
    FIFO order including head-blocking semantics, which is what keeps the
    existing test matrix green (ties break by submit order, so nothing is
    ever skipped and the aging cap never engages).

    Correctness-neutral by design: admission ORDER is the only output; the
    decode math of an admitted request is row-independent, so streams stay
    bit-identical to the FIFO path no matter how this reorders them."""

    def __init__(self, max_slots: int, max_tokens: int, max_queue: int = 0,
                 *, num_experts: int, ewma_alpha: float = 0.25,
                 window: int = 8, load_weight: float = 0.125,
                 max_skips: int = 16):
        super().__init__(max_slots, max_tokens, max_queue)
        self.num_experts = num_experts
        self.ewma_alpha = ewma_alpha
        self.window = window
        self.load_weight = load_weight
        self.max_skips = max_skips
        self.load = np.zeros(num_experts, np.float64)  # per-expert EWMA
        self._active_union = np.zeros(num_experts, bool)
        # the request the page gate rejected this tick (the preemption
        # machinery frees pages for THIS one, not the arrival-order head)
        self.last_blocked: Request | None = None

    # ------------------------------------------------------------ observation

    def observe(self, sig) -> None:
        """Fold one admitted request's observed/predicted routing into the
        per-expert load EWMAs (Sieve's evolving-popularity signal)."""
        if sig is None:
            return
        self.load *= 1.0 - self.ewma_alpha
        self.load[np.asarray(sig, bool)] += self.ewma_alpha

    def note_active(self, sigs) -> None:
        """Refresh the active batch's expert-union (engine calls this with
        the signatures of every slot owner before asking for admissions)."""
        u = np.zeros(self.num_experts, bool)
        for s in sigs:
            if s is not None:
                u |= np.asarray(s, bool)
        self._active_union = u

    # -------------------------------------------------------------- admission

    def score(self, req: Request) -> float:
        """Higher = admit sooner. 0 for unknown signatures so unscored
        requests neither jump nor yield within their class."""
        if req.expert_sig is None:
            return 0.0
        sig = np.asarray(req.expert_sig, bool)
        new = sig & ~self._active_union
        overlap = int((sig & self._active_union).sum())
        return overlap - int(new.sum()) - \
            self.load_weight * float(self.load[new].sum())

    def victim_bonus(self, sig, other_sigs) -> int:
        """Preemption cost model: how many experts does this victim touch
        that NO other active request needs? Evicting the request with the
        most unique experts shrinks the tick's expert set the most."""
        if sig is None:
            return 0
        others = np.zeros(self.num_experts, bool)
        for s in other_sigs:
            if s is not None:
                others |= np.asarray(s, bool)
        return int((np.asarray(sig, bool) & ~others).sum())

    def next_admission(self, num_active: int,
                       can_admit=None) -> Request | None:
        """Pick the best-scoring candidate among the first `window`
        same-priority entries at the head of the heap — unless a scanned
        candidate has already been passed over `max_skips` times, in which
        case the OLDEST such candidate is force-admitted (the starvation
        bound; skips only count when an admission actually happens, so a
        blocked tick ages nobody). The page gate applies to the CHOSEN
        candidate (its identity is remembered in `last_blocked` so
        preemption frees pages for it, not for the arrival-order head)."""
        self.last_blocked = None
        if not self.queue or num_active >= self.max_slots:
            return None
        head_prio = self.queue[0][0]
        cands = heapq.nsmallest(
            self.window, (e for e in self.queue if e[0] == head_prio))
        forced = [e for e in cands if e[2].times_skipped >= self.max_skips]
        best = min(forced) if forced else \
            min(cands, key=lambda e: (-self.score(e[2]), e[1]))
        req = best[2]
        if can_admit is not None and not can_admit(req):
            self.last_blocked = req
            return None
        for e in cands:
            if e is not best:
                e[2].times_skipped += 1
        req.times_skipped = 0
        self.queue.remove(best)
        heapq.heapify(self.queue)
        return req
