"""Grouped-query attention with a memory-efficient (flash-style) chunked core.

Pure-JAX XLA path used by training / prefill / the multi-pod dry-run. The
double-chunked online-softmax scan bounds the materialized score block to
[B, H, cq, ck] regardless of GSPMD propagation, which is what lets the 32k
prefill cells fit HBM. (On real TPU the Pallas flash kernel would replace the
inner loop; Pallas cannot be *lowered* for TPU from this CPU-only container,
so the XLA path is the dry-run/compile path.)

Supports: GQA (num_kv_heads < num_heads), RoPE, causal / sliding-window /
bidirectional / cross masks, QKV bias (qwen2), logit softcap, single-token
decode against a KV cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.models.layers import (apply_rope, dense_init, dp_spec, mesh_axis,
                                 shard_hint, split)

NEG_INF = -1e30


# ----------------------------------------------------------------------- init

def attn_init(key, cfg, *, d_model: int = 0, cross: bool = False) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = split(key, 4)
    p = {
        "wq": dense_init(k1, d, nq * hd, dt),
        "wk": dense_init(k2, d, nkv * hd, dt),
        "wv": dense_init(k3, d, nkv * hd, dt),
        "wo": dense_init(k4, nq * hd, d, dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


# ----------------------------------------------------------- chunked SDPA core

@partial(
    jax.jit,
    static_argnames=("causal", "softcap", "ck", "sp_attn"),
)
def sdpa_chunked(
    q: jax.Array,            # [B, Sq, Hq, D]
    k: jax.Array,            # [B, Sk, Hkv, D]
    v: jax.Array,            # [B, Sk, Hkv, D]
    q_pos: jax.Array,        # [Sq] int32 absolute positions of queries
    k_pos: jax.Array,        # [Sk] int32 absolute positions of keys
    window,                  # traced int32 scalar: 0 => global, >0 => local span
    kv_len,                  # traced int32 scalar: keys with k_pos >= kv_len masked
    *,
    causal: bool,
    softcap: float = 0.0,
    ck: int = 1024,
    sp_attn: bool = False,
) -> jax.Array:
    """Flash-style online-softmax attention, scanned over KV chunks only.

    Queries keep their natural [B, Sq, ...] layout so GSPMD shards the score
    blocks natively: head-parallel when Hkv divides the model axis (Megatron),
    else sequence-parallel on Sq (SP attention for odd head counts). The KV
    chunk axis is the scan axis and is never sharded; materialized score block
    is [B, Sq_local, Hkv, G, ck].
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    ck = min(ck, Sk)
    while Sk % ck:
        ck -= 1
    nk = Sk // ck
    scale = 1.0 / (D ** 0.5)

    # --- model-axis work split for the score/PV blocks -------------------
    # Hkv | M : Megatron head-parallel attention on the kv-head dim.
    # Hq  | M : GQA with too few kv heads — expand K/V to Hq ("repeat_kv")
    #           and shard the query-head dim; kv replication cost is tiny.
    # otherwise: attention replicated on the model axis (odd head counts,
    #           e.g. 24/28 heads over 16); everything else stays TP.
    M = mesh_axis("model")
    dp = dp_spec()
    expand = M > 1 and Hkv % M != 0 and Hq % M == 0
    if expand:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        Hkv, G = Hq, 1

    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D).astype(q.dtype)

    if M > 1 and Hkv % M == 0:
        qg = shard_hint(qg, dp, None, "model", None, None)
        k = shard_hint(k, dp, None, "model", None)
        v = shard_hint(v, dp, None, "model", None)
    elif sp_attn and M > 1 and Sq % M == 0:
        # sequence-parallel score blocks (forward-only paths; §Perf knob for
        # odd head counts — kv replicated, q rows sharded)
        qg = shard_hint(qg, dp, "model", None, None, None)

    kc = k.reshape(B, nk, ck, Hkv, D).transpose(1, 0, 2, 3, 4)  # [nk,B,ck,Hkv,D]
    vc = v.reshape(B, nk, ck, Hkv, D).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nk, ck)

    def kv_step(carry, kv):
        m, l, acc = carry                              # [B,Sq,Hkv,G](,D)
        kb, vb, kpb = kv                               # [B,ck,Hkv,D], ..., [ck]
        s = jnp.einsum(
            "bqhgd,bkhd->bqhgk", qg, kb, preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpb[None, :] < kv_len
        if causal:
            mask &= kpb[None, :] <= q_pos[:, None]
        mask &= jnp.where(
            window > 0, kpb[None, :] > q_pos[:, None] - window, True)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ------------------------------------------------- flash backward (custom VJP)

def _mask_block(kpb, q_pos, kv_len, window, causal: bool):
    mask = kpb[None, :] < kv_len
    if causal:
        mask &= kpb[None, :] <= q_pos[:, None]
    mask &= jnp.where(window > 0, kpb[None, :] > q_pos[:, None] - window, True)
    return mask                                            # [Sq, ck]


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def sdpa_flash(q, k, v, q_pos, k_pos, window, kv_len, causal, softcap, ck):
    return _flash_fwd(q, k, v, q_pos, k_pos, window, kv_len,
                      causal, softcap, ck)[0]


def _flash_fwd(q, k, v, q_pos, k_pos, window, kv_len, causal, softcap, ck):
    """Online-softmax forward that also returns the row statistics (m, l) —
    the only residuals the backward needs besides (q, k, v, out)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    ckk = min(ck, Sk)
    while Sk % ckk:
        ckk -= 1
    nk = Sk // ckk
    scale = 1.0 / (D ** 0.5)
    M = mesh_axis("model")
    dp = dp_spec()
    expand = M > 1 and Hkv % M != 0 and Hq % M == 0
    if expand:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        Hkv, G = Hq, 1
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D).astype(q.dtype)
    if M > 1 and Hkv % M == 0:
        qg = shard_hint(qg, dp, None, "model", None, None)
        k = shard_hint(k, dp, None, "model", None)
        v = shard_hint(v, dp, None, "model", None)
    kc = k.reshape(B, nk, ckk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ckk, Hkv, D).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nk, ckk)

    def kv_step(carry, kv):
        m, l, acc = carry
        kb, vb, kpb = kv
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb,
                       preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = _mask_block(kpb, q_pos, kv_len, window, causal)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        kv_step, (m0, l0, a0), (kc, vc, kp), unroll=1)
    l = jnp.maximum(l, 1e-20)
    out = (acc / l[..., None]).reshape(B, Sq, Hq, D).astype(q.dtype)
    lse = m + jnp.log(l)                                    # [B,Sq,Hkv,G]
    return out, lse


def _flash_fwd_vjp(q, k, v, q_pos, k_pos, window, kv_len, causal, softcap, ck):
    out, lse = _flash_fwd(q, k, v, q_pos, k_pos, window, kv_len,
                          causal, softcap, ck)
    return out, (q, k, v, out, lse, q_pos, k_pos, window, kv_len)


def _flash_bwd(causal, softcap, ck, res, dout):
    """Chunk-streamed backward: recompute p per kv chunk from (q, k, lse);
    never materializes the [Sq, Sk] score matrix nor stacks per-chunk
    intermediates (scan carries are only dq)."""
    q, k, v, out, lse, q_pos, k_pos, window, kv_len = res
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    ckk = min(ck, Sk)
    while Sk % ckk:
        ckk -= 1
    nk = Sk // ckk
    scale = 1.0 / (D ** 0.5)
    M = mesh_axis("model")
    dp = dp_spec()
    expand = M > 1 and Hkv % M != 0 and Hq % M == 0
    if expand:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        Hkv_e, G_e = Hq, 1
    else:
        Hkv_e, G_e = Hkv, G
    qg = q.astype(jnp.float32).reshape(B, Sq, Hkv_e, G_e, D) * scale
    dog = dout.astype(jnp.float32).reshape(B, Sq, Hkv_e, G_e, D)
    og = out.astype(jnp.float32).reshape(B, Sq, Hkv_e, G_e, D)
    Drow = (dog * og).sum(-1)                               # [B,Sq,Hkv_e,G_e]
    if M > 1 and Hkv_e % M == 0:
        qg = shard_hint(qg.astype(q.dtype), dp, None, "model", None, None)
        dog = shard_hint(dog, dp, None, "model", None, None)
        k = shard_hint(k, dp, None, "model", None)
        v = shard_hint(v, dp, None, "model", None)
    kc = k.reshape(B, nk, ckk, Hkv_e, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ckk, Hkv_e, D).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nk, ckk)

    def kv_step(dq, kv):
        kb, vb, kpb = kv
        sraw = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(q.dtype), kb,
                          preferred_element_type=jnp.float32)
        if softcap > 0:
            t = jnp.tanh(sraw / softcap)
            s = softcap * t
            dsoft = 1.0 - t * t                             # d softcap / d sraw
        else:
            s = sraw
            dsoft = None
        mask = _mask_block(kpb, q_pos, kv_len, window, causal)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                     # [B,Sq,Hkv,G,ck]
        dv = jnp.einsum("bqhgk,bqhgd->bkhd", p, dog,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dog.astype(v.dtype), vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Drow[..., None])
        if dsoft is not None:
            ds = ds * dsoft
        ds = jnp.where(mask[None, :, None, None, :], ds, 0.0)
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds.astype(k.dtype), kb,
                             preferred_element_type=jnp.float32)
        dk = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qg,
                        preferred_element_type=jnp.float32)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hkv_e, G_e, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, (kc, vc, kp))
    dq = (dq * scale).reshape(B, Sq, Hq, D).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Hkv_e, D)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Hkv_e, D)
    if expand:
        dk = dk.reshape(B, Sk, Hkv, G, D).sum(3)
        dv = dv.reshape(B, Sk, Hkv, G, D).sum(3)
    dk = dk.astype(k.dtype)
    dv = dv.astype(v.dtype)
    zero_i = jnp.zeros_like(q_pos)
    return (dq, dk, dv, zero_i, jnp.zeros_like(k_pos),
            jnp.zeros_like(jnp.asarray(0, jnp.int32)),
            jnp.zeros_like(jnp.asarray(0, jnp.int32)))


sdpa_flash.defvjp(_flash_fwd_vjp, _flash_bwd)


# ------------------------------------------------------------------- full pass

def attn_forward(
    params: dict,
    x: jax.Array,                 # [B, S, D]
    *,
    cfg,
    positions: jax.Array,         # [S]
    window=0,                     # traced scalar ok (scan-over-layers)
    causal: bool = True,
    kv_source: jax.Array = None,  # cross-attention memory [B, Sk, D]
    use_rope: bool = True,
    return_kv: bool = False,
) -> jax.Array:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads

    q = x @ params["wq"]
    src = x if kv_source is None else kv_source
    k = src @ params["wk"]
    v = src @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    Sk = src.shape[1]
    q = q.reshape(B, S, nq, hd)
    k = k.reshape(B, Sk, nkv, hd)
    v = v.reshape(B, Sk, nkv, hd)

    if use_rope and kv_source is None:
        from repro.models.layers import rope_angles
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])

    k_pos = positions if kv_source is None else jnp.arange(Sk, dtype=jnp.int32)
    if getattr(cfg, "sp_attn", False):
        # sequence-parallel score blocks (forward-only serving paths)
        out = sdpa_chunked(
            q, k, v, positions.astype(jnp.int32), k_pos.astype(jnp.int32),
            jnp.asarray(window, jnp.int32), jnp.asarray(Sk + 10**9, jnp.int32),
            causal=causal and kv_source is None, softcap=cfg.logit_softcap,
            sp_attn=True,
        )
    else:
        # flash custom-VJP core: backward recomputes score blocks chunk-wise
        # instead of letting scan-AD stack fp32 intermediates (§Perf H3b)
        out = sdpa_flash(
            q, k, v, positions.astype(jnp.int32), k_pos.astype(jnp.int32),
            jnp.asarray(window, jnp.int32), jnp.asarray(Sk + 10**9, jnp.int32),
            causal and kv_source is None, cfg.logit_softcap, 1024,
        )
    out = out.reshape(B, S, nq * hd) @ params["wo"]
    # pin the residual back to the Megatron layout (batch-sharded, replicated
    # on the model axis) so sequence-parallel attention for odd head counts
    # does not flip the MLP/MoE strategy to replicated-weight SP
    out = shard_hint(out, dp_spec(), None, None)
    if return_kv:
        return out, k, v
    return out


# --------------------------------------------------------------------- decode

def _decode_sdpa(q, k, v, mask, softcap_val: float):
    """Direct single-query SDPA — no scan, so GSPMD can shard the KV-cache
    sequence dim (scores get partitioned; softmax max/sum become all-reduces).
    q [B,1,Hq,D]; k/v [B,S,Hkv,D]; mask [B?,S] or [S] bool."""
    B, _, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    if softcap_val > 0:
        s = softcap_val * jnp.tanh(s / softcap_val)
    m = mask if mask.ndim == 2 else mask[None]
    s = jnp.where(m[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D)


def attn_decode(
    params: dict,
    x_t: jax.Array,               # [B, 1, D] current token
    cache_k: jax.Array,           # [B, Smax, Hkv, hd] — or, with block_table,
    cache_v: jax.Array,           #   a shared page pool [NP, ps, Hkv, hd]
    t,                            # traced int32 position: scalar or [B] per-slot
    *,
    cfg,
    window=0,
    use_rope: bool = True,
    block_table=None,             # [B, P] int32 page ids (paged KV pool)
) -> tuple:
    """Single-token decode against the KV cache.

    With `block_table`, the cache arrays are a PAGED pool shared by every
    slot: `cache_k[NP, ps, Hkv, hd]` holds fixed-size token pages and
    `block_table[b, j]` names the physical page backing slot b's j-th
    logical page (0 = the reserved null page — unallocated, and the write
    target of retired rows, so its contents are trash by design). The new
    token is scattered into page `bt[b, t // ps]` at offset `t % ps`, and
    attention gathers the slot's pages back into the dense [B, P*ps]
    logical layout — positions beyond `t` (including anything routed to the
    null page) are masked before the softmax, so the paged step is
    bit-identical to the dense one.

    Which paged realization runs is cfg.paged_attn (resolved by
    kernels/paged_attn.py::resolve_mode): "kernel" walks the block table
    inside a Pallas grid — per-tick HBM traffic scales with each row's LIVE
    pages instead of max_tokens — while "gather" keeps the dense
    re-materialization below (the bit-exact escape hatch)."""
    B = x_t.shape[0]
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    # Per-slot positions: every slot of a continuous-batching pool sits at its
    # own decode offset. A scalar t (static batch, all rows in lock-step) is
    # broadcast so both paths share one compiled graph.
    t_vec = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (B,))

    q = x_t @ params["wq"]
    k = x_t @ params["wk"]
    v = x_t @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, 1, nq, hd)
    k = k.reshape(B, 1, nkv, hd)
    v = v.reshape(B, 1, nkv, hd)

    if use_rope:
        from repro.models.layers import rope_angles
        cos, sin = rope_angles(t_vec[:, None], hd, cfg.rope_theta)  # [B,1,hd/2]
        q = apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
        k = apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])

    rows = jnp.arange(B)
    # Quantized pools arrive as (int8 pages, f32 per-page scales) tuples —
    # unpack here, repack on return so the caller's carry stays bundled.
    k_scales = v_scales = None
    if isinstance(cache_k, tuple):
        cache_k, k_scales = cache_k
        cache_v, v_scales = cache_v
    if block_table is None:
        cache_k = cache_k.at[rows, t_vec].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, t_vec].set(v[:, 0].astype(cache_v.dtype))
        att_k, att_v = cache_k, cache_v
        Smax = cache_k.shape[1]
        ret_k, ret_v = cache_k, cache_v
    else:
        ps = cache_k.shape[1]
        page = block_table[rows, t_vec // ps]                       # [B]
        if k_scales is not None:
            cache_k, k_scales = Q.scatter_token(
                cache_k, k_scales, page, t_vec % ps, k[:, 0])
            cache_v, v_scales = Q.scatter_token(
                cache_v, v_scales, page, t_vec % ps, v[:, 0])
            ret_k, ret_v = (cache_k, k_scales), (cache_v, v_scales)
        else:
            cache_k = cache_k.at[page, t_vec % ps].set(
                k[:, 0].astype(cache_k.dtype))
            cache_v = cache_v.at[page, t_vec % ps].set(
                v[:, 0].astype(cache_v.dtype))
            ret_k, ret_v = cache_k, cache_v
        from repro.kernels import paged_attn as PAGED
        if PAGED.resolve_mode(cfg) == "kernel":
            out = PAGED.paged_attn_decode(
                q[:, 0], cache_k, cache_v, block_table, t_vec,
                window=jnp.asarray(window, jnp.int32),
                softcap=cfg.logit_softcap,
                k_scales=k_scales, v_scales=v_scales)[:, None]  # [B,1,Hq,hd]
            out = out.astype(x_t.dtype).reshape(B, 1, nq * hd) @ params["wo"]
            return out, ret_k, ret_v
        P = block_table.shape[1]
        Smax = P * ps
        if k_scales is not None:
            att_k = (cache_k[block_table].astype(jnp.float32)
                     * k_scales[block_table][:, :, None, :, None]
                     ).reshape(B, Smax, nkv, hd)
            att_v = (cache_v[block_table].astype(jnp.float32)
                     * v_scales[block_table][:, :, None, :, None]
                     ).reshape(B, Smax, nkv, hd)
        else:
            att_k = cache_k[block_table].reshape(B, Smax, nkv, hd)
            att_v = cache_v[block_table].reshape(B, Smax, nkv, hd)

    k_pos = jnp.arange(Smax, dtype=jnp.int32)
    mask = k_pos[None, :] <= t_vec[:, None]                         # [B, Smax]
    w = jnp.asarray(window, jnp.int32)
    mask &= jnp.where(w > 0, k_pos[None, :] > t_vec[:, None] - w, True)
    out = _decode_sdpa(q, att_k, att_v, mask, cfg.logit_softcap)
    out = out.astype(x_t.dtype).reshape(B, 1, nq * hd) @ params["wo"]
    return out, ret_k, ret_v


def attn_chunk(
    params: dict,
    x: jax.Array,                 # [B, Cs, D] one prompt chunk
    cache_k: jax.Array,           # [B, Smax, Hkv, hd] dense KV cache — or,
    cache_v: jax.Array,           #   with block_table, a pool [NP,ps,Hkv,hd]
    start,                        # traced int32: absolute position of chunk[0]
    *,
    cfg,
    window=0,
    kv_len=None,                  # traced int32: keys >= kv_len masked
    block_table=None,             # [B, P] int32 page ids (paged KV pool)
) -> tuple:
    """Chunked-prefill attention: append one prompt chunk to a dense KV
    cache and attend its queries over everything cached so far (earlier
    chunks + the causal prefix of this one). `start` is traced, so one
    compile serves every chunk of every prompt; the last (right-padded)
    chunk rides in with `kv_len = start + valid` so pad keys never score.

    With `block_table` the caches are a shared page pool: the chunk's K/V
    scatter into the pages backing positions start..start+Cs-1 (pad
    positions past the row's allocation map to the null page 0 — their
    writes are unreachable and their keys sit past kv_len anyway), and
    attention runs over the prefix's pages — in-kernel (cfg.paged_attn
    "kernel") or via a transient dense gather (the fallback, bit-exact vs
    the dense chunk path since masked stale pages contribute exactly 0)."""
    B, Cs, _ = x.shape
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    positions = (jnp.asarray(start, jnp.int32) +
                 jnp.arange(Cs, dtype=jnp.int32))                  # [Cs]

    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, Cs, nq, hd)
    k = k.reshape(B, Cs, nkv, hd)
    v = v.reshape(B, Cs, nkv, hd)

    from repro.models.layers import rope_angles
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)          # [Cs, hd/2]
    q = apply_rope(q, cos[:, None, :], sin[:, None, :])
    k = apply_rope(k, cos[:, None, :], sin[:, None, :])

    k_scales = v_scales = None
    if isinstance(cache_k, tuple):
        cache_k, k_scales = cache_k
        cache_v, v_scales = cache_v
    if block_table is None:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, positions[0], 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, positions[0], 0, 0))
        att_k, att_v = cache_k, cache_v
        Smax = cache_k.shape[1]
        ret_k, ret_v = cache_k, cache_v
    else:
        ps = cache_k.shape[1]
        rows = jnp.arange(B)
        pages = block_table[rows[:, None], positions[None, :] // ps]  # [B,Cs]
        if k_scales is not None:
            cache_k, k_scales = Q.scatter_chunk(
                cache_k, k_scales, pages, positions[None, :] % ps, k)
            cache_v, v_scales = Q.scatter_chunk(
                cache_v, v_scales, pages, positions[None, :] % ps, v)
            ret_k, ret_v = (cache_k, k_scales), (cache_v, v_scales)
        else:
            cache_k = cache_k.at[pages, positions[None, :] % ps].set(
                k.astype(cache_k.dtype))
            cache_v = cache_v.at[pages, positions[None, :] % ps].set(
                v.astype(cache_v.dtype))
            ret_k, ret_v = cache_k, cache_v
        P = block_table.shape[1]
        Smax = P * ps
        from repro.kernels import paged_attn as PAGED
        if PAGED.resolve_mode(cfg) == "kernel":
            kvl = jnp.asarray(Smax if kv_len is None else kv_len, jnp.int32)
            out = PAGED.paged_attn_chunk(
                q, cache_k, cache_v, block_table, positions[0], kvl,
                window=jnp.asarray(window, jnp.int32),
                softcap=cfg.logit_softcap,
                k_scales=k_scales, v_scales=v_scales)      # [B,Cs,Hq,hd] f32
            out = out.astype(x.dtype).reshape(B, Cs, nq * hd) @ params["wo"]
            return out, ret_k, ret_v
        if k_scales is not None:
            att_k = (cache_k[block_table].astype(jnp.float32)
                     * k_scales[block_table][:, :, None, :, None]
                     ).reshape(B, Smax, nkv, hd)
            att_v = (cache_v[block_table].astype(jnp.float32)
                     * v_scales[block_table][:, :, None, :, None]
                     ).reshape(B, Smax, nkv, hd)
        else:
            att_k = cache_k[block_table].reshape(B, Smax, nkv, hd)
            att_v = cache_v[block_table].reshape(B, Smax, nkv, hd)

    k_pos = jnp.arange(Smax, dtype=jnp.int32)
    kvl = jnp.asarray(Smax if kv_len is None else kv_len, jnp.int32)
    out = sdpa_chunked(
        q, att_k, att_v, positions, k_pos,
        jnp.asarray(window, jnp.int32), kvl,
        causal=True, softcap=cfg.logit_softcap)
    out = out.reshape(B, Cs, nq * hd) @ params["wo"]
    return out, ret_k, ret_v


def cross_attn_decode(params: dict, x_t: jax.Array, memory: jax.Array, *, cfg):
    """Single-token cross attention over a fixed encoder/image memory."""
    B = x_t.shape[0]
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    Sk = memory.shape[1]
    q = (x_t @ params["wq"]).reshape(B, 1, nq, hd)
    k = (memory @ params["wk"]).reshape(B, Sk, nkv, hd)
    v = (memory @ params["wv"]).reshape(B, Sk, nkv, hd)
    mask = jnp.ones((Sk,), bool)
    out = _decode_sdpa(q, k, v, mask, cfg.logit_softcap)
    return out.astype(x_t.dtype).reshape(B, 1, nq * hd) @ params["wo"]
