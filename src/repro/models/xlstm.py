"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel training form with
exact log-domain stabilization) and sLSTM (scalar memory, recurrent scan).

mLSTM true semantics (per head):
  C_t = f_t C_{t-1} + i_t k_t v_t^T      n_t = f_t n_{t-1} + i_t k_t
  h_t = (q_t^T C_t) / max(|q_t^T n_t|, 1)
with f_t = sigmoid(f_raw), i_t = exp(i_raw). The chunkwise form carries a
log-scale M per head so all exponentials stay bounded; the decode path is the
standard stabilized recurrence and matches the chunkwise form exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init, split

LOG_EPS = -1e30


# ------------------------------------------------------------- mLSTM core

def mlstm_chunked(q, k, v, li, lf, chunk: int, state=None):
    """q/k/v [B,S,H,D]; li/lf [B,S,H] (log input gate, log forget gate).

    Returns h [B,S,H,D] and final state (C_hat [B,H,D,D], n_hat [B,H,D], M [B,H]).
    """
    B, S, H, D = q.shape
    scale = 1.0 / (D ** 0.5)
    L = min(chunk, S)
    while S % L:
        L -= 1
    c = S // L

    qc = (q * scale).reshape(B, c, L, H, D).astype(jnp.float32)
    kc = k.reshape(B, c, L, H, D).astype(jnp.float32)
    vc = v.reshape(B, c, L, H, D).astype(jnp.float32)
    lic = li.reshape(B, c, L, H).astype(jnp.float32)
    lfc = lf.reshape(B, c, L, H).astype(jnp.float32)
    bc = jnp.cumsum(lfc, axis=2)                       # [B,c,L,H]

    tril = jnp.tril(jnp.ones((L, L), bool))

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        M0 = jnp.full((B, H), LOG_EPS, jnp.float32)
    else:
        C0, n0, M0 = state

    def chunk_step(carry, inp):
        C_hat, n_hat, M = carry
        qb, kb, vb, lib, bb = inp                      # [B,L,H,*]
        bT = bb.transpose(0, 2, 1)                     # [B,H,L]
        liT = lib.transpose(0, 2, 1)
        logD = bT[:, :, :, None] - bT[:, :, None, :] + liT[:, :, None, :]
        logD = jnp.where(tril[None, None], logD, LOG_EPS)
        m_intra = logD.max(axis=-1)                    # [B,H,L]
        m_inter = bT + M[:, :, None]
        m = jnp.maximum(m_intra, m_inter)
        Dm = jnp.exp(logD - m[..., None])              # [B,H,L,L]
        scores = jnp.einsum("blhd,bmhd->bhlm", qb, kb)
        w = scores * Dm
        num = jnp.einsum("bhlm,bmhd->bhld", w, vb)
        num = num + jnp.exp(m_inter - m)[..., None] * jnp.einsum(
            "blhd,bhdv->bhlv", qb, C_hat)
        qn = w.sum(axis=-1) + jnp.exp(m_inter - m) * jnp.einsum(
            "blhd,bhd->bhl", qb, n_hat)
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m))
        h = (num / den[..., None]).transpose(0, 2, 1, 3)   # [B,L,H,D]

        bL = bb[:, -1]                                  # [B,H]
        g = bL[:, None] - bb + lib                      # [B,L,H]
        M_new = jnp.maximum(M + bL, g.max(axis=1))
        sc_old = jnp.exp(M + bL - M_new)
        sc_new = jnp.exp(g - M_new[:, None])            # [B,L,H]
        C_new = C_hat * sc_old[..., None, None] + jnp.einsum(
            "blhd,blhv,blh->bhdv", kb, vb, sc_new)
        n_new = n_hat * sc_old[..., None] + jnp.einsum(
            "blhd,blh->bhd", kb, sc_new)
        return (C_new, n_new, M_new), h

    xs = (
        qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
        vc.transpose(1, 0, 2, 3, 4), lic.transpose(1, 0, 2, 3),
        bc.transpose(1, 0, 2, 3),
    )
    (Cf, nf, Mf), hs = jax.lax.scan(chunk_step, (C0, n0, M0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return h.astype(q.dtype), (Cf, nf, Mf)


def mlstm_decode_step(state, q_t, k_t, v_t, li_t, lf_t):
    """One-token stabilized recurrence. q/k/v_t [B,H,D]; li/lf [B,H]."""
    C_hat, n_hat, M = state
    D = q_t.shape[-1]
    q_t = q_t.astype(jnp.float32) / (D ** 0.5)
    k_t = k_t.astype(jnp.float32)
    v_t = v_t.astype(jnp.float32)
    M_new = jnp.maximum(lf_t + M, li_t)
    sc_old = jnp.exp(lf_t + M - M_new)
    sc_in = jnp.exp(li_t - M_new)
    C_new = C_hat * sc_old[..., None, None] + sc_in[..., None, None] * (
        k_t[..., :, None] * v_t[..., None, :])
    n_new = n_hat * sc_old[..., None] + sc_in[..., None] * k_t
    num = jnp.einsum("bhd,bhdv->bhv", q_t, C_new)
    qn = jnp.einsum("bhd,bhd->bh", q_t, n_new)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-M_new))
    h = num / den[..., None]
    return (C_new, n_new, M_new), h


# ------------------------------------------------------------- mLSTM block

def mlstm_block_init(key, cfg) -> dict:
    d = cfg.d_model
    di = 2 * d
    h = cfg.num_heads
    dt = jnp.dtype(cfg.dtype)
    ks = split(key, 7)
    return {
        "norm": rmsnorm_init(d),
        "up": dense_init(ks[0], d, 2 * di, dt),        # (x_m, gate)
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "wq": dense_init(ks[2], di, di, dt),
        "wk": dense_init(ks[3], di, di, dt),
        "wv": dense_init(ks[4], di, di, dt),
        "w_if": dense_init(ks[5], di, 2 * h, dt),
        "gn": jnp.ones((di,), jnp.float32),
        "down": dense_init(ks[6], di, d, dt),
    }


def _causal_conv(u, w, b):
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(K)) + b


def _headnorm(y, scale, H):
    """Per-head group RMS norm; y [B,S,H,D] -> [B,S,H*D]."""
    B, S = y.shape[0], y.shape[1]
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6)
    return yf.reshape(B, S, -1) * scale


def mlstm_block(params, x, *, cfg, decode_state=None):
    """Full mLSTM residual block. x [B,S,D].

    decode_state None -> chunkwise parallel over S (returns out only);
    else single-token decode (S==1) returning (out, new_state).
    """
    B, S, d = x.shape
    di = 2 * d
    H = cfg.num_heads
    hd = di // H
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    up = xin @ params["up"]
    xm, gate = jnp.split(up, 2, axis=-1)

    if decode_state is None:
        xc = jax.nn.silu(_causal_conv(xm, params["conv_w"], params["conv_b"]))
        new_conv = None
    else:
        hist = jnp.concatenate([decode_state["conv"], xm], axis=1)
        xc = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", hist, params["conv_w"]) + params["conv_b"]
        )[:, None, :]
        new_conv = hist[:, 1:, :]

    q = (xc @ params["wq"]).reshape(B, S, H, hd)
    k = (xc @ params["wk"]).reshape(B, S, H, hd)
    v = (xm @ params["wv"]).reshape(B, S, H, hd)
    if_raw = (xm @ params["w_if"]).astype(jnp.float32)
    li = if_raw[..., :H]                                  # log input gate = i_raw
    lf = jax.nn.log_sigmoid(if_raw[..., H:])

    if decode_state is None:
        hseq, _ = mlstm_chunked(q, k, v, li, lf, chunk=min(128, S))
        out = _headnorm(hseq, params["gn"], H)
        out = out * jax.nn.silu(gate.astype(jnp.float32))
        return x + (out.astype(x.dtype) @ params["down"])
    else:
        st, h1 = mlstm_decode_step(
            decode_state["mlstm"], q[:, 0], k[:, 0], v[:, 0], li[:, 0], lf[:, 0])
        out = _headnorm(h1[:, None], params["gn"], H)
        out = out * jax.nn.silu(gate.astype(jnp.float32))
        y = x + (out.astype(x.dtype) @ params["down"])
        return y, {"mlstm": st, "conv": new_conv}


def mlstm_init_state(cfg, batch: int):
    d = cfg.d_model
    di = 2 * d
    H = cfg.num_heads
    hd = di // H
    return {
        "mlstm": (
            jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), LOG_EPS, jnp.float32),
        ),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.dtype(cfg.dtype)),
    }


# ------------------------------------------------------------- sLSTM block

def slstm_block_init(key, cfg) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    dt = jnp.dtype(cfg.dtype)
    ks = split(key, 4)
    ff = max(d * 4 // 3, 64)
    return {
        "norm": rmsnorm_init(d),
        "w_in": dense_init(ks[0], d, 4 * d, dt),       # i,f,z,o inputs
        "r": (jax.random.normal(ks[1], (4, H, hd, hd), jnp.float32)
              / (hd ** 0.5)).astype(dt),                # block-diag recurrent
        "gn": jnp.ones((d,), jnp.float32),
        "ff_norm": rmsnorm_init(d),
        "ff_up": dense_init(ks[2], d, 2 * ff, dt),
        "ff_down": dense_init(ks[3], ff, d, dt),
    }


def _slstm_cell(params, u, state, H, hd):
    """One time step. u [B,4d] pre-activations from input; state dict."""
    h_prev = state["h"]                                   # [B,H,hd]
    rec = jnp.einsum("ghij,bhj->bghi", params["r"].astype(jnp.float32),
                     h_prev)                              # [B,4,H,hd]
    B = u.shape[0]
    gates = u.astype(jnp.float32).reshape(B, 4, H, hd) + rec
    li_, lf_, z, o = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    lf_ = jax.nn.log_sigmoid(lf_)
    m_new = jnp.maximum(lf_ + state["m"], li_)
    fi = jnp.exp(lf_ + state["m"] - m_new)
    ii = jnp.exp(li_ - m_new)
    c = fi * state["c"] + ii * jnp.tanh(z)
    n = fi * state["n"] + ii
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_init_state(cfg, batch: int):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, H, hd), 0.0, jnp.float32), "h": z}


def slstm_block(params, x, *, cfg, decode_state=None):
    """sLSTM residual block + gated FFN. x [B,S,D]."""
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    u = xin @ params["w_in"]                              # [B,S,4d]

    if decode_state is None:
        def step(st, ut):
            st2 = _slstm_cell(params, ut, st, H, hd)
            return st2, st2["h"]
        st0 = slstm_init_state(cfg, B)
        _, hs = jax.lax.scan(step, st0, u.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2, 3)                      # [B,S,H,hd]
        new_state = None
    else:
        st = _slstm_cell(params, u[:, 0], decode_state, H, hd)
        h = st["h"][:, None]
        new_state = st

    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    hf = hf * jax.lax.rsqrt(var + 1e-6)
    out = hf.reshape(B, S, d) * params["gn"]
    x = x + out.astype(x.dtype)
    # gated FFN
    xin2 = rmsnorm(params["ff_norm"], x, cfg.norm_eps)
    a, b = jnp.split(xin2 @ params["ff_up"], 2, axis=-1)
    x = x + (jax.nn.silu(a) * b) @ params["ff_down"]
    if decode_state is None:
        return x
    return x, new_state
