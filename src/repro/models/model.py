"""Language-model assembly for every assigned architecture family.

One schema (`ModelConfig`) drives five structural families:

  attn      dense / MoE decoder-only transformers (starcoder2, granite-8b,
            qwen2, gemma3, deepseek-moe, granite-moe, llama_moe_4_16)
  attn+enc  whisper-style encoder-decoder (encoder_layers > 0)
  attn+x    llama-3.2-vision: cross-attention image layers every Nth layer
  xlstm     mLSTM stacks with interleaved sLSTM blocks
  mamba2    zamba2: Mamba2 stack with a weight-shared attention block

Public API:
  model_init(key, cfg)                                   -> params
  model_forward(params, tokens, cfg, extras)             -> (x_final, aux_loss)
  logits_from_hidden(params, x, cfg)                     -> [.., V]
  loss_fn(params, batch, cfg)                            -> (loss, metrics)
  init_decode_state(cfg, batch, max_len, extras)         -> state pytree
  prefill(params, tokens, cfg, extras)                   -> (state, last_logits)
  serve_step(params, state, tokens_t, cfg)               -> (logits, state)
  init_decode_slot(state, slot)                          -> state (slot reset)
  write_decode_slot(state, slot, src_state[, page_ids])  -> state (slot filled)
  prefill_chunk(params, state, tokens, cfg, start, vl)   -> (state, logits)

Decode state comes in two layouts: DENSE (per-slot KV rows
[L, B, max_len, h, hd]) and PAGED (`init_decode_state(paged=(num_pages,
page_size))` — a shared page pool [L, NP, ps, h, hd] plus a per-slot
block_table of physical page ids; serving/pool.py owns the host-side page
allocator). serve_step picks the attention path from the state's keys, so
both layouts run through the same engine.

Decode positions: `state["t"]` is either a scalar (static batch — every row in
lock-step, the classic generate() path) or an int32 vector [B] (per-slot —
the continuous-batching pool in repro/serving, where each slot sits at its
own offset). All decode kernels broadcast the scalar form to the vector form
internally, so both run the same compiled graph.

All layer stacks are scanned (jax.lax.scan over stacked params) so the HLO
stays compact at 62-100 layers; heterogeneous families scan homogeneous
segments. `cfg.remat` wraps scan bodies in jax.checkpoint.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import moe as MOE
from repro.core import quant as Q
from repro.core.go_cache import (GOCache, go_cache_init, go_cache_init_slot,
                                 go_cache_prefill, go_cache_write_slot)
from repro.core.grouping import default_groups, group_of_expert_from_groups
from repro.models import attention as ATT
from repro.models import blocks as B
from repro.models.layers import (dense_init, embed_init, rmsnorm,
                                 rmsnorm_init, split, stack_init)
from repro.models.ssm import mamba2_init_state
from repro.models.xlstm import mlstm_init_state, slstm_init_state


# ----------------------------------------------------------------- structure

def layer_windows(cfg) -> np.ndarray:
    """Per-layer sliding-window spans (0 = global attention)."""
    L = cfg.num_layers
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        return np.array(
            [cfg.sliding_window if (l % (r + 1)) != r else 0 for l in range(L)],
            np.int32)
    if cfg.sliding_window > 0:
        return np.full(L, cfg.sliding_window, np.int32)
    return np.zeros(L, np.int32)


@functools.lru_cache(maxsize=None)
def _moe_deployment(moe_cfg):
    """Deployment-time C2 artifacts, computed ONCE per MoE config (host-side
    numpy) instead of inside every traced forward: the [E] group-id map and
    the [G, g] member matrix the group-multiplexed paths consume."""
    groups = default_groups(moe_cfg)
    return (jnp.asarray(group_of_expert_from_groups(groups), jnp.int32),
            jnp.asarray(groups, jnp.int32))


def expert_groups(cfg) -> jax.Array | None:
    """C2 grouping -> [E] group id per expert (None for non-MoE)."""
    if cfg.moe is None:
        return None
    return _moe_deployment(cfg.moe)[0]


def expert_group_members(cfg) -> jax.Array | None:
    """C2 grouping -> [G, g] expert ids per group (None for non-MoE)."""
    if cfg.moe is None:
        return None
    return _moe_deployment(cfg.moe)[1]


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _xlstm_segments(cfg):
    """(num_segments, mlstm_per_segment); sLSTM closes each segment."""
    if cfg.slstm_every <= 0:
        return 1, cfg.num_layers
    assert cfg.num_layers % cfg.slstm_every == 0
    return cfg.num_layers // cfg.slstm_every, cfg.slstm_every - 1


def _zamba_segments(cfg):
    if cfg.attn_every <= 0:
        return 0, cfg.num_layers
    return cfg.num_layers // cfg.attn_every, cfg.attn_every


# ----------------------------------------------------------------------- init

def model_init(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = split(key, 10)
    p = {
        "embed": embed_init(ks[0], cfg.vocab_size, d, dt),
        "final_norm": rmsnorm_init(d),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], d, cfg.vocab_size, dt)

    if cfg.block == "attn":
        use_moe = cfg.moe is not None
        if cfg.encoder_layers > 0:
            # whisper-style enc-dec (no RoPE: learned decoder positions);
            # table sized for the assigned decode_32k cell
            p["pos_embed"] = (jax.random.normal(
                ks[2], (40960, d), jnp.float32) * 0.02).astype(dt)
            p["encoder"] = stack_init(
                ks[3], cfg.encoder_layers,
                lambda k: B.attn_block_init(k, cfg, gelu=True))
            p["dec_self"] = stack_init(
                ks[4], cfg.num_layers, _dec_self_init_fn(cfg))
            p["dec_cross"] = stack_init(
                ks[5], cfg.num_layers,
                lambda k: B.attn_block_init(k, cfg, cross=True, gelu=True))
            p["enc_norm"] = rmsnorm_init(d)
        elif cfg.cross_attn_every > 0:
            every = cfg.cross_attn_every
            assert cfg.num_layers % every == 0
            n_sup = cfg.num_layers // every
            n_self = every - 1
            p["layers"] = stack_init(
                ks[3], n_sup,
                lambda k: stack_init(k, n_self,
                                     lambda k2: B.attn_block_init(k2, cfg)))
            p["cross_layers"] = stack_init(
                ks[4], n_sup,
                lambda k: B.attn_block_init(k, cfg, cross=True))
        else:
            p["layers"] = stack_init(
                ks[3], cfg.num_layers,
                lambda k: B.attn_block_init(k, cfg, use_moe=use_moe))
    elif cfg.block == "xlstm":
        n_seg, n_m = _xlstm_segments(cfg)
        p["mlayers"] = stack_init(
            ks[3], n_seg,
            lambda k: stack_init(k, n_m, lambda k2: B.mlstm_block_init(k2, cfg)))
        p["slayers"] = stack_init(
            ks[4], n_seg, lambda k: B.slstm_block_init(k, cfg))
    elif cfg.block == "mamba2":
        p["layers"] = stack_init(
            ks[3], cfg.num_layers, lambda k: B.mamba2_block_init(k, cfg))
        if cfg.attn_every > 0:
            p["shared_attn"] = B.attn_block_init(ks[4], cfg)
    else:
        raise ValueError(cfg.block)
    return p


def _dec_self_init_fn(cfg):
    def init(k):
        return {"ln1": rmsnorm_init(cfg.d_model),
                "attn": ATT.attn_init(k, cfg)}
    return init


# -------------------------------------------------------------------- forward

def model_forward(params: dict, tokens: jax.Array, cfg, extras: dict | None = None):
    """tokens [B, S] -> (x_final [B, S, d] normalized, aux_balance_loss)."""
    extras = extras or {}
    x = params["embed"][tokens]
    if cfg.block == "attn" and cfg.encoder_layers > 0:
        return _fwd_whisper(params, x, cfg, extras)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.block == "attn" and cfg.cross_attn_every > 0:
        return _fwd_vlm(params, x, positions, cfg, extras)
    if cfg.block == "attn":
        return _fwd_attn(params, x, positions, cfg)
    if cfg.block == "xlstm":
        return _fwd_xlstm(params, x, cfg)
    if cfg.block == "mamba2":
        return _fwd_zamba(params, x, positions, cfg)
    raise ValueError(cfg.block)


def _fwd_attn(params, x, positions, cfg):
    goe = expert_groups(cfg)
    gm = expert_group_members(cfg)
    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, xs):
        x, bal = carry
        lp, w = xs
        x, aux = B.attn_block(lp, x, cfg=cfg, positions=positions, window=w,
                              group_of_expert=goe, group_members=gm)
        if aux is not None and "balance_loss" in aux:
            bal = bal + jnp.sum(aux["balance_loss"])
        return (x, bal), None

    (x, bal), _ = jax.lax.scan(
        _maybe_remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
        (params["layers"], windows))
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), bal


def _fwd_vlm(params, x, positions, cfg, extras):
    memory = extras["image_embeds"]                    # [B, I, d] stub patches

    def body(x, xs):
        self_stack, cross_p = xs
        n_self = cfg.cross_attn_every - 1
        for i in range(n_self):
            lp = jax.tree.map(lambda a: a[i], self_stack)
            x, _ = B.attn_block(lp, x, cfg=cfg, positions=positions)
        xc, _ = B.attn_block(cross_p, x, cfg=cfg, positions=positions,
                             causal=False, kv_source=memory, use_rope=False)
        return xc, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x,
                        (params["layers"], params["cross_layers"]))
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros((), jnp.float32)


def _fwd_whisper(params, x, cfg, extras):
    frames = extras["audio_frames"]                    # [B, F, d] stub frames
    F = frames.shape[1]
    enc_pos = jnp.arange(F, dtype=jnp.int32)

    def enc_body(h, lp):
        h, _ = B.attn_block(lp, h, cfg=cfg, positions=enc_pos, causal=False,
                            use_rope=False)
        return h, None

    h, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), frames, params["encoder"])
    memory = rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    S = x.shape[1]
    x = x + params["pos_embed"][:S]
    dec_pos = jnp.arange(S, dtype=jnp.int32)

    def dec_body(x, xs):
        sp, cp = xs
        hh = rmsnorm(sp["ln1"], x, cfg.norm_eps)
        x = x + ATT.attn_forward(sp["attn"], hh, cfg=cfg, positions=dec_pos,
                                 causal=True, use_rope=False)
        x, _ = B.attn_block(cp, x, cfg=cfg, positions=dec_pos, causal=False,
                            kv_source=memory, use_rope=False)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(dec_body, cfg), x,
                        (params["dec_self"], params["dec_cross"]))
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros((), jnp.float32)


def _fwd_xlstm(params, x, cfg):
    n_seg, n_m = _xlstm_segments(cfg)

    def m_body(x, lp):
        return B.mlstm_block(lp, x, cfg=cfg), None

    for s in range(n_seg):
        mstack = jax.tree.map(lambda a: a[s], params["mlayers"])
        x, _ = jax.lax.scan(_maybe_remat(m_body, cfg), x, mstack)
        sp = jax.tree.map(lambda a: a[s], params["slayers"])
        x = B.slstm_block(sp, x, cfg=cfg)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros((), jnp.float32)


def _fwd_zamba(params, x, positions, cfg):
    n_app, seg = _zamba_segments(cfg)

    def m_body(x, lp):
        return B.mamba2_block(lp, x, cfg=cfg), None

    if n_app == 0:
        x, _ = jax.lax.scan(_maybe_remat(m_body, cfg), x, params["layers"])
    else:
        for s in range(n_app):
            stack = jax.tree.map(lambda a: a[s * seg:(s + 1) * seg],
                                 params["layers"])
            x, _ = jax.lax.scan(_maybe_remat(m_body, cfg), x, stack)
            x, _ = B.attn_block(params["shared_attn"], x, cfg=cfg,
                                positions=positions)
        rem = cfg.num_layers - n_app * seg
        if rem:
            stack = jax.tree.map(lambda a: a[n_app * seg:], params["layers"])
            x, _ = jax.lax.scan(_maybe_remat(m_body, cfg), x, stack)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------- loss

def logits_from_hidden(params: dict, x: jax.Array, cfg) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


def chunked_xent(params, x, labels, cfg, chunk: int = 512):
    """Cross-entropy without materializing [B, S, V]: scan over S chunks.
    x [B,S,d]; labels [B,S] int32 (-1 = masked). Returns (sum_loss, count)."""
    Bsz, S, d = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n = S // chunk
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    xc = x.reshape(Bsz, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(Bsz, n, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        loss, cnt = carry
        xb, lb = inp                                    # [B, c, d], [B, c]
        logits = (xb @ w.astype(xb.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        loss = loss + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (loss, cnt), None

    body = jax.checkpoint(body) if cfg.remat else body
    (loss, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    return loss, cnt


def _training_cfg(cfg):
    """Training runs the differentiable XLA realization: the pallas kernels
    define no VJP yet (ROADMAP), so backend="auto" silently pins to xla. An
    EXPLICIT backend="pallas" fails fast HERE: inside the layer scan the
    autodiff tracers are invisible (scan bodies are traced to a jaxpr before
    the JVP rule runs), so the `resolve_backend` guard cannot see the grad
    trace and the failure would otherwise surface as a bare
    NotImplementedError from pallas_call at transpose time."""
    if cfg.moe is None:
        return cfg
    b = getattr(cfg.moe, "backend", "auto")
    if b == "pallas":
        raise NotImplementedError(
            "pallas backend has no backward pass yet; use backend='auto' or "
            "'xla' for training (see ROADMAP: custom VJP over gmm/gmm_swiglu)."
            " For forward-only evaluation on pallas, call model_forward + "
            "chunked_xent directly — loss_fn is the training entry point")
    if b == "auto":
        import dataclasses
        return cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, backend="xla"))
    return cfg


def loss_fn(params: dict, batch: dict, cfg):
    """batch: tokens [B,S], labels [B,S] (+ stub extras). -> (loss, metrics)."""
    cfg = _training_cfg(cfg)
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    x, bal = model_forward(params, batch["tokens"], cfg, extras)
    loss_sum, cnt = chunked_xent(params, x, batch["labels"], cfg)
    ce = loss_sum / jnp.maximum(cnt, 1.0)
    coef = cfg.moe.balance_coef if cfg.moe is not None else 0.0
    total = ce + coef * bal / max(1, cfg.num_layers)
    return total, {"ce": ce, "balance": bal}


# --------------------------------------------------------------- decode state

def kv_cache_spec(cfg, batch: int, max_len: int):
    hd = cfg.resolved_head_dim()
    return (batch, max_len, cfg.num_kv_heads, hd)


def paged_supported(cfg) -> bool:
    """Paged KV pools cover the plain attention family — the KV cache is the
    only sequence-shaped decode state there (recurrent families are O(1) per
    slot, enc-dec/vlm carry per-request memories)."""
    return (cfg.block == "attn" and cfg.encoder_layers == 0
            and cfg.cross_attn_every == 0)


def init_decode_state(cfg, batch: int, max_len: int,
                      extras: dict | None = None, *,
                      per_slot_t: bool = False,
                      paged: tuple[int, int] | None = None) -> dict:
    """Zero-initialized decode state. `extras` may carry the cross-attention
    memory (image/audio embeds already encoded) for vlm/enc-dec archs.
    With per_slot_t, `t` is an int32 vector [batch] so every slot advances
    independently (the continuous-batching pool layout).

    `paged=(num_pages, page_size)` swaps the dense per-slot KV rows for a
    shared page pool: `k_pages`/`v_pages` [L, num_pages, page_size, h, hd]
    plus a per-slot `block_table` [batch, max_len // page_size] of physical
    page ids (0 = the reserved null page). HBM then scales with the pool's
    page count, not batch x max_len. GO caches stay slot-resident — they
    are [E, k]-shaped, not sequence-shaped. Attention family only."""
    extras = extras or {}
    dt = jnp.dtype(cfg.dtype)
    st = {"t": jnp.zeros((batch,) if per_slot_t else (), jnp.int32)}
    shp = kv_cache_spec(cfg, batch, max_len)
    if paged is not None:
        if not paged_supported(cfg):
            raise ValueError(
                "paged decode state is attention-family only "
                f"(block={cfg.block!r}, encoder_layers={cfg.encoder_layers}, "
                f"cross_attn_every={cfg.cross_attn_every})")
        num_pages, ps = paged
        if max_len % ps:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={ps}")
        Q.validate_kv_quant(cfg.kv_quant)
        quant = cfg.kv_quant == "int8"
        L = cfg.num_layers
        hd = cfg.resolved_head_dim()
        page_dt = jnp.int8 if quant else dt
        st["block_table"] = jnp.zeros((batch, max_len // ps), jnp.int32)
        st["k_pages"] = jnp.zeros(
            (L, num_pages, ps, cfg.num_kv_heads, hd), page_dt)
        st["v_pages"] = jnp.zeros(
            (L, num_pages, ps, cfg.num_kv_heads, hd), page_dt)
        if quant:
            # per-page, per-kv-head amax scales; zero = empty page
            st["k_scales"] = jnp.zeros(
                (L, num_pages, cfg.num_kv_heads), jnp.float32)
            st["v_scales"] = jnp.zeros(
                (L, num_pages, cfg.num_kv_heads), jnp.float32)
        if cfg.moe is not None and cfg.moe.routing == "expert_choice" \
                and cfg.moe.go_cache:
            e = cfg.moe
            per = go_cache_init(batch, e.num_experts, e.top_k, cfg.d_model,
                                jnp.int8 if quant else dt)
            st["go"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (L, *a.shape)), per)
            if quant:
                # per-row GO scales (outputs rows are [E, k, d] per slot)
                st["go_scales"] = jnp.zeros(
                    (L, batch, e.num_experts, e.top_k), jnp.float32)
        return st

    if cfg.block == "attn" and cfg.encoder_layers > 0:
        L = cfg.num_layers
        st["k"] = jnp.zeros((L, *shp), dt)
        st["v"] = jnp.zeros((L, *shp), dt)
        st["memory"] = extras.get(
            "memory", jnp.zeros((batch, cfg.num_audio_frames, cfg.d_model), dt))
    elif cfg.block == "attn" and cfg.cross_attn_every > 0:
        n_sup = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1
        st["k"] = jnp.zeros((n_sup * n_self, *shp), dt)   # flat self-layer idx
        st["v"] = jnp.zeros((n_sup * n_self, *shp), dt)
        st["memory"] = extras.get(
            "memory", jnp.zeros((batch, cfg.num_image_tokens, cfg.d_model), dt))
    elif cfg.block == "attn":
        L = cfg.num_layers
        st["k"] = jnp.zeros((L, *shp), dt)
        st["v"] = jnp.zeros((L, *shp), dt)
        if cfg.moe is not None and cfg.moe.routing == "expert_choice" \
                and cfg.moe.go_cache:
            e = cfg.moe
            per = go_cache_init(batch, e.num_experts, e.top_k, cfg.d_model, dt)
            st["go"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (L, *a.shape)), per)
    elif cfg.block == "xlstm":
        n_seg, n_m = _xlstm_segments(cfg)
        per_m = mlstm_init_state(cfg, batch)
        st["mlstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_seg, n_m, *a.shape)), per_m)
        per_s = slstm_init_state(cfg, batch)
        st["slstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_seg, *a.shape)), per_s)
    elif cfg.block == "mamba2":
        per = mamba2_init_state(cfg, batch)
        st["ssm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), per)
        n_app, _ = _zamba_segments(cfg)
        if n_app:
            st["k"] = jnp.zeros((n_app, *shp), dt)
            st["v"] = jnp.zeros((n_app, *shp), dt)
    return st


# ------------------------------------------------------------- per-slot state
#
# The continuous-batching engine (repro/serving) owns ONE pooled decode state
# of `num_slots` batch rows and retires/admits requests per row. These two ops
# are the whole interface it needs: reset a row, and splat a single-request
# prefill (batch-1 state) into a row. Batch axes per key:
#   t -> 0 (vector form)   k/v/go/ssm/slstm -> 1 (leading layer axis)
#   mlstm -> 2 (segment, layer, batch)      memory -> 0

def init_decode_slot(state: dict, slot) -> dict:
    """Reset pool slot `slot` (traced int32 ok) to the empty decode state.
    Paged pools only reset the slot's BLOCK TABLE (to the null page) — the
    physical pages go back to the host allocator's free list and are
    rewritten before any future occupant can read them, so clearing their
    contents would be wasted bandwidth. GO rows reset as usual (scores to
    -inf) on this same free path."""
    st = dict(state)
    if st["t"].ndim == 1:
        st["t"] = st["t"].at[slot].set(0)
    else:
        st["t"] = jnp.zeros((), jnp.int32)
    if "block_table" in st:
        st["block_table"] = st["block_table"].at[slot].set(0)
    for key in ("k", "v"):
        if key in st:
            st[key] = st[key].at[:, slot].set(0)
    if "go" in st:
        # vmap over the stacked layer axis -> per-layer [B, ...] caches
        st["go"] = jax.vmap(lambda c: go_cache_init_slot(c, slot))(st["go"])
    if "go_scales" in st:
        st["go_scales"] = st["go_scales"].at[:, slot].set(0)
    if "ssm" in st:
        st["ssm"] = jax.tree.map(lambda a: a.at[:, slot].set(0), st["ssm"])
    if "mlstm" in st:
        st["mlstm"] = jax.tree.map(lambda a: a.at[:, :, slot].set(0), st["mlstm"])
    if "slstm" in st:
        st["slstm"] = jax.tree.map(lambda a: a.at[:, slot].set(0), st["slstm"])
    if "memory" in st:
        st["memory"] = st["memory"].at[slot].set(0)
    return st


def write_decode_slot(state: dict, slot, src: dict, page_ids=None) -> dict:
    """Write a batch-1 decode state `src` (a single-request prefill built with
    the SAME max_len as the pool) into pool slot `slot`.

    Paged pools additionally take `page_ids` [max_len // page_size] int32 —
    the slot's full block-table row. The dense prefill KV splits into
    page-size rows and scatters to those physical pages; entries that are 0
    (null — pages past the request's allocation) dump their rows onto the
    null trash page, so ONE compile serves every allocation size."""
    st = dict(state)
    st["t"] = st["t"].at[slot].set(jnp.asarray(src["t"], jnp.int32).reshape(()))
    if "block_table" in st:
        assert page_ids is not None, "paged pool: pass the slot's page_ids"
        pid = jnp.asarray(page_ids, jnp.int32)
        st["block_table"] = st["block_table"].at[slot].set(pid)
        L, _, ps, h, hd = st["k_pages"].shape
        P = pid.shape[0]
        quant = "k_scales" in st
        for key, srck in (("k_pages", "k"), ("v_pages", "v")):
            if srck not in src:
                # paged-native chunk prefill: the chunk run already scattered
                # its KV into the pool's pages — nothing to splat here
                continue
            assert src[srck].shape[2] == P * ps, \
                f"{srck}: prefill len {src[srck].shape[2]} != pool " \
                f"max_tokens {P * ps} (prefill must use the pool's max_len)"
            pages = src[srck][:, 0].reshape(L, P, ps, h, hd)
            if quant:
                # splat-quantize: each page against its own amax — a pure
                # function of the tokens, independent of pool history
                q, sc = Q.quantize_pages(pages)
                st[key] = st[key].at[:, pid].set(q)
                sk = {"k_pages": "k_scales", "v_pages": "v_scales"}[key]
                st[sk] = st[sk].at[:, pid].set(sc)
            else:
                st[key] = st[key].at[:, pid].set(pages.astype(st[key].dtype))
    for key in ("k", "v"):
        if key in st:
            assert st[key].shape[2:] == src[key].shape[2:], \
                f"{key}: pool {st[key].shape} vs slot {src[key].shape} " \
                "(prefill must use the pool's max_len)"
            st[key] = st[key].at[:, slot].set(src[key][:, 0].astype(st[key].dtype))
    if "go" in st:
        src_go = src["go"]
        if "go_scales" in st:
            # quantize the full-precision prefill rows once, at the splat
            qout, qsc = Q.quantize_rows(src_go.outputs)
            src_go = src_go._replace(outputs=qout)
            st["go_scales"] = st["go_scales"].at[:, slot].set(qsc[:, 0])
        st["go"] = jax.vmap(lambda c, s: go_cache_write_slot(c, slot, s))(
            st["go"], src_go)
    if "ssm" in st:
        st["ssm"] = jax.tree.map(
            lambda a, b: a.at[:, slot].set(b[:, 0].astype(a.dtype)),
            st["ssm"], src["ssm"])
    if "mlstm" in st:
        st["mlstm"] = jax.tree.map(
            lambda a, b: a.at[:, :, slot].set(b[:, :, 0].astype(a.dtype)),
            st["mlstm"], src["mlstm"])
    if "slstm" in st:
        st["slstm"] = jax.tree.map(
            lambda a, b: a.at[:, slot].set(b[:, 0].astype(a.dtype)),
            st["slstm"], src["slstm"])
    if "memory" in st:
        st["memory"] = st["memory"].at[slot].set(
            src["memory"][0].astype(st["memory"].dtype))
    return st


# ----------------------------------------------------------------- serve step

def serve_step(params: dict, state: dict, tokens_t: jax.Array, cfg):
    """One decode step. tokens_t [B] int32 -> (logits [B, V] fp32, state)."""
    x = params["embed"][tokens_t][:, None, :]            # [B, 1, d]
    t = state["t"]

    if cfg.block == "attn" and cfg.encoder_layers > 0:
        x, state = _dec_whisper(params, x, state, cfg)
    elif cfg.block == "attn" and cfg.cross_attn_every > 0:
        x, state = _dec_vlm(params, x, state, cfg)
    elif cfg.block == "attn":
        x, state = _dec_attn(params, x, state, cfg)
    elif cfg.block == "xlstm":
        x, state = _dec_xlstm(params, x, state, cfg)
    elif cfg.block == "mamba2":
        x, state = _dec_zamba(params, x, state, cfg)
    else:
        raise ValueError(cfg.block)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, x[:, 0, :], cfg)
    state["t"] = t + 1
    return logits, state


def _dec_attn(params, x, state, cfg):
    t = state["t"]
    windows = jnp.asarray(layer_windows(cfg))
    goe = expert_groups(cfg)
    has_go = "go" in state
    paged = "block_table" in state
    qkv = paged and "k_scales" in state
    qgo = has_go and "go_scales" in state
    kk, vk = ("k_pages", "v_pages") if paged else ("k", "v")
    bt = state["block_table"] if paged else None

    # The full KV (and GO) caches ride in the scan CARRY and are updated
    # layer-by-layer with dynamic_update_index — XLA keeps them in place
    # (donated buffers), instead of double-buffering a stacked ys output.
    # Quantized pools bundle each cache with its scales — (pages, scales)
    # tuples ride the carry and tree.map generalizes the index/update.
    def body(carry, xs):
        x, K, V, go, l = carry
        lp, w = xs
        pick = lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
        put = lambda full, new: jax.lax.dynamic_update_index_in_dim(
            full, new.astype(full.dtype), l, 0)
        ck = jax.tree.map(pick, K)
        cv = jax.tree.map(pick, V)
        go_l = jax.tree.map(pick, go) if has_go else None
        if qgo:
            # layer boundary: int8 GO rows -> f32 (f32, NOT the cfg compute
            # dtype: in f32 an unchanged row requantizes to its exact int8
            # bits, so idle rows are bit-stable across ticks)
            go_l, gsc = go_l
            go_l = go_l._replace(outputs=Q.dequantize_rows(go_l.outputs, gsc))
        x, ck, cv, go_l, _ = B.attn_block_decode(
            lp, x, ck, cv, t, cfg=cfg, window=w, group_of_expert=goe,
            go_cache=go_l, block_table=bt)
        if qgo:
            qout, gsc = Q.quantize_rows(go_l.outputs)
            go_l = (go_l._replace(outputs=qout), gsc)
        K = jax.tree.map(put, K, ck)
        V = jax.tree.map(put, V, cv)
        if has_go:
            go = jax.tree.map(put, go, go_l)
        return (x, K, V, go, l + 1), None

    K0 = (state[kk], state["k_scales"]) if qkv else state[kk]
    V0 = (state[vk], state["v_scales"]) if qkv else state[vk]
    go0 = state.get("go")
    if qgo:
        go0 = (go0, state["go_scales"])
    carry0 = (x, K0, V0, go0, jnp.zeros((), jnp.int32))
    (x, K, V, go, _), _ = jax.lax.scan(
        body, carry0, (params["layers"], windows))
    if qkv:
        (state[kk], state["k_scales"]) = K
        (state[vk], state["v_scales"]) = V
    else:
        state[kk], state[vk] = K, V
    if has_go:
        if qgo:
            state["go"], state["go_scales"] = go
        else:
            state["go"] = go
    return x, state


def _dec_vlm(params, x, state, cfg):
    t = state["t"]
    memory = state["memory"]
    n_self = cfg.cross_attn_every - 1

    def body(carry, xs):
        x, K, V, sup = carry                 # K/V [n_sup*n_self, B, S, h, hd]
        self_stack, cross_p = xs
        for i in range(n_self):
            lp = jax.tree.map(lambda a: a[i], self_stack)
            l = sup * n_self + i
            ck = jax.lax.dynamic_index_in_dim(K, l, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(V, l, 0, keepdims=False)
            x, ck, cv, _, _ = B.attn_block_decode(lp, x, ck, cv, t, cfg=cfg)
            K = jax.lax.dynamic_update_index_in_dim(
                K, ck.astype(K.dtype), l, 0)
            V = jax.lax.dynamic_update_index_in_dim(
                V, cv.astype(V.dtype), l, 0)
        x = B.cross_block_decode(cross_p, x, memory, cfg=cfg)
        return (x, K, V, sup + 1), None

    carry0 = (x, state["k"], state["v"], jnp.zeros((), jnp.int32))
    (x, K, V, _), _ = jax.lax.scan(
        body, carry0, (params["layers"], params["cross_layers"]))
    state["k"], state["v"] = K, V
    return x, state


def _dec_whisper(params, x, state, cfg):
    t = state["t"]
    memory = state["memory"]
    t_vec = jnp.broadcast_to(
        jnp.asarray(t, jnp.int32).reshape(-1), (x.shape[0],))
    x = x + params["pos_embed"][t_vec][:, None, :]

    def body(carry, xs):
        x, K, V, l = carry
        sp, cp = xs
        ck = jax.lax.dynamic_index_in_dim(K, l, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(V, l, 0, keepdims=False)
        h = rmsnorm(sp["ln1"], x, cfg.norm_eps)
        a, ck, cv = ATT.attn_decode(sp["attn"], h, ck, cv, t, cfg=cfg,
                                    use_rope=False)
        x = x + a
        x = B.cross_block_decode(cp, x, memory, cfg=cfg)
        K = jax.lax.dynamic_update_index_in_dim(K, ck.astype(K.dtype), l, 0)
        V = jax.lax.dynamic_update_index_in_dim(V, cv.astype(V.dtype), l, 0)
        return (x, K, V, l + 1), None

    carry0 = (x, state["k"], state["v"], jnp.zeros((), jnp.int32))
    (x, K, V, _), _ = jax.lax.scan(
        body, carry0, (params["dec_self"], params["dec_cross"]))
    state["k"], state["v"] = K, V
    return x, state


def _dec_xlstm(params, x, state, cfg):
    n_seg, n_m = _xlstm_segments(cfg)

    def m_body(x, xs):
        lp, st = xs
        x, st2 = B.mlstm_block(lp, x, cfg=cfg, decode_state=st)
        return x, st2

    new_m, new_s = [], []
    for s in range(n_seg):
        mstack = jax.tree.map(lambda a: a[s], params["mlayers"])
        mstate = jax.tree.map(lambda a: a[s], state["mlstm"])
        x, mst = jax.lax.scan(m_body, x, (mstack, mstate))
        new_m.append(mst)
        sp = jax.tree.map(lambda a: a[s], params["slayers"])
        sst = jax.tree.map(lambda a: a[s], state["slstm"])
        x, sst2 = B.slstm_block(sp, x, cfg=cfg, decode_state=sst)
        new_s.append(sst2)
    state["mlstm"] = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
    state["slstm"] = jax.tree.map(lambda *a: jnp.stack(a), *new_s)
    return x, state


def _dec_zamba(params, x, state, cfg):
    t = state["t"]
    n_app, seg = _zamba_segments(cfg)

    def m_body(x, xs):
        lp, st = xs
        x, st2 = B.mamba2_block_decode(lp, x, st, cfg=cfg)
        return x, st2

    if n_app == 0:
        x, ssm = jax.lax.scan(m_body, x, (params["layers"], state["ssm"]))
        state["ssm"] = ssm
        return x, state

    new_ssm, new_k, new_v = [], [], []
    for s in range(n_app):
        stack = jax.tree.map(lambda a: a[s * seg:(s + 1) * seg], params["layers"])
        sst = jax.tree.map(lambda a: a[s * seg:(s + 1) * seg], state["ssm"])
        x, ssm2 = jax.lax.scan(m_body, x, (stack, sst))
        new_ssm.append(ssm2)
        x, ck, cv, _, _ = B.attn_block_decode(
            params["shared_attn"], x, state["k"][s], state["v"][s], t, cfg=cfg)
        new_k.append(ck)
        new_v.append(cv)
    rem = cfg.num_layers - n_app * seg
    if rem:
        stack = jax.tree.map(lambda a: a[n_app * seg:], params["layers"])
        sst = jax.tree.map(lambda a: a[n_app * seg:], state["ssm"])
        x, ssm2 = jax.lax.scan(m_body, x, (stack, sst))
        new_ssm.append(ssm2)
    state["ssm"] = jax.tree.map(lambda *a: jnp.concatenate(a), *new_ssm)
    state["k"] = jnp.stack(new_k)
    state["v"] = jnp.stack(new_v)
    return x, state


# -------------------------------------------------------------------- prefill

def prefill(params: dict, tokens: jax.Array, cfg, extras: dict | None = None,
            max_len: int = 0, valid_len=None):
    """Run the full-sequence forward while FILLING the decode state (KV caches,
    GO caches, SSM states). Returns (state, last_token_logits [B, V]).

    `valid_len` (traced int32 scalar) supports BUCKETED prefill: `tokens` is
    right-padded to a bucket length, but only the first valid_len positions
    are real. One compile then serves every prompt length in the bucket.
    Causal attention never lets a real position see a pad; expert-choice
    routing masks pads out of the top-C selection (so the GO cache holds
    only real tokens); the returned logits come from position valid_len - 1
    and the decode position starts there — pad KV rows are overwritten by
    decode steps before they can ever be attended.

    Implemented for the attention families (the serving examples); recurrent
    families can prefill by stepping serve_step (their state is O(1))."""
    extras = extras or {}
    Bsz, S = tokens.shape
    max_len = max_len or (2 * S)
    state = init_decode_state(cfg, Bsz, max_len, extras)
    if cfg.block != "attn" or cfg.encoder_layers > 0:
        assert valid_len is None, \
            "bucketed prefill is attention-family only (recurrent/enc-dec " \
            "archs prefill step-by-step — there is no per-length compile to " \
            "amortize)"
        # step-by-step prefill (exactly equivalent for recurrent/enc-dec archs)
        logits = None
        for i in range(S):
            logits, state = serve_step(params, state, tokens[:, i], cfg)
        return state, logits

    vl = None if valid_len is None else jnp.asarray(valid_len, jnp.int32)
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = jnp.asarray(layer_windows(cfg))
    goe = expert_groups(cfg)
    gm = expert_group_members(cfg)
    x = params["embed"][tokens]
    has_go = "go" in state

    def body(x, xs):
        lp, w = xs
        out = B.attn_block(lp, x, cfg=cfg, positions=positions, window=w,
                           group_of_expert=goe, group_members=gm,
                           return_kv=True, valid_len=vl)
        x, aux, k, v = out
        if has_go:
            # build this layer's GO cache from the expert-choice aux
            e = cfg.moe
            go = go_cache_prefill(
                None, None, aux["weighted_outputs"], aux["chosen_tokens"],
                aux["chosen_scores"], e.top_k)
            return x, (k, v, go)
        return x, (k, v)

    if cfg.cross_attn_every > 0:
        assert valid_len is None, "bucketed prefill: cross-attn archs TODO"
        state, x = _prefill_vlm(params, x, positions, state, cfg)
    else:
        x, ys = jax.lax.scan(body, x, (params["layers"], windows))
        k, v = ys[0], ys[1]
        L = cfg.num_layers
        state["k"] = jax.lax.dynamic_update_slice(
            state["k"], k.astype(state["k"].dtype), (0, 0, 0, 0, 0))
        state["v"] = jax.lax.dynamic_update_slice(
            state["v"], v.astype(state["v"].dtype), (0, 0, 0, 0, 0))
        if has_go:
            state["go"] = ys[2]

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if vl is None:
        logits = logits_from_hidden(params, x[:, -1, :], cfg)
        state["t"] = jnp.asarray(S, jnp.int32)
    else:
        logits = logits_from_hidden(params, jnp.take(x, vl - 1, axis=1), cfg)
        state["t"] = vl
    return state, logits


def prefill_chunk(params: dict, state: dict, tokens: jax.Array, cfg,
                  start, valid_len=None):
    """Append ONE prompt chunk (tokens [B, Cs] at absolute positions
    start..start+Cs-1) to a dense decode state mid-prefill. Chained over
    page-granular chunks this replaces the single long `prefill` pass, so a
    long prompt never stalls the serving engine for more than one chunk of
    work per tick.

    `start` and `valid_len` are TRACED int32 scalars: one compile per chunk
    length serves every chunk of every prompt. The last chunk is
    right-padded to Cs and rides in with `valid_len` = its real token count
    — causal attention plus the kv_len mask keep real positions off the
    pads, and expert-choice routing masks pads out of the chunk's top-C
    (blocks.py::attn_block_chunk), so the merged GO cache holds only real
    tokens. Expert-choice capacity derives from the CHUNK length, so MoE
    streams are deterministic per chunking but may differ from one-shot
    prefill (the same caveat as prompt bucketing). Dense archs reproduce
    the one-shot streams.

    Returns (state, logits) where logits come from chunk position
    valid_len - 1 — only meaningful on the final chunk. state["t"] lands on
    start + valid_len. Attention family only.

    A PAGED state (carries "block_table"/"k_pages"/"v_pages" instead of
    dense "k"/"v" rows — the engine threads the pool's page store through a
    batch-1 view) prefills directly into the pool's pages: each chunk
    scatters its KV to the pages backing its positions and attends over the
    prefix's pages (attention.py::attn_chunk paged path), so chunked
    prefill never materializes a dense [1, max_tokens] KV copy."""
    assert paged_supported(cfg), \
        "chunked prefill is attention-family only (recurrent archs prefill " \
        "step-by-step; enc-dec/vlm archs are one-shot)"
    Bsz, Cs = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    vl = jnp.asarray(Cs if valid_len is None else valid_len, jnp.int32)
    windows = jnp.asarray(layer_windows(cfg))
    goe = expert_groups(cfg)
    gm = expert_group_members(cfg)
    x = params["embed"][tokens]
    has_go = "go" in state
    paged = "block_table" in state
    qkv = paged and "k_scales" in state
    kk, vk = ("k_pages", "v_pages") if paged else ("k", "v")
    bt = state["block_table"] if paged else None

    # Quantized pools bundle (pages, scales) in the carry — same tree.map
    # generalization as _dec_attn. The chunk job's GO cache stays full
    # precision (go_cache_merge reads it); it quantizes once at the
    # write_decode_slot splat on completion.
    def body(carry, xs):
        x, K, V, go, l = carry
        lp, w = xs
        pick = lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
        put = lambda full, new: jax.lax.dynamic_update_index_in_dim(
            full, new.astype(full.dtype), l, 0)
        ck = jax.tree.map(pick, K)
        cv = jax.tree.map(pick, V)
        go_l = jax.tree.map(pick, go) if has_go else None
        x, ck, cv, go_l, _ = B.attn_block_chunk(
            lp, x, ck, cv, start, cfg=cfg, window=w, valid_len=vl,
            group_of_expert=goe, group_members=gm, go_cache=go_l,
            block_table=bt)
        K = jax.tree.map(put, K, ck)
        V = jax.tree.map(put, V, cv)
        if has_go:
            go = jax.tree.map(put, go, go_l)
        return (x, K, V, go, l + 1), None

    K0 = (state[kk], state["k_scales"]) if qkv else state[kk]
    V0 = (state[vk], state["v_scales"]) if qkv else state[vk]
    carry0 = (x, K0, V0, state.get("go"), jnp.zeros((), jnp.int32))
    (x, K, V, go, _), _ = jax.lax.scan(
        body, carry0, (params["layers"], windows))
    state = dict(state)
    if qkv:
        (state[kk], state["k_scales"]) = K
        (state[vk], state["v_scales"]) = V
    else:
        state[kk], state[vk] = K, V
    if has_go:
        state["go"] = go
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, jnp.take(x, vl - 1, axis=1), cfg)
    state["t"] = start + vl
    return state, logits


def _prefill_vlm(params, x, positions, state, cfg):
    memory = state["memory"]
    n_self = cfg.cross_attn_every - 1

    def body(x, xs):
        self_stack, cross_p = xs
        ks, vs = [], []
        for i in range(n_self):
            lp = jax.tree.map(lambda a: a[i], self_stack)
            x, _, k, v = B.attn_block(lp, x, cfg=cfg, positions=positions,
                                      return_kv=True)
            ks.append(k)
            vs.append(v)
        x, _ = B.attn_block(cross_p, x, cfg=cfg, positions=positions,
                            causal=False, kv_source=memory, use_rope=False)
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (k, v) = jax.lax.scan(body, x, (params["layers"], params["cross_layers"]))
    # [n_sup, n_self, B, S, h, hd] -> flat layer index, matching decode state
    k = k.reshape(-1, *k.shape[2:])
    v = v.reshape(-1, *v.shape[2:])
    state["k"] = jax.lax.dynamic_update_slice(
        state["k"], k.astype(state["k"].dtype), (0,) * 5)
    state["v"] = jax.lax.dynamic_update_slice(
        state["v"], v.astype(state["v"].dtype), (0,) * 5)
    return state, x
