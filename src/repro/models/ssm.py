"""Mamba2 (SSD — state-space duality) block, chunked-parallel + recurrent decode.

Follows the minimal-SSD formulation of the Mamba2 paper: scalar A per head,
grouped B/C (ngroups=1), short causal conv on (x, B, C), chunked scan:
intra-chunk quadratic term + inter-chunk state recurrence. The decode path is
the O(1) per-token recurrence on the [H, P, N] state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, split


def _segsum(x):
    """x [..., L] -> [..., L, L] lower-triangular segment sums:
    out[i,j] = sum_{k=j+1..i} x[k] for j<i, 0 for i==j, -inf above."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D_skip, chunk: int):
    """SSD forward.

    x  [b, s, h, p]   per-head inputs
    dt [b, s, h]      positive step sizes
    A  [h]            negative scalars
    B  [b, s, n]      input matrix (ngroups=1, broadcast over heads)
    C  [b, s, n]      output matrix
    D_skip [h]        skip connection
    Returns y [b, s, h, p] and final state [b, h, p, n].
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    while s % L:
        L -= 1
    c = s // L

    xc = x.reshape(b, c, L, h, p)
    dtc = dt.reshape(b, c, L, h)
    Bc = B.reshape(b, c, L, n)
    Cc = C.reshape(b, c, L, n)

    dA = dtc * A  # [b,c,L,h], negative
    dA_cs = jnp.cumsum(dA, axis=2)                    # [b,c,L,h]

    # ---- intra-chunk (quadratic within chunk) ----
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # [b,c,h,L,L]
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)     # [b,c,L,L]
    y_diag = jnp.einsum(
        "bclm,bchlm,bcmh,bcmhp->bclhp", scores, Lmat, dtc, xc,
        preferred_element_type=jnp.float32,
    )

    # ---- chunk states ----
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)          # [b,c,L,h]
    states = jnp.einsum(
        "bcln,bclh,bclhp->bchpn", Bc, dtc * decay_states, xc,
        preferred_element_type=jnp.float32,
    )                                                             # [b,c,h,p,n]

    # ---- inter-chunk recurrence over c ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                     # [b,c,h]

    def step(carry, inp):
        st, dec = inp                                             # [b,h,p,n],[b,h]
        new = carry * dec[..., None, None] + st
        return new, carry                                         # emit state entering chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)            # [b,c,h,p,n]

    # ---- inter-chunk contribution to outputs ----
    state_decay = jnp.exp(dA_cs)                                  # [b,c,L,h]
    y_off = jnp.einsum(
        "bcln,bclh,bchpn->bclhp", Cc, state_decay, prev_states,
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(b, s, h, p) + x * D_skip[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t, D_skip):
    """One-token recurrence. state [b,h,p,n]; x_t [b,h,p]; dt_t [b,h];
    B_t/C_t [b,n]."""
    dA = jnp.exp(dt_t * A)                                        # [b,h]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, B_t)
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t) + x_t * D_skip[None, :, None]
    return state, y.astype(x_t.dtype)


# ------------------------------------------------------------- mamba2 block

def mamba2_init(key, cfg) -> dict:
    d = cfg.d_model
    di = 2 * d
    n = cfg.ssm_state
    h = cfg.num_heads
    dt_ = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = split(key, 4)
    conv_ch = di + 2 * n
    return {
        "in_proj": dense_init(k1, d, 2 * di + 2 * n + h, dt_),   # z, x, B, C, dt
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_ch), jnp.float32)
                   * 0.1).astype(dt_),
        "conv_b": jnp.zeros((conv_ch,), dt_),
        "A_log": jnp.log(jnp.linspace(1.0, float(h), h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_proj": dense_init(k3, di, d, dt_),
        "norm_scale": jnp.ones((di,), jnp.float32),
    }


def _causal_conv(u, w, b):
    """u [B,S,C], depthwise causal conv, width K."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(K))
    return out + b


def _mamba2_project(params, x, cfg):
    d = cfg.d_model
    di = 2 * d
    n = cfg.ssm_state
    h = cfg.num_heads
    zxbcdt = x @ params["in_proj"]
    z, xs, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xs, Bc, Cc, dt, di, n, h


def mamba2_forward(params, x, *, cfg):
    """x [B,S,D] -> [B,S,D] (full-sequence chunked SSD)."""
    z, xs, Bc, Cc, dt, di, n, h = _mamba2_project(params, x, cfg)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"]))
    xs, Bc, Cc = jnp.split(conv_out, [di, di + n], axis=-1)
    b, s = x.shape[0], x.shape[1]
    p = di // h
    dt_pos = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, _ = ssd_chunked(
        xs.reshape(b, s, h, p), dt_pos, A,
        Bc.astype(jnp.float32), Cc.astype(jnp.float32), params["D"], cfg.ssm_chunk,
    )
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2 style)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"]
    return yf.astype(x.dtype) @ params["out_proj"]


def mamba2_init_state(cfg, batch: int):
    d = cfg.d_model
    di = 2 * d
    h = cfg.num_heads
    return {
        "ssm": jnp.zeros((batch, h, di // h, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * cfg.ssm_state),
                          jnp.dtype(cfg.dtype)),
    }


def mamba2_decode(params, x_t, state, *, cfg):
    """x_t [B,1,D]; state dict -> (y [B,1,D], new_state)."""
    z, xs, Bc, Cc, dt, di, n, h = _mamba2_project(params, x_t, cfg)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)          # [B,1,C]
    hist = jnp.concatenate([state["conv"], conv_in], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", hist, params["conv_w"]) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:, :]
    xs, Bc, Cc = jnp.split(conv_out, [di, di + n], axis=-1)
    b = x_t.shape[0]
    p = di // h
    dt_pos = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    new_ssm, y = ssd_decode_step(
        state["ssm"], xs.reshape(b, h, p).astype(jnp.float32), dt_pos, A,
        Bc.astype(jnp.float32), Cc.astype(jnp.float32), params["D"],
    )
    y = y.reshape(b, 1, di)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"]
    out = yf.astype(x_t.dtype) @ params["out_proj"]
    return out, {"ssm": new_ssm, "conv": new_conv}
