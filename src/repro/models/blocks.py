"""Residual blocks assembled from attention / MoE / SSM / xLSTM primitives.

Every block is an (init, apply) pair over plain dict pytrees, with a matching
single-token decode variant that threads its cache/state explicitly. Blocks
are *stackable*: inits are vmap-safe so whole layer stacks can be built with
`stack_init` and consumed by `jax.lax.scan`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import moe as MOE
from repro.core.go_cache import go_cache_step
from repro.kernels import ops as OPS
from repro.models import attention as ATT
from repro.models.layers import (gelu_mlp, gelu_mlp_init, mlp, mlp_init,
                                 rmsnorm, rmsnorm_init)
from repro.models.ssm import mamba2_decode, mamba2_forward, mamba2_init
from repro.models.xlstm import mlstm_block, mlstm_block_init, slstm_block, slstm_block_init


# ------------------------------------------------------- attention (+FFN) block

def attn_block_init(key, cfg, *, use_moe: bool = False, cross: bool = False,
                    gelu: bool = False) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": ATT.attn_init(k1, cfg, cross=cross),
        "ln2": rmsnorm_init(cfg.d_model),
    }
    if use_moe:
        p["moe"] = MOE.moe_init(k2, cfg.d_model, cfg.moe, jnp.dtype(cfg.dtype))
    elif cfg.d_ff > 0:
        p["mlp"] = (gelu_mlp_init if gelu else mlp_init)(
            k2, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype))
    return p


def _ffn_apply(params: dict, x: jax.Array, cfg, group_of_expert,
               group_members=None, valid_len=None) -> tuple:
    """Post-attention FFN sublayer (dense MLP or MoE). x [B,S,d].

    `valid_len` (traced int32 scalar, bucketed prefill) masks right-padded
    positions out of the EXPERT-CHOICE routing — a pad can never win an
    expert slot, so the GO cache built from this pass holds only real
    tokens. Token-choice paths ignore it: routing is per token and pads'
    outputs land only on pad rows (their pairs also rank AFTER every real
    pair in the capacity order, so real drops are unchanged)."""
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    aux = None
    if "moe" in params:
        B, S, d = h.shape
        backend = MOE.resolve_backend(cfg.moe, (h, params))
        # XLA backend routes per sequence (vmap over batch), two reasons:
        #  * the sort-based dispatch never crosses the batch dim, so GSPMD
        #    keeps dispatch buffers batch-sharded (a global argsort over
        #    B*S would gather the whole batch onto every device);
        #  * expert-choice selection per sequence is what the GO cache
        #    serves, so train == serve semantics.
        # The pallas backend keeps ROUTING per sequence (same semantics) but
        # flattens the FFN pairs of the whole batch into one tile plan, so
        # the grouped GEMM pays its per-expert tile padding once, not B times.
        if cfg.moe.routing == "expert_choice":
            if backend == "pallas":
                y, aux = MOE.expert_choice_forward_batched(
                    params["moe"], h, cfg.moe, valid_len=valid_len)
            else:
                y, aux = jax.vmap(
                    lambda xb: MOE.expert_choice_forward(
                        params["moe"], xb, cfg.moe, valid_len=valid_len))(h)
        elif MOE.ep_available(cfg.moe):
            y, aux = MOE.moe_forward_ep(params["moe"], h, cfg.moe)
        elif backend == "pallas":
            y, aux = MOE.moe_forward(params["moe"], h.reshape(B * S, d),
                                     cfg.moe, group_of_expert, group_members)
            y = y.reshape(B, S, d)
        else:
            y, aux = jax.vmap(
                lambda xb: MOE.moe_forward(params["moe"], xb, cfg.moe,
                                           group_of_expert,
                                           group_members))(h)
            aux = {"counts": aux["counts"].sum(0),
                   "balance_loss": aux["balance_loss"].mean(),
                   "dropped": aux["dropped"].sum()}
    elif "mlp" in params:
        w = params["mlp"]
        y = gelu_mlp(w, h) if "wg" not in w else mlp(w, h)
    else:
        y = jnp.zeros_like(h)
    return x + y, aux


def attn_block(params: dict, x: jax.Array, *, cfg, positions, window=0,
               causal: bool = True, group_of_expert=None, group_members=None,
               kv_source=None, use_rope: bool = True,
               return_kv: bool = False, valid_len=None) -> tuple:
    """Full-sequence attention block. Returns (x, aux) with MoE aux or None;
    with return_kv also the post-RoPE (k, v) for KV-cache prefill."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    a = ATT.attn_forward(params["attn"], h, cfg=cfg, positions=positions,
                         window=window, causal=causal, kv_source=kv_source,
                         use_rope=use_rope, return_kv=return_kv)
    if return_kv:
        a, k, v = a
    x = x + a
    x, aux = _ffn_apply(params, x, cfg, group_of_expert, group_members,
                        valid_len)
    if return_kv:
        return x, aux, k, v
    return x, aux


def attn_block_decode(params: dict, x_t: jax.Array, cache_k, cache_v, t, *,
                      cfg, window=0, group_of_expert=None,
                      go_cache=None, block_table=None) -> tuple:
    """One-token decode. x_t [B,1,d]. Returns (x, ck, cv, go_cache, aux).
    With `block_table`, cache_k/cache_v are the shared paged KV pool
    (attention.py::attn_decode paged path); the GO cache stays slot-resident
    either way — it is [E, k]-shaped, not sequence-shaped."""
    h = rmsnorm(params["ln1"], x_t, cfg.norm_eps)
    a, ck, cv = ATT.attn_decode(params["attn"], h, cache_k, cache_v, t,
                                cfg=cfg, window=window,
                                block_table=block_table)
    x = x_t + a
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    aux = None
    if "moe" in params:
        B = h2.shape[0]
        h2f = h2[:, 0]                                   # [B, d]
        if go_cache is not None:
            # C4: expert-choice decode through the GO cache. On the pallas
            # backend only the SELECTED experts' tiles stream through the
            # grouped GEMM (~B*k rows); the xla fallback computes all E
            # expert FFNs per token and masks.
            moe_p = params["moe"]
            e = cfg.moe
            if MOE.resolve_backend(e, (h2f, moe_p)) == "pallas":
                res = go_cache_step(
                    go_cache, h2f, t, moe_p["gate"],
                    contrib_fn=lambda xt, sel, g: OPS.go_selected_ffn(
                        xt, sel, g, moe_p["experts"], e.num_experts,
                        bn=MOE._block_rows(e), topk_hint=e.top_k)[0])
            else:
                res = go_cache_step(
                    go_cache, h2f, t, moe_p["gate"],
                    lambda xt: MOE.expert_ffn_all(moe_p, xt))
            y = res.y + MOE._shared_out(moe_p, h2f)
            go_cache = res.cache
            aux = {"selected": res.selected}
        else:
            y = MOE.token_choice_decode(params["moe"], h2f, cfg.moe)
        x = x + y[:, None, :]
    elif "mlp" in params:
        w = params["mlp"]
        y = gelu_mlp(w, h2) if "wg" not in w else mlp(w, h2)
        x = x + y
    return x, ck, cv, go_cache, aux


def attn_block_chunk(params: dict, x: jax.Array, cache_k, cache_v, start, *,
                     cfg, window=0, valid_len=None, group_of_expert=None,
                     group_members=None, go_cache=None,
                     block_table=None) -> tuple:
    """Chunked-prefill block: append one prompt chunk (x [B,Cs,d] at
    absolute positions start..start+Cs-1) to the KV cache — dense, or with
    `block_table` the shared paged pool — then run the FFN sublayer over
    the chunk. For expert-choice MoE the chunk's routing (capacity from the
    CHUNK length) builds a per-chunk GO cache that merges into the
    accumulated one — `valid_len` (chunk-relative) masks the last chunk's
    right-padding out of the routing, so pads never enter the cache.
    Returns (x, ck, cv, go_cache, aux)."""
    start = jnp.asarray(start, jnp.int32)
    vl = jnp.asarray(x.shape[1] if valid_len is None else valid_len, jnp.int32)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    a, ck, cv = ATT.attn_chunk(params["attn"], h, cache_k, cache_v, start,
                               cfg=cfg, window=window, kv_len=start + vl,
                               block_table=block_table)
    x = x + a
    x, aux = _ffn_apply(params, x, cfg, group_of_expert, group_members, vl)
    if go_cache is not None:
        from repro.core.go_cache import go_cache_merge, go_cache_prefill
        chunk_go = go_cache_prefill(
            None, None, aux["weighted_outputs"],
            aux["chosen_tokens"] + start, aux["chosen_scores"],
            cfg.moe.top_k)
        go_cache = go_cache_merge(go_cache, chunk_go)
    return x, ck, cv, go_cache, aux


def cross_block_decode(params: dict, x_t: jax.Array, memory, *, cfg) -> jax.Array:
    """Cross-attention block decode (static memory, no cache growth)."""
    h = rmsnorm(params["ln1"], x_t, cfg.norm_eps)
    a = ATT.cross_attn_decode(params["attn"], h, memory, cfg=cfg)
    x = x_t + a
    x, _ = _ffn_apply(params, x, cfg, None)
    return x


# ------------------------------------------------------------- mamba2 block

def mamba2_block_init(key, cfg) -> dict:
    return {"ln": rmsnorm_init(cfg.d_model), "mix": mamba2_init(key, cfg)}


def mamba2_block(params: dict, x: jax.Array, *, cfg) -> jax.Array:
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    return x + mamba2_forward(params["mix"], h, cfg=cfg)


def mamba2_block_decode(params: dict, x_t: jax.Array, state, *, cfg) -> tuple:
    h = rmsnorm(params["ln"], x_t, cfg.norm_eps)
    y, new_state = mamba2_decode(params["mix"], h, state, cfg=cfg)
    return x_t + y, new_state


__all__ = [
    "attn_block_init", "attn_block", "attn_block_decode", "attn_block_chunk",
    "cross_block_decode",
    "mamba2_block_init", "mamba2_block", "mamba2_block_decode",
    "mlstm_block_init", "mlstm_block", "slstm_block_init", "slstm_block",
]
