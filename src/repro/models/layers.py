"""Shared neural-net building blocks (pure functional JAX).

Params are plain dict pytrees. Every module is an (init, apply) pair.
Compute convention: activations in cfg.dtype (bf16), normalization and
softmax statistics in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------ sharding hints

def current_mesh():
    """The Mesh from an enclosing `with mesh:` context, or None."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m is None or m.empty else m


def mesh_axis(name: str) -> int:
    m = current_mesh()
    return m.shape[name] if (m is not None and name in m.axis_names) else 1


def dp_spec():
    """The data-parallel axes of the active mesh (pod folds into DP)."""
    m = current_mesh()
    if m is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in m.axis_names)
    return axes if axes else None


def shard_hint(x, *spec):
    """with_sharding_constraint against the ACTIVE mesh; silently a no-op
    outside a mesh context (single-device tests / examples). Axis entries
    whose name is absent from the mesh are dropped to None."""
    m = current_mesh()
    if m is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    names = set(m.axis_names)

    def clean(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            return e if (e and all(a in names for a in e)) else None
        return e if e in names else None

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, PartitionSpec(*[clean(e) for e in spec])))


# ---------------------------------------------------------------- init utils

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split(key, n: int):
    return list(jax.random.split(key, n))


# ------------------------------------------------------------------- RMSNorm

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- RoPE

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions [*, S] -> (cos, sin) each [*, S, head_dim//2], fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, D]; cos/sin broadcastable [..., S, 1, D//2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- SwiGLU

def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = split(key, 3)
    return {
        "wi": dense_init(k1, d, d_ff, dtype),
        "wg": dense_init(k2, d, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d, dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    return h @ params["wo"]


def gelu_mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2 = split(key, 2)
    return {"wi": dense_init(k1, d, d_ff, dtype), "wo": dense_init(k2, d_ff, d, dtype)}


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]


# ------------------------------------------------------------------- softcap

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# -------------------------------------------------------- stacked-layer init

def stack_init(key, n: int, init_fn):
    """vmap an init over n layer keys -> stacked param pytree [n, ...]."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
