"""AdamW + cosine schedule + global-norm clipping + gradient accumulation.

Self-contained (no optax): plain pytree transforms so the optimizer state is
an ordinary dict pytree that the sharder (ZeRO-1: m/v sharded over data AND
model axes) and the checkpointer can treat uniformly.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # [] int32
    m: dict                  # first moment (fp32, same tree as params)
    v: dict                  # second moment (fp32)


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def adamw_init(params: dict) -> AdamWState:
    def zeros():
        # fresh buffers for m and v (aliased buffers break donation)
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if _is_float(p) else None,
            params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(), zeros())


def cosine_lr(step, *, base_lr: float, warmup: int, total: int,
              min_frac: float = 0.1):
    warm = base_lr * (step + 1) / max(1, warmup)
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree) if x is not None]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(
        lambda x: None if x is None else x * scale, grads,
        is_leaf=lambda x: x is None), g


def adamw_update(params: dict, grads: dict, state: AdamWState, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(
        lambda g, p: g.astype(jnp.float32) if _is_float(p) else None,
        grads, params)
    if grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = global_norm(grads)
    t = state.step + 1
    c1 = 1.0 - b1 ** t.astype(jnp.float32)
    c2 = 1.0 - b2 ** t.astype(jnp.float32)

    def upd(p, g, m, v):
        if g is None:
            return p, m, v
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / c1
        vh = v2 / c2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(t, new_m, new_v), {"grad_norm": gnorm}


def accumulate_grads(loss_fn, params, microbatches, cfg):
    """Gradient accumulation via scan over leading microbatch axis.
    microbatches: dict of arrays [n_micro, per_micro, ...]."""
    def body(carry, mb):
        acc, loss_acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb, cfg)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    n = jax.tree.leaves(microbatches)[0].shape[0]
    (g, loss), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                microbatches)
    inv = 1.0 / n
    return jax.tree.map(lambda x: x * inv, g), loss * inv
