"""Deterministic, seekable, shard-aware synthetic data pipeline.

Design goals (the properties a production loader must have, realized without
an external corpus):

  * deterministic & seekable — batch(step) is a pure function of
    (seed, step, shard), so a restarted job resumes bit-exactly from a
    checkpointed step with NO replayed or skipped samples;
  * shard-aware — each data-parallel shard draws a disjoint slice;
  * structured — token streams are Zipf-distributed with Markov locality so
    models actually learn (loss decreases), unlike uniform noise;
  * packed — fixed (seq_len + 1) windows yield (tokens, labels) pairs.

Swapping in a real tokenized corpus only requires replacing `_window`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3           # unigram skew
    locality: float = 0.7         # P(next token ~ local bigram state)


class SyntheticCorpus:
    """Infinite deterministic corpus; `batch(step, shard, num_shards)` is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        V = cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        # fixed unigram distribution (Zipf) + a sparse "bigram" successor map
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.unigram = p / p.sum()
        self.successor = rng.integers(0, V, size=V, dtype=np.int64)

    def _window(self, idx: int) -> np.ndarray:
        """Sample window `idx` of length seq_len + 1 (pure function of idx)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, 0xDA7A, idx))
        n = cfg.seq_len + 1
        draws = rng.choice(cfg.vocab_size, size=n, p=self.unigram)
        use_local = rng.random(n) < cfg.locality
        out = np.empty(n, np.int64)
        out[0] = draws[0]
        for i in range(1, n):
            out[i] = self.successor[out[i - 1]] if use_local[i] else draws[i]
        return out

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Global batch for `step`, sliced for `shard` of `num_shards`."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        per = cfg.global_batch // num_shards
        base = step * cfg.global_batch + shard * per
        rows = np.stack([self._window(base + i) for i in range(per)])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


def make_batch_iterator(cfg: DataConfig, start_step: int = 0,
                        shard: int = 0, num_shards: int = 1):
    """Resumable iterator: (step, batch) pairs from `start_step`."""
    corpus = SyntheticCorpus(cfg)
    step = start_step
    while True:
        yield step, corpus.batch(step, shard, num_shards)
        step += 1
