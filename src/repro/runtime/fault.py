"""Fault tolerance: step supervision, retry policy, straggler detection.

At 1000+ nodes the failure model is: transient device/step errors (retry),
hard node loss (restart from checkpoint, possibly re-meshed — see elastic.py),
and stragglers (slow steps that stall the synchronous collective).

`StepSupervisor` wraps the train step:
  * retries transient failures up to `max_retries` (with the same inputs —
    steps are deterministic given (params, batch), so retry is safe);
  * raises `RestartRequired` after exhausting retries — the launcher catches
    it, restores the latest committed checkpoint, and resumes (train.py);
  * records per-step wall times and flags stragglers at
    median * straggler_factor; the hook is where a production deployment
    would trigger hot-spare swap / re-sharding. At the MoE layer the C2
    load-aware placement is itself the straggler *prevention* mechanism.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class RestartRequired(RuntimeError):
    """Raised when a step cannot be completed in-place; the launcher must
    restore from the latest committed checkpoint."""


@dataclass
class StepStats:
    times: list = field(default_factory=list)
    retries: int = 0
    stragglers: list = field(default_factory=list)

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]


class StepSupervisor:
    """Shared by the training loop AND the serving engine's decode tick
    (serving/engine.py): both steps are deterministic given their inputs,
    so retrying with the same inputs is always safe. `retry_on` narrows or
    widens the transient-error classes (RestartRequired is never retried —
    it IS the give-up signal)."""

    def __init__(self, max_retries: int = 2, straggler_factor: float = 3.0,
                 on_straggler=None,
                 retry_on: tuple = (RuntimeError, ValueError)):
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.retry_on = tuple(retry_on)
        self.stats = StepStats()

    def run(self, step_fn, *args, step: int = -1, **kw):
        """Execute step_fn with retry + timing. Returns its result."""
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                out = step_fn(*args, **kw)
                out = _block(out)
                break
            except self.retry_on as e:
                if isinstance(e, RestartRequired):
                    raise
                attempt += 1
                self.stats.retries += 1
                if attempt > self.max_retries:
                    raise RestartRequired(
                        f"step {step} failed {attempt} times: {e}") from e
        dt = time.perf_counter() - t0
        med = self.stats.median()
        self.stats.times.append(dt)
        if med > 0 and dt > med * self.straggler_factor:
            self.stats.stragglers.append((step, dt, med))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, med)
        return out


def _block(x):
    """Force async dispatch errors to surface inside the supervised region."""
    import jax
    return jax.block_until_ready(x)
