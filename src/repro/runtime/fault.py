"""Fault tolerance: step supervision, retry policy, straggler detection,
and process-level supervision for the serving path.

At 1000+ nodes the failure model is: transient device/step errors (retry),
hard node loss (restart from checkpoint, possibly re-meshed — see elastic.py),
and stragglers (slow steps that stall the synchronous collective).

`StepSupervisor` wraps the train step:
  * retries transient failures up to `max_retries` (with the same inputs —
    steps are deterministic given (params, batch), so retry is safe);
  * raises `RestartRequired` after exhausting retries — the launcher catches
    it, restores the latest committed checkpoint, and resumes (train.py);
  * records per-step wall times and flags stragglers at
    median * straggler_factor; the hook is where a production deployment
    would trigger hot-spare swap / re-sharding. At the MoE layer the C2
    load-aware placement is itself the straggler *prevention* mechanism.

`ProcessSupervisor` is the serving analogue one level up: the engine runs
in a CHILD process (launch/serve.py --supervise re-execs itself) that
journals every request lifecycle event (serving/journal.py); the parent
watches for exits and missed heartbeats, SIGKILLs a hung child, restarts
with exponential backoff, and each restarted generation re-dispatches
through `ServingEngine.recover()` — the same restore-from-committed-state
contract the training launcher has, extended across the process boundary.
Heartbeats are file mtimes (the engine touches REPRO_HEARTBEAT once per
tick): no pipes to deadlock on, works under SIGKILL, and the staleness
threshold can stay generous because jit compiles legitimately stall early
ticks for tens of seconds."""
from __future__ import annotations

import os
import subprocess
import time
from dataclasses import dataclass, field


class RestartRequired(RuntimeError):
    """Raised when a step cannot be completed in-place; the launcher must
    restore from the latest committed checkpoint."""


@dataclass
class StepStats:
    times: list = field(default_factory=list)
    retries: int = 0
    stragglers: list = field(default_factory=list)

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]


class StepSupervisor:
    """Shared by the training loop AND the serving engine's decode tick
    (serving/engine.py): both steps are deterministic given their inputs,
    so retrying with the same inputs is always safe. `retry_on` narrows or
    widens the transient-error classes (RestartRequired is never retried —
    it IS the give-up signal)."""

    def __init__(self, max_retries: int = 2, straggler_factor: float = 3.0,
                 on_straggler=None,
                 retry_on: tuple = (RuntimeError, ValueError)):
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.retry_on = tuple(retry_on)
        self.stats = StepStats()

    def run(self, step_fn, *args, step: int = -1, **kw):
        """Execute step_fn with retry + timing. Returns its result."""
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                out = step_fn(*args, **kw)
                out = _block(out)
                break
            except self.retry_on as e:
                if isinstance(e, RestartRequired):
                    raise
                attempt += 1
                self.stats.retries += 1
                if attempt > self.max_retries:
                    raise RestartRequired(
                        f"step {step} failed {attempt} times: {e}") from e
        dt = time.perf_counter() - t0
        med = self.stats.median()
        self.stats.times.append(dt)
        if med > 0 and dt > med * self.straggler_factor:
            self.stats.stragglers.append((step, dt, med))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, med)
        return out


def _block(x):
    """Force async dispatch errors to surface inside the supervised region."""
    import jax
    return jax.block_until_ready(x)


@dataclass
class SupervisorStats:
    restarts: int = 0
    heartbeat_kills: int = 0
    exit_codes: list = field(default_factory=list)


class ProcessSupervisor:
    """Run a child process under restart supervision with file-mtime
    heartbeats.

    Each generation gets REPRO_SUPERVISE_GENERATION=<n> in its environment
    (generation 0 is the first launch) and, when a heartbeat file is
    configured, REPRO_HEARTBEAT=<path> — the serving engine touches that
    file every tick. A child that exits 0 ends supervision; any other exit
    (including SIGKILL from a chaos crash) restarts it after an
    exponentially backed-off delay, up to `max_restarts` restarts, after
    which RestartRequired propagates to the caller. A child whose heartbeat
    goes stale past `heartbeat_timeout_s` is SIGKILLed and restarted
    through the same path — a hang and a crash are the same failure to the
    recovery contract.

    The child decides WHAT to do differently per generation (the serve CLI
    recovers from the journal when one exists); the supervisor only decides
    WHETHER it runs. `heartbeat_timeout_s` defaults generous because jit
    compilation legitimately stalls the first ticks for tens of seconds."""

    def __init__(self, cmd: list, *, env: dict | None = None,
                 heartbeat_file: str | None = None,
                 heartbeat_timeout_s: float = 120.0,
                 max_restarts: int = 3, backoff_s: float = 0.25,
                 backoff_factor: float = 2.0, poll_s: float = 0.1,
                 on_restart=None):
        self.cmd = list(cmd)
        self.env = env
        self.heartbeat_file = heartbeat_file
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.backoff_factor = backoff_factor
        self.poll_s = poll_s
        self.on_restart = on_restart
        self.stats = SupervisorStats()

    def run(self) -> int:
        """Supervise until a generation exits 0 (returns 0) or the restart
        budget is exhausted (raises RestartRequired)."""
        generation = 0
        backoff = self.backoff_s
        while True:
            env = dict(os.environ if self.env is None else self.env)
            env["REPRO_SUPERVISE_GENERATION"] = str(generation)
            if self.heartbeat_file:
                env["REPRO_HEARTBEAT"] = self.heartbeat_file
                # prime the mtime so staleness counts from LAUNCH, not from
                # whenever a previous generation last ticked
                with open(self.heartbeat_file, "a"):
                    os.utime(self.heartbeat_file, None)
            proc = subprocess.Popen(self.cmd, env=env)
            code = self._watch(proc)
            self.stats.exit_codes.append(code)
            if code == 0:
                return 0
            if self.stats.restarts >= self.max_restarts:
                raise RestartRequired(
                    f"child failed {self.stats.restarts + 1} times "
                    f"(exit codes {self.stats.exit_codes}) — restart budget "
                    f"of {self.max_restarts} exhausted")
            self.stats.restarts += 1
            generation += 1
            if self.on_restart is not None:
                self.on_restart(generation, code)
            time.sleep(backoff)
            backoff *= self.backoff_factor

    def _watch(self, proc) -> int:
        """Poll one generation to exit, SIGKILLing it on heartbeat
        staleness. Returns its exit code."""
        while True:
            code = proc.poll()
            if code is not None:
                return code
            if self.heartbeat_file:
                try:
                    age = time.time() - os.path.getmtime(self.heartbeat_file)
                except OSError:
                    age = 0.0
                if age > self.heartbeat_timeout_s:
                    proc.kill()
                    proc.wait()
                    self.stats.heartbeat_kills += 1
                    return -9
            time.sleep(self.poll_s)
