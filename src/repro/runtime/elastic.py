"""Elastic scaling: re-mesh on device-count change and continue from the
latest checkpoint.

Checkpoints are host-gathered full arrays (checkpoint/ckpt.py), so a restore
under ANY new mesh only needs the new NamedShardings: `remesh_plan` picks the
largest (data, model) grid that the new device count supports while keeping
the model axis large enough for the biggest sharded dim to fit per-device
memory, and `reshard` places a restored pytree onto it. The data pipeline is
seekable, so resuming at (step, new num_shards) is bit-exact w.r.t. sample
order per step.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh_plan(num_devices: int, *, prefer_model: int = 16,
                multi_pod_threshold: int = 512) -> tuple:
    """Pick mesh shape+axes for an arbitrary surviving device count.
    Keeps the model axis at the largest power-of-two divisor <= prefer_model;
    splits off a pod axis above the threshold."""
    model = 1
    while model * 2 <= prefer_model and num_devices % (model * 2) == 0:
        model *= 2
    rest = num_devices // model
    if num_devices >= multi_pod_threshold and rest % 2 == 0:
        return (2, rest // 2, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_mesh_from_plan(shape, axes, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    arr = np.asarray(devices[:int(np.prod(shape))]).reshape(shape)
    return Mesh(arr, axes)


def reshard(tree, mesh: Mesh, spec_tree) -> dict:
    """Place every leaf onto `mesh` with its PartitionSpec from spec_tree."""
    def put(x, spec):
        if x is None:
            return None
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, spec_tree,
                        is_leaf=lambda x: x is None or isinstance(x, P))
