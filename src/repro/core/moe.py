"""MoE layer: the paper's techniques as first-class JAX features.

Execution paths (all numerically validated against `dense_forward`):

  dense_forward      reference oracle: every expert over every token, masked.
  dispatch_forward   production path (train/prefill): sort-based capacity
                     dispatch (megablocks-style), batched expert GEMM, combine.
                     Expert dim is EP-sharded; the C2 load-aware permutation is
                     applied to the expert axis at deployment so each EP shard
                     carries balanced aggregate load.
  group_forward      C1 group-multiplexed path: experts share a group lane
                     with POOLED capacity (the TPU analogue of shared
                     peripherals: padding amortized at group granularity).
  expert-choice      routing where experts pick tokens (Zhou et al.); decode
                     uses the GO cache (core/go_cache.py) instead of this.

Every routed path executes on one of two BACKENDS, selected by
`MoEConfig.backend` (resolved by `resolve_backend`):

  "xla"     masked/capacity-padded einsum realization. group_forward masks
            over the g group members (g x redundant FLOPs); dispatch packs
            [E, C, d] capacity buffers. Correct everywhere; the CPU default.
  "pallas"  the tile-dispatch grouped GEMM (kernels/moe_gmm + kernels/ops):
            (group, expert)-sorted rows stream through ONE execution lane,
            each expert weight tile staged exactly once per column stripe —
            the paper's C1 multiplexing with ZERO redundant member passes.
            Combine weights are applied in-kernel (gmm_scaled); the path is
            dropless (worst-case tile padding instead of capacity drops;
            pooled-capacity overflow reduces to zero combine weights so the
            C1 drop semantics are preserved bit-for-bit).
  "auto"    pallas on TPU (Mosaic lowering), xla elsewhere.

Aux outputs carry load statistics for the balance loss and for the C2
workload tracer.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import routing as R
from repro.kernels import ops as OPS
from repro.models.layers import dense_init, split


def resolve_backend(e: MoEConfig, refs=None) -> str:
    """Resolve `MoEConfig.backend` to the concrete engine for this host.

    Pass the layer inputs/params (any pytree) as `refs` to fail fast when an
    EXPLICIT backend="pallas" is traced for differentiation: the pallas
    kernels define no VJP yet, and without this guard the failure surfaces
    deep inside jax at transpose time as a bare `NotImplementedError` with
    an EMPTY message (grads flow through the params, so the activations
    alone are not enough — a layer-level `jax.grad` over params closes over
    constant activations)."""
    b = getattr(e, "backend", "auto")
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if b not in ("xla", "pallas"):
        raise ValueError(f"unknown MoE backend: {b!r}")
    if b == "pallas" and refs is not None and any(
            _under_autodiff(l) for l in jax.tree.leaves(refs)):
        raise NotImplementedError(
            "pallas backend has no backward pass yet; use backend='auto' or "
            "'xla' for training (loss_fn already pins 'auto' to xla — see "
            "ROADMAP: custom VJP over gmm/gmm_swiglu)")
    return b


def _under_autodiff(x) -> bool:
    """Best-effort: is `x` being traced for differentiation? Walks the tracer
    nesting for a JVP tracer (grad/vjp linearization), unwrapping jit/vmap
    tracers along the way. grad-of-jit retraces are caught at transpose time
    by jax itself — this only makes the common paths fail early and clearly."""
    from jax.interpreters import ad
    t = x
    for _ in range(16):
        if not isinstance(t, jax.core.Tracer):
            return False
        if isinstance(t, ad.JVPTracer):
            return True
        t = getattr(t, "primal", getattr(t, "val", None))
    return False


def _block_rows(e: MoEConfig) -> int:
    return getattr(e, "gmm_block_rows", 0) or OPS.default_block_rows()


# ----------------------------------------------------------------------- init

def moe_init(key, d_model: int, e: MoEConfig, dtype) -> dict:
    ks = split(key, 7)
    E, de = e.num_experts, e.d_expert

    def bank(k1, k2, k3, n):
        kk1 = jax.random.split(k1, n)
        kk2 = jax.random.split(k2, n)
        kk3 = jax.random.split(k3, n)
        return {
            "wi": jax.vmap(lambda k: dense_init(k, d_model, de, dtype))(kk1),
            "wg": jax.vmap(lambda k: dense_init(k, d_model, de, dtype))(kk2),
            "wo": jax.vmap(lambda k: dense_init(k, de, d_model, dtype))(kk3),
        }

    p = {
        "gate": dense_init(ks[0], d_model, E, jnp.float32),
        "experts": bank(ks[1], ks[2], ks[3], E),
    }
    if e.num_shared_experts:
        p["shared"] = bank(ks[4], ks[5], ks[6], e.num_shared_experts)
    return p


def _expert_gemm(bank: dict, x: jax.Array) -> jax.Array:
    """x [E, C, d] -> [E, C, d] through each expert's SwiGLU FFN."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, bank["wg"])) * jnp.einsum(
        "ecd,edf->ecf", x, bank["wi"])
    return jnp.einsum("ecf,efd->ecd", h, bank["wo"])


def _shared_out(params: dict, x: jax.Array) -> jax.Array:
    """Always-on shared experts (deepseek-style). x [T, d]."""
    if "shared" not in params:
        return jnp.zeros_like(x)
    sh = params["shared"]
    h = jax.nn.silu(jnp.einsum("td,sdf->stf", x, sh["wg"])) * jnp.einsum(
        "td,sdf->stf", x, sh["wi"])
    return jnp.einsum("stf,sfd->td", h, sh["wo"]).astype(x.dtype)


def expert_ffn_all(params: dict, x: jax.Array) -> jax.Array:
    """All-expert outputs for a token batch. x [B, d] -> [B, E, d].
    Used by the GO-cache decode step (dense fallback) and the oracle."""
    b = params["experts"]
    h = jax.nn.silu(jnp.einsum("td,edf->etf", x, b["wg"])) * jnp.einsum(
        "td,edf->etf", x, b["wi"])
    return jnp.einsum("etf,efd->ted", h, b["wo"])


# --------------------------------------------------------------------- oracle

def dense_forward(params: dict, x: jax.Array, e: MoEConfig) -> jax.Array:
    """Reference: [T, d] -> [T, d], token-choice or expert-choice, no capacity
    limits (expert-choice uses exact top-C over the full batch)."""
    T = x.shape[0]
    eo = expert_ffn_all(params, x)                       # [T, E, d]
    if e.routing == "token_choice":
        r = R.token_choice(x, params["gate"], e.top_k)
        mask = jnp.zeros((T, e.num_experts), jnp.float32)
        mask = jax.vmap(lambda m, i, w: m.at[i].add(w))(mask, r.expert_idx, r.weights)
    else:
        cap = ec_capacity(T, e)
        r = R.expert_choice(x, params["gate"], cap)
        mask = jnp.zeros((e.num_experts, T), jnp.float32)
        mask = jax.vmap(lambda m, i, w: m.at[i].add(w))(
            mask, r.token_idx, r.weights)
        mask = mask.T
    y = jnp.einsum("te,ted->td", mask, eo.astype(jnp.float32))
    return (y + _shared_out(params, x).astype(jnp.float32)).astype(x.dtype)


def ec_capacity(num_tokens: int, e: MoEConfig) -> int:
    """Expert-choice capacity: on average top_k experts per token."""
    return max(1, (num_tokens * e.top_k) // e.num_experts)


# --------------------------------------------- sort-based capacity dispatch

class DispatchPlan(NamedTuple):
    x_disp: jax.Array        # [E, C, d] dispatched tokens (zeros where empty)
    dest: jax.Array          # [N] flat slot (E*C = dropped)
    weights: jax.Array       # [N] combine weights
    token: jax.Array         # [N] source token per pair
    counts: jax.Array        # [E] tokens routed per expert (pre-capacity)


def _expert_positions(expert_flat):
    """Stable expert-sort of routed pairs + each pair's position within its
    expert's run — THE capacity-eviction order. Every realization of a
    capacity drop (buffer eviction in `_plan_dispatch`, zero combine weights
    in the EP pallas branch) must consume this one definition, or sharded
    xla-vs-pallas drop parity silently breaks."""
    N = expert_flat.shape[0]
    order = jnp.argsort(expert_flat, stable=True)
    se = expert_flat[order]
    pos = jnp.arange(N, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left").astype(jnp.int32)
    return order, se, pos


def _plan_dispatch(x, expert_flat, weights_flat, token_flat, E, C):
    N = expert_flat.shape[0]
    order, se, pos = _expert_positions(expert_flat)
    dest_sorted = jnp.where(pos < C, se * C + pos, E * C)
    # O(N) scatter inversion of the sort permutation (was a second argsort)
    dest = jnp.zeros((N,), jnp.int32).at[order].set(dest_sorted)
    buf = jnp.zeros((E * C + 1, x.shape[-1]), x.dtype)
    x_disp = buf.at[dest].set(x[token_flat], mode="drop")[:-1].reshape(E, C, -1)
    counts = jnp.bincount(expert_flat, length=E)
    return DispatchPlan(x_disp, dest, weights_flat, token_flat, counts)


def _combine(y_disp, plan, T, out_dtype):
    flat = jnp.concatenate(
        [y_disp.reshape(-1, y_disp.shape[-1]),
         jnp.zeros((1, y_disp.shape[-1]), y_disp.dtype)], axis=0)
    y_pairs = flat[plan.dest].astype(jnp.float32) * plan.weights[:, None]
    out = jnp.zeros((T, y_disp.shape[-1]), jnp.float32)
    out = out.at[plan.token].add(y_pairs)
    return out.astype(out_dtype)


def dispatch_forward(params: dict, x: jax.Array, e: MoEConfig,
                     capacity: int = 0) -> tuple:
    """Production token-choice path. x [T, d] -> (y [T, d], aux dict).

    backend="pallas" routes through the tile-dispatch grouped GEMM: no
    [E, C, d] capacity buffer and no drops (padding absorbs the worst case),
    combine weights fused in-kernel."""
    if resolve_backend(e, (x, params)) == "pallas":
        return _dispatch_forward_pallas(params, x, e)
    T = x.shape[0]
    E, k = e.num_experts, e.top_k
    C = capacity or max(1, int(math.ceil(T * k / E * e.capacity_factor)))
    r = R.token_choice(x, params["gate"], k)
    expert_flat = r.expert_idx.reshape(-1).astype(jnp.int32)
    weights_flat = r.weights.reshape(-1)
    token_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    plan = _plan_dispatch(x, expert_flat, weights_flat, token_flat, E, C)
    y_disp = _expert_gemm(params["experts"], plan.x_disp)
    y = _combine(y_disp, plan, T, x.dtype) + _shared_out(params, x)
    aux = {
        "counts": plan.counts,
        "balance_loss": R.load_balance_loss(r.scores, r.expert_idx, E),
        "dropped": (plan.dest == E * C).sum(),
    }
    return y, aux


def _dispatch_forward_pallas(params: dict, x: jax.Array, e: MoEConfig) -> tuple:
    """Token-choice through the tile-dispatch grouped GEMM (dropless)."""
    T = x.shape[0]
    E, k = e.num_experts, e.top_k
    r = R.token_choice(x, params["gate"], k)
    ef = r.expert_idx.reshape(-1).astype(jnp.int32)
    wf = r.weights.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    y, _, plan = OPS.moe_ffn_fused(x, tok, ef, wf, params["experts"], E, T,
                                   bn=_block_rows(e))
    y = y.astype(x.dtype) + _shared_out(params, x)
    aux = {
        "counts": plan.counts,
        "balance_loss": R.load_balance_loss(r.scores, r.expert_idx, E),
        "dropped": jnp.zeros((), jnp.int32),
    }
    return y, aux


def group_forward(params: dict, x: jax.Array, e: MoEConfig,
                  group_of_expert: jax.Array, pool_factor: float = 0.7,
                  members: jax.Array | None = None) -> tuple:
    """C1 — group-multiplexed path with POOLED group capacity.

    Experts of a group share one lane buffer of size C_grp = g * C_exp *
    pool_factor: pooling lets a hot expert borrow slots from its cold
    group-mates (the paper pairs them by sorted load precisely so this works),
    cutting padded slots vs per-expert buckets at equal drop rate.
    The XLA realization masks over the g members (g x redundant FLOPs); the
    pallas backend removes the redundancy by expert-indexed weight staging
    over (group, expert)-sorted tiles. `members` is the [G, g] expert-id
    matrix precomputed at deployment (models/model.py:expert_group_members);
    when None it is derived from `group_of_expert` in-trace.
    """
    T = x.shape[0]
    E, k, g = e.num_experts, e.top_k, e.group_size
    G = E // g
    C_exp = max(1, int(math.ceil(T * k / E * e.capacity_factor)))
    C_grp = max(1, int(math.ceil(g * C_exp * pool_factor)))
    if members is None:
        members = _members_matrix(group_of_expert, G, g)         # [G, g]
    if resolve_backend(e, (x, params)) == "pallas":
        return _group_forward_pallas(params, x, e, group_of_expert, members,
                                     C_grp)
    r = R.token_choice(x, params["gate"], k)
    expert_flat = r.expert_idx.reshape(-1).astype(jnp.int32)
    grp_flat = group_of_expert[expert_flat]
    weights_flat = r.weights.reshape(-1)
    token_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    # dispatch by GROUP, but keep rows sorted by (group, expert) so the kernel
    # sees expert-contiguous runs (dispatch-locality analogue of Alg. 1)
    order, sg, pos = _group_sorted_positions(grp_flat, expert_flat, E)
    dest_sorted = jnp.where(pos < C_grp, sg * C_grp + pos, G * C_grp)
    N = order.shape[0]
    dest = jnp.zeros((N,), jnp.int32).at[order].set(dest_sorted)
    buf = jnp.zeros((G * C_grp + 1, x.shape[-1]), x.dtype)
    x_disp = buf.at[dest].set(x[token_flat], mode="drop")[:-1].reshape(G, C_grp, -1)
    row_expert = jnp.full((G * C_grp + 1,), -1, jnp.int32).at[dest].set(
        expert_flat, mode="drop")[:-1].reshape(G, C_grp)

    # XLA fallback: accumulate each member's masked contribution
    bank = params["experts"]
    y_disp = jnp.zeros(x_disp.shape, jnp.float32)
    for j in range(g):
        eid = members[:, j]                                      # [G]
        wg = bank["wg"][eid]
        wi = bank["wi"][eid]
        wo = bank["wo"][eid]
        h = jax.nn.silu(jnp.einsum("gcd,gdf->gcf", x_disp, wg)) * jnp.einsum(
            "gcd,gdf->gcf", x_disp, wi)
        yj = jnp.einsum("gcf,gfd->gcd", h, wo)
        m = (row_expert == eid[:, None])[..., None]
        y_disp = y_disp + jnp.where(m, yj.astype(jnp.float32), 0.0)

    plan = DispatchPlan(x_disp, dest, weights_flat, token_flat,
                        jnp.bincount(expert_flat, length=E))
    y = _combine(y_disp.astype(x.dtype), plan, T, x.dtype) + _shared_out(params, x)
    aux = {
        "counts": plan.counts,
        "balance_loss": R.load_balance_loss(r.scores, r.expert_idx, E),
        "dropped": (dest == G * C_grp).sum(),
        "slots": G * C_grp,
    }
    return y, aux


def _group_sorted_positions(grp: jax.Array, ef: jax.Array, E: int):
    """(group, expert)-stable sort of routed pairs + position of each pair
    within its GROUP's run. ONE definition shared by both backends: the
    pooled-capacity drop set (pos >= C_grp) must be identical whether it is
    realized as a buffer eviction (xla) or a zero combine weight (pallas) —
    pinned by tests/test_moe_paths.py drop-parity."""
    sort_key = grp * E + ef
    order = jnp.argsort(sort_key, stable=True)
    sg = grp[order]
    pos = jnp.arange(order.shape[0], dtype=jnp.int32) - jnp.searchsorted(
        sg, sg, side="left").astype(jnp.int32)
    return order, sg, pos


@functools.lru_cache(maxsize=None)
def _group_fuse_pairs(E: int, g: int) -> tuple:
    """Pairwise lane-fusion map over the group-major lane ranks: members of
    one C2 group pair up two at a time (an odd trailing member rides alone),
    so each pair of under-occupied member runs shares its boundary tile —
    the roadmap's dynamic lane fusion, static per deployment."""
    fuse = [0] * E
    nid = 0
    for grp in range(E // g):
        for j in range(0, g, 2):
            fuse[grp * g + j] = nid
            if j + 1 < g:
                fuse[grp * g + j + 1] = nid
            nid += 1
    return tuple(fuse)


def group_lane_map(members: jax.Array, group_size: int):
    """ONE definition of the C1 group-major lane layout, shared by the
    production path (`_group_forward_pallas`) and the benchmark's plan
    accounting: lane rank r holds expert `lane_of_rank[r]`, and lanes fuse
    pairwise within their group. Returns (lane_of_rank [E], rank_of_expert
    [E], fuse tuple [E])."""
    lane_of_rank = jnp.asarray(members, jnp.int32).reshape(-1)
    E = lane_of_rank.shape[0]
    rank_of_expert = jnp.zeros((E,), jnp.int32).at[lane_of_rank].set(
        jnp.arange(E, dtype=jnp.int32))
    return lane_of_rank, rank_of_expert, _group_fuse_pairs(E, group_size)


def _group_forward_pallas(params: dict, x: jax.Array, e: MoEConfig,
                          group_of_expert: jax.Array, members: jax.Array,
                          C_grp: int) -> tuple:
    """C1 pooled-capacity semantics on the zero-redundancy kernel.

    The SAME (group, expert)-stable order as the XLA path decides which pairs
    overflow the pooled group buffer; overflow pairs keep their rows but get a
    ZERO combine weight — numerically identical to a drop, while every
    surviving row streams through the grouped GEMM exactly once (no g x
    member masking). Tiles are planned in group-major lane order with the
    group's member lanes FUSED pairwise (`_group_fuse_pairs`), so the
    multiplexed lane sees its members' runs back to back in shared tiles.
    """
    T = x.shape[0]
    E, k, g = e.num_experts, e.top_k, e.group_size
    G = E // g
    r = R.token_choice(x, params["gate"], k)
    ef = r.expert_idx.reshape(-1).astype(jnp.int32)
    grp = group_of_expert[ef]
    wf = r.weights.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    N = ef.shape[0]

    # pooled-capacity overflow in (group, expert)-stable order == XLA drops
    order, _, pos = _group_sorted_positions(grp, ef, E)
    keep = jnp.zeros((N,), bool).at[order].set(pos < C_grp)
    wf = jnp.where(keep, wf, 0.0)

    # group-major lane ranks: lane r holds expert members.flatten()[r]
    lane_of_rank, rank_of_expert, fuse = group_lane_map(members, g)
    y, _, plan = OPS.moe_ffn_fused(
        x, tok, rank_of_expert[ef], wf, params["experts"], E, T,
        expert_of_lane=lane_of_rank, bn=_block_rows(e), fuse=fuse)
    y = y.astype(x.dtype) + _shared_out(params, x)
    aux = {
        "counts": jnp.bincount(ef, length=E),
        "balance_loss": R.load_balance_loss(r.scores, r.expert_idx, E),
        "dropped": (~keep).sum(),
        "slots": G * C_grp,
    }
    return y, aux


def _members_matrix(group_of_expert: jax.Array, G: int, g: int) -> jax.Array:
    """[E] group ids -> [G, g] expert ids per group (host-traceable)."""
    E = group_of_expert.shape[0]
    order = jnp.argsort(group_of_expert * E + jnp.arange(E), stable=True)
    return order.reshape(G, g).astype(jnp.int32)


# ------------------------------------------------------------- expert choice

def expert_choice_forward(params: dict, x: jax.Array, e: MoEConfig,
                          valid_len=None) -> tuple:
    """Expert-choice prefill/train: each expert gathers its top-C tokens.
    Returns (y, aux) where aux also carries what the GO cache needs.
    `valid_len` masks right-padded (bucketed-prefill) positions out of the
    routing, so pads never enter the GO cache."""
    if resolve_backend(e, (x, params)) == "pallas":
        return _expert_choice_forward_pallas(params, x, e, valid_len)
    T = x.shape[0]
    cap = ec_capacity(T, e)
    r = R.expert_choice(x, params["gate"], cap, valid_len=valid_len)
    x_disp = x[r.token_idx]                               # [E, C, d] (gather)
    y_disp = _expert_gemm(params["experts"], x_disp)      # [E, C, d]
    w = r.weights                                         # [E, C]
    contrib = y_disp.astype(jnp.float32) * w[..., None]
    out = jnp.zeros((T, x.shape[-1]), jnp.float32)
    out = out.at[r.token_idx.reshape(-1)].add(contrib.reshape(-1, x.shape[-1]))
    y = out.astype(x.dtype) + _shared_out(params, x)
    aux = {
        "counts": jnp.bincount(r.token_idx.reshape(-1), length=T),
        "chosen_tokens": r.token_idx,
        "chosen_scores": w,
        "weighted_outputs": contrib.astype(x.dtype),      # [E, C, d]
        "scores": r.scores,
    }
    return y, aux


def _expert_choice_forward_pallas(params: dict, x: jax.Array,
                                  e: MoEConfig, valid_len=None) -> tuple:
    """Expert-choice through the grouped GEMM: (expert, slot) pairs are
    already expert-contiguous, so the tile plan is the identity layout and
    every expert's top-C tokens stream through the lane in one run."""
    T, d = x.shape
    cap = ec_capacity(T, e)
    E = e.num_experts
    r = R.expert_choice(x, params["gate"], cap, valid_len=valid_len)
    ef = jnp.repeat(jnp.arange(E, dtype=jnp.int32), cap)
    tok = r.token_idx.reshape(-1).astype(jnp.int32)
    wf = r.weights.reshape(-1)
    y, y_rows, plan = OPS.moe_ffn_fused(x, tok, ef, wf, params["experts"],
                                        E, T, bn=_block_rows(e))
    contrib = OPS.gather_rows(y_rows, plan).reshape(E, cap, d)   # fp32
    y_out = y.astype(x.dtype) + _shared_out(params, x)
    aux = {
        "counts": jnp.bincount(tok, length=T),
        "chosen_tokens": r.token_idx,
        "chosen_scores": r.weights,
        "weighted_outputs": contrib.astype(x.dtype),             # [E, C, d]
        "scores": r.scores,
    }
    return y_out, aux


def expert_choice_forward_batched(params: dict, h: jax.Array,
                                  e: MoEConfig, valid_len=None) -> tuple:
    """Batched expert-choice on the pallas backend: routing stays PER
    SEQUENCE (the GO-cache / train==serve semantics), but the FFN pairs of
    the whole batch flatten into ONE tile plan so the grouped GEMM amortizes
    its per-expert padding across the batch instead of paying it B times.
    h [B, S, d] -> (y [B, S, d], aux vmapped like the per-sequence path)."""
    B, S, d = h.shape
    cap = ec_capacity(S, e)
    E = e.num_experts
    r = jax.vmap(lambda xb: R.expert_choice(
        xb, params["gate"], cap, valid_len=valid_len))(h)
    ef = jnp.tile(jnp.repeat(jnp.arange(E, dtype=jnp.int32), cap), B)
    tok = (r.token_idx.astype(jnp.int32)
           + (jnp.arange(B, dtype=jnp.int32) * S)[:, None, None]).reshape(-1)
    wf = r.weights.reshape(-1)
    y, y_rows, plan = OPS.moe_ffn_fused(
        h.reshape(B * S, d), tok, ef, wf, params["experts"], E, B * S,
        bn=_block_rows(e))
    contrib = OPS.gather_rows(y_rows, plan).reshape(B, E, cap, d)
    y = y.reshape(B, S, d).astype(h.dtype) + jax.vmap(
        lambda xb: _shared_out(params, xb))(h)
    aux = {
        "counts": jax.vmap(lambda t: jnp.bincount(t.reshape(-1), length=S))(
            r.token_idx),
        "chosen_tokens": r.token_idx,
        "chosen_scores": r.weights,
        "weighted_outputs": contrib.astype(h.dtype),             # [B, E, C, d]
        "scores": r.scores,
    }
    return y, aux


# -------------------------------------------------------------------- decode

def token_choice_decode(params: dict, x: jax.Array, e: MoEConfig) -> jax.Array:
    """Decode step for token-choice: x [B, d] one token per sequence.
    Dropless: capacity bounds the worst case (every row picks the same expert),
    so serving never silently drops a token's expert contribution. (The pallas
    backend is dropless by construction.)"""
    y, _ = dispatch_forward(
        params, x, e, capacity=max(1, x.shape[0] * e.top_k))
    return y


def moe_forward(params: dict, x: jax.Array, e: MoEConfig,
                group_of_expert=None, group_members=None) -> tuple:
    """Router for the full-sequence paths; x [T, d]."""
    if e.routing == "expert_choice":
        return expert_choice_forward(params, x, e)
    if e.use_grouped_gemm and e.group_size > 1 and group_of_expert is not None:
        return group_forward(params, x, e, group_of_expert,
                             members=group_members)
    return dispatch_forward(params, x, e)


# --------------------------------------------------- expert-parallel (EP)

def moe_forward_ep(params: dict, h: jax.Array, e: MoEConfig) -> tuple:
    """True expert parallelism via shard_map over the model axis.

    Each model shard owns E/M experts ([E, ...] banks are EP-sharded by the
    rule-based sharder); the routing gate is replicated and each shard
    dispatches ONLY the (token, expert) pairs that hit its local experts, so
    dispatch buffers shrink by M and never cross the batch sharding. Partial
    outputs are combined with a psum — the EP analogue of the paper's
    shared-peripheral combine. The C2 load-aware permutation is applied to
    the expert index at deployment so each shard's aggregate load balances
    (straggler mitigation at the MoE layer).

    Both backends run INSIDE the shard body. backend="xla" packs a per-shard
    [E_loc, C, d] capacity buffer; backend="pallas" builds a PER-SHARD tile
    plan (plan_tile_dispatch with the shard's expert_offset/num_local window:
    non-local pairs ride a skipped drop lane) and streams the local pairs
    through the grouped GEMM. Capacity overflow is decided by ONE rule —
    position in the expert-stable sorted order, the same order _plan_dispatch
    evicts in — so both backends drop the SAME pairs (pallas realizes a drop
    as a zero combine weight, pinned by tests/test_moe_mesh.py).

    h [B, S, d] -> (y [B, S, d], aux). Token-choice only; requires
    E % model_axis == 0 (callers fall back to the vmapped path otherwise).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.models.layers import current_mesh, dp_spec

    mesh = current_mesh()
    M = mesh.shape["model"]
    E, k = e.num_experts, e.top_k
    E_loc = E // M
    B, S, d = h.shape
    dp = dp_spec()
    C = max(1, int(math.ceil(S * k / E * e.capacity_factor)))
    use_pallas = resolve_backend(e, (h, params)) == "pallas"
    bn = _block_rows(e)

    def body(h_loc, gate, wg, wi, wo):
        i = jax.lax.axis_index("model")
        lo = i * E_loc

        def per_seq(xb):
            r = R.token_choice(xb, gate, k)
            ef = r.expert_idx.reshape(-1).astype(jnp.int32)
            wf = r.weights.reshape(-1)
            tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
            local = (ef >= lo) & (ef < lo + E_loc)
            ef_l = jnp.where(local, ef - lo, E_loc)     # E_loc = drop bucket
            bal = R.load_balance_loss(r.scores, r.expert_idx, E)
            if use_pallas:
                # same per-shard capacity rule as the xla buffer below: the
                # planner's `pos` is the pair's rank within its lane's stable
                # run (derived from the plan's own sort — no second argsort);
                # evicted pairs keep their rows, lose their combine weight
                y, _, plan = OPS.moe_ffn_fused(
                    xb, tok, ef, wf, {"wg": wg, "wi": wi, "wo": wo}, E, S,
                    bn=bn, expert_offset=lo, num_local=E_loc, capacity=C,
                    replicate_under_mesh=False)   # shard_map body: local data
                cnt = plan.counts[:E_loc]
                dropped = (local & (plan.pos >= C)).sum()
            else:
                plan = _plan_dispatch(xb, ef_l, wf, tok, E_loc, C)
                hdn = jax.nn.silu(jnp.einsum(
                    "ecd,edf->ecf", plan.x_disp, wg)) * jnp.einsum(
                    "ecd,edf->ecf", plan.x_disp, wi)
                y_disp = jnp.einsum("ecf,efd->ecd", hdn, wo)
                y = _combine(y_disp, plan, S, jnp.float32)
                cnt = jnp.bincount(ef_l, length=E_loc + 1)[:E_loc]
                dropped = (local & (plan.dest == E_loc * C)).sum()
            return y, bal, cnt, dropped

        y, bal, cnt, dropped = jax.vmap(per_seq)(h_loc)
        y = jax.lax.psum(y, "model")
        cnt = jax.lax.psum(cnt.sum(0), dp) if dp else cnt.sum(0)
        dropped = jax.lax.psum(dropped.sum(), ("model",) + (dp or ()))
        bal = jax.lax.pmean(bal.mean(), dp) if dp else bal.mean()
        return (y, bal, cnt, dropped)

    bank = params["experts"]
    y, bal, cnt, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp, None, None), P(), P("model"), P()),
        check_rep=False,
    )(h, params["gate"], bank["wg"], bank["wi"], bank["wo"])

    y = y.astype(h.dtype) + jax.vmap(lambda xb: _shared_out(params, xb))(h)
    aux = {"counts": cnt, "balance_loss": bal, "dropped": dropped}
    return y, aux


def ep_available(e: MoEConfig) -> bool:
    """EP path usable: inside a mesh whose model axis divides E."""
    from repro.models.layers import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    M = mesh.shape["model"]
    return M > 1 and e.num_experts % M == 0
