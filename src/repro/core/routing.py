"""MoE routing: token-choice (eq. 1-3) and expert-choice, plus the paper's
incremental TopKUpdate (eq. 4-5) that powers the GO cache.

All functions are jit-safe (static shapes, no data-dependent control flow).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TokenChoiceRouting(NamedTuple):
    expert_idx: jax.Array     # [T, k] int32 chosen experts per token
    weights: jax.Array        # [T, k] fp32 combine weights (softmax over top-k)
    scores: jax.Array         # [T, E] fp32 raw gate scores (pre-softmax)


class ExpertChoiceRouting(NamedTuple):
    token_idx: jax.Array      # [E, C] int32 tokens chosen by each expert
    weights: jax.Array        # [E, C] fp32 combine weights G[t,e]
    scores: jax.Array         # [T, E] fp32 gate affinity matrix (softmax over E)


def gate_scores(x: jax.Array, w_gate: jax.Array) -> jax.Array:
    """x [T, d] -> raw scores [T, E] in fp32."""
    return (x.astype(jnp.float32) @ w_gate.astype(jnp.float32))


def token_choice(x: jax.Array, w_gate: jax.Array, k: int) -> TokenChoiceRouting:
    """Eq. (1)-(2): softmax(KeepTopK(x W_G, k)) — softmax over the k kept."""
    s = gate_scores(x, w_gate)                          # [T, E]
    top_s, top_i = jax.lax.top_k(s, k)                  # [T, k]
    w = jax.nn.softmax(top_s, axis=-1)
    return TokenChoiceRouting(top_i.astype(jnp.int32), w, s)


def expert_choice(x: jax.Array, w_gate: jax.Array, capacity: int,
                  valid_len=None) -> ExpertChoiceRouting:
    """Zhou et al. expert-choice: G = softmax over experts; each expert takes
    its top-`capacity` tokens by affinity.

    `valid_len` (traced int32 scalar) masks the affinities of positions
    >= valid_len to zero BEFORE the top-C selection — the bucketed-prefill
    hook: right-padded prompt slots can never win an expert slot (softmax
    affinities of real tokens are > 0, so any real token outranks a pad),
    and a pad chosen only because fewer than C real tokens exist carries a
    zero combine weight."""
    s = gate_scores(x, w_gate)
    g = jax.nn.softmax(s, axis=-1)                      # [T, E] over experts
    if valid_len is not None:
        T = x.shape[0]
        g = g * (jnp.arange(T) < valid_len)[:, None]
    top_g, top_t = jax.lax.top_k(g.T, capacity)         # [E, C]
    return ExpertChoiceRouting(top_t.astype(jnp.int32), top_g, g)


class TopKUpdateResult(NamedTuple):
    new_scores: jax.Array     # [E, k] updated cached top-k scores
    new_token_ids: jax.Array  # [E, k] updated token ids per slot
    selected: jax.Array       # [E] bool: did expert select the incoming token
    slot: jax.Array           # [E] int32 slot replaced (valid where selected)


def topk_update(
    s_prev: jax.Array,        # [E, k] cached scores (fp32)
    tok_prev: jax.Array,      # [E, k] cached token ids
    s_new: jax.Array,         # [E] incoming token's affinity per expert
    new_token_id,             # scalar int32
) -> TopKUpdateResult:
    """Paper eq. (5): per expert, if the new score beats the current min of the
    cached top-k, it replaces that min slot; otherwise the cache is unchanged.
    O(E k) per decode step — no recompute over history."""
    slot = jnp.argmin(s_prev, axis=-1)                  # [E]
    cur_min = jnp.take_along_axis(s_prev, slot[:, None], axis=-1)[:, 0]
    selected = s_new >= cur_min
    onehot = jax.nn.one_hot(slot, s_prev.shape[1], dtype=bool)
    upd = selected[:, None] & onehot
    new_scores = jnp.where(upd, s_new[:, None], s_prev)
    new_tok = jnp.where(upd, jnp.asarray(new_token_id, tok_prev.dtype), tok_prev)
    return TopKUpdateResult(new_scores, new_tok, selected, slot.astype(jnp.int32))


def load_balance_loss(scores: jax.Array, expert_idx: jax.Array, num_experts: int):
    """Shazeer-style auxiliary loss (importance * load) for token-choice
    training; returns scalar fp32."""
    g = jax.nn.softmax(scores, axis=-1)                 # [T, E]
    importance = g.mean(axis=0)                         # fraction of prob mass
    onehot = jax.nn.one_hot(expert_idx, num_experts).sum(axis=1)  # [T, E]
    load = onehot.mean(axis=0) / max(1, expert_idx.shape[-1])
    return num_experts * jnp.sum(importance * load) * expert_idx.shape[-1]
