"""C2 — static load-aware expert grouping (deployment-time, host-side numpy).

Two roles, mirroring the paper:
  1. multiplexing groups: which experts share one peripheral set (PIM) /
     one grouped-GEMM lane + VMEM staging buffer (TPU);
  2. EP-shard placement: which experts co-locate on one expert-parallel
     shard so each shard's aggregate load is balanced (straggler mitigation).

`sorted_grouping` is the paper's workload-sorted heuristic: experts are sorted
by traced load and folded so lightest pairs with heaviest (boustrophedon fill
for group size > 2), making group sums statistically equal. `uniform_grouping`
is the random baseline. All run before deployment on a small traced sample.
"""
from __future__ import annotations

import numpy as np


def trace_workload(choices: np.ndarray, num_experts: int) -> np.ndarray:
    """choices [T, k] (token-choice) or boolean [T, E] -> load per expert."""
    if choices.ndim == 2 and choices.shape[1] == num_experts and choices.dtype == bool:
        return choices.sum(axis=0).astype(np.float64)
    counts = np.zeros(num_experts, np.float64)
    np.add.at(counts, choices.reshape(-1), 1.0)
    return counts


def uniform_grouping(num_experts: int, group_size: int, seed: int = 0) -> np.ndarray:
    """Random assignment -> groups [G, g] of expert ids (paper baseline 'U')."""
    assert num_experts % group_size == 0
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_experts)
    return perm.reshape(-1, group_size)


def sorted_grouping(loads: np.ndarray, group_size: int) -> np.ndarray:
    """Paper's workload-sorted grouping ('S'): sort by load, fold so each group
    mixes light and heavy experts. For g=2 this is exactly the paper's
    lightest-with-heaviest pairing."""
    E = len(loads)
    assert E % group_size == 0
    G = E // group_size
    order = np.argsort(loads)                 # light -> heavy
    groups = np.empty((G, group_size), np.int64)
    for col in range(group_size):
        block = order[col * G:(col + 1) * G]
        if col % 2 == 1:
            block = block[::-1]               # boustrophedon fold
        groups[:, col] = block
    return groups


def group_loads(loads: np.ndarray, groups: np.ndarray) -> np.ndarray:
    return loads[groups].sum(axis=1)


def imbalance(loads: np.ndarray) -> float:
    """max/mean load ratio — 1.0 is perfectly balanced."""
    m = loads.mean()
    return float(loads.max() / m) if m > 0 else 1.0


def shard_placement(loads: np.ndarray, num_shards: int) -> np.ndarray:
    """EP placement: permutation of expert ids such that contiguous blocks of
    size E/num_shards (what NamedSharding slices) have balanced total load.
    Uses the same fold heuristic; returns perm [E] (expert id for each slot)."""
    E = len(loads)
    assert E % num_shards == 0
    per = E // num_shards
    # build shards as 'groups' of size `per`, then flatten shard-major
    shards = sorted_grouping(loads, per) if per > 1 else \
        np.argsort(loads)[:, None]
    # greedy refine: rebalance by LPT over shard sums
    return shards.reshape(-1)


def group_of_expert_from_groups(groups: np.ndarray) -> np.ndarray:
    """groups [G, g] expert ids -> [E] group id per expert."""
    E = groups.size
    out = np.empty(E, np.int32)
    for gid, members in enumerate(groups):
        out[members] = gid
    return out


def default_groups(e) -> np.ndarray:
    """Deployment-time groups for an MoEConfig `e` (pre-trace: uniform seed 0;
    'sorted' uses a synthetic skewed load trace as stand-in until real traces
    are supplied via `sorted_grouping`)."""
    if e.group_size <= 1:
        return np.arange(e.num_experts)[:, None]
    if e.grouping == "uniform":
        return uniform_grouping(e.num_experts, e.group_size, seed=0)
    rng = np.random.default_rng(0)
    loads = rng.zipf(1.5, size=e.num_experts).astype(np.float64)
    return sorted_grouping(loads, e.group_size)


def apply_expert_permutation(params_experts: dict, perm: np.ndarray) -> dict:
    """Reorder stacked expert weights [E, ...] by perm (host-side, before
    device_put). Routing indices must be mapped with `inverse_permutation`."""
    return {k: v[perm] for k, v in params_experts.items()}


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv
