"""C3 — prefill-stage dynamic scheduling (paper §III.D, Fig. 2, Algorithm 1).

Host-side (numpy) scheduler. With crossbar-level multiplexing, each expert
GROUP owns one shared peripheral set, so a group processes at most one
(token, expert) pair per cycle. A token whose data is already latched at the
group's peripheral (same token in the previous cycle of the same group), or
which is broadcast to another group in the same cycle, needs no new transfer.

Three schedules, matching the paper's notation:
  token_wise   — baseline: tokens strictly one by one, groups idle whenever the
                 current token does not activate them.
  compact  (C) — each group independently processes its own token queue
                 back-to-back; makespan = max group load.
  reschedule (O) — Algorithm 1: insert idle slots into the slack (`res`) of
                 shorter groups so token occurrences align into reuse runs /
                 shared broadcasts, without extending the makespan.

The TPU-runtime analogue of this scheduler is dispatch locality (tokens sorted
by (group, expert) so each weight tile is staged into VMEM once); see
core/moe.py and kernels/moe_gmm.py. Here we keep the paper's exact semantics
for the simulator and the reproduction benchmarks.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

IDLE = -1


class Schedule(NamedTuple):
    timeline: np.ndarray      # [G, T_sched] int64 token id per (group, cycle), IDLE for none
    makespan: int
    transfers: int


def choices_to_group_queues(choices: np.ndarray, groups: np.ndarray):
    """choices [T, E] bool; groups [G, g] expert ids ->
    per-group ordered queue of token occurrences (token-major order, one entry
    per (token, expert-in-group) hit — multi-hits are adjacent => reuse)."""
    queues = []
    for members in groups:
        hits = choices[:, members]                     # [T, g]
        q = []
        for t in range(choices.shape[0]):
            q.extend([t] * int(hits[t].sum()))
        queues.append(q)
    return queues


def count_transfers(timeline: np.ndarray) -> int:
    """A (group, cycle) slot needs a transfer iff its token differs from the
    same group's previous cycle AND no other group already transfers that
    token in this cycle (shared broadcast bus)."""
    G, T = timeline.shape
    transfers = 0
    for c in range(T):
        needed = set()
        for i in range(G):
            tok = timeline[i, c]
            if tok == IDLE:
                continue
            if c > 0 and timeline[i, c - 1] == tok:
                continue                                # latched at peripheral
            needed.add(tok)
        transfers += len(needed)
    return transfers


def _to_timeline(queues, length=None) -> np.ndarray:
    L = length or max((len(q) for q in queues), default=0)
    tl = np.full((len(queues), L), IDLE, np.int64)
    for i, q in enumerate(queues):
        tl[i, :len(q)] = q
    return tl


def token_wise_schedule(choices: np.ndarray, groups: np.ndarray) -> Schedule:
    """Baseline: global token order; all groups synchronize on each token.
    A token occupies max(hits over groups) cycles; groups with fewer hits idle."""
    T = choices.shape[0]
    cols = [[] for _ in groups]
    for t in range(T):
        hits = [int(choices[t, members].sum()) for members in groups]
        span = max(hits + [0])
        for i, h in enumerate(hits):
            cols[i].extend([t] * h + [IDLE] * (span - h))
    tl = _to_timeline(cols)
    return Schedule(tl, tl.shape[1], count_transfers(tl))


def compact_schedule(choices: np.ndarray, groups: np.ndarray) -> Schedule:
    """Paper 'C': dispatch multiple tokens simultaneously; each group drains
    its own queue with no idles."""
    queues = choices_to_group_queues(choices, groups)
    tl = _to_timeline(queues)
    return Schedule(tl, tl.shape[1], count_transfers(tl))


def reschedule_idle(choices: np.ndarray, groups: np.ndarray) -> Schedule:
    """Algorithm 1 — Reschedule by Inserting Idle.

    load[i,t] per group from the choices; the longest group (max_id) fixes the
    makespan; res[i,t] = csum[max_id,t] - csum[i,t] is group i's slack after
    token t. Each shorter group may defer its processing of token t by up to
    res[i,t] cycles: we align each token occurrence with the cycle where the
    longest group processes the SAME token (shared broadcast => data reuse)
    whenever that lands inside the slack window; otherwise schedule at the
    earliest free cycle. Idles fill the gaps. Makespan never exceeds L*.
    """
    T, _ = choices.shape
    G = len(groups)
    load = np.stack([choices[:, m].sum(axis=1) for m in groups])     # [G, T]
    csum = load.cumsum(axis=1)
    max_id = int(np.argmax(csum[:, -1]))
    L_star = int(csum[max_id, -1])

    # cycles at which the longest group processes each token occurrence
    ref_cycles = {}                              # token -> list of cycles
    c = 0
    for t in range(T):
        for _ in range(int(load[max_id, t])):
            ref_cycles.setdefault(t, []).append(c)
            c += 1

    timeline = np.full((G, L_star), IDLE, np.int64)
    timeline[max_id, :] = _to_timeline(
        choices_to_group_queues(choices, groups[max_id:max_id + 1]), L_star)[0]

    for i in range(G):
        if i == max_id:
            continue
        occ = []                                    # token-major occurrences
        for t in range(T):
            occ.extend([t] * int(load[i, t]))
        cursor = 0
        for j, t in enumerate(occ):
            # feasibility: occurrences j+1.. still need L-1-j cycles after c
            c_max = L_star - len(occ) + j
            aligned = [c for c in ref_cycles.get(t, ())
                       if cursor <= c <= c_max and timeline[i, c] == IDLE]
            if aligned:
                c = aligned[0]                      # defer into slack => reuse
            else:
                c = cursor                          # earliest free cycle
            timeline[i, c] = t
            cursor = c + 1
    tl = timeline
    resched = Schedule(tl, tl.shape[1], count_transfers(tl))
    # Idle insertion is only applied when it helps: aligning with the longest
    # group's broadcasts can occasionally break a within-group latch run, so
    # fall back to the compact timeline if it moved transfers the wrong way
    # (same makespan either way — matching the paper's stated property).
    comp = compact_schedule(choices, groups)
    if comp.transfers < resched.transfers:
        tl2 = _to_timeline(choices_to_group_queues(choices, groups), L_star)
        return Schedule(tl2, L_star, comp.transfers)
    return resched


SCHEDULES = {
    "token_wise": token_wise_schedule,
    "compact": compact_schedule,
    "reschedule": reschedule_idle,
}


def schedule_stats(choices: np.ndarray, groups: np.ndarray) -> dict:
    out = {}
    for name, fn in SCHEDULES.items():
        s = fn(choices, groups)
        out[name] = {"makespan": s.makespan, "transfers": s.transfers}
    return out
