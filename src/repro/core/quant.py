"""Quantized decode state: int8 pages + per-page scales (cfg.kv_quant).

The paged pool makes quantization natural — scale granularity IS page
granularity. KV pages store int8 values with one f32 amax scale per
(page, kv-head); GO rows (TopKUpdate history — not recomputable, so they
must round-trip through snapshots) store int8 with one f32 scale per
cached row. Everything here operates on raw arrays; layout/layer handling
belongs to the callers (models/model.py, serving/pool.py).

Write-side contract (the part determinism rests on):

  * splat (one-shot prefill -> write_decode_slot): each page quantizes
    against the amax of its OWN contents — pure function of the tokens.
  * incremental scatter (decode / chunked prefill): scales only ever GROW
    (scatter-max). When a new token raises a page's amax, the page's
    existing int8 values are re-quantized by the exact ratio old/new in
    f32 (`factor == 1.0` leaves them bit-identical through rint), so a
    page's contents depend only on the tokens written to it — never on
    page-reuse history. Freed pages MUST therefore return with zeroed
    scales (SlotPool.scrub_released), or a reused page would inherit an
    inflated amax and quantize differently than a fresh one.

Error model: with scale = amax / QMAX and no clipping (|x| <= amax by
construction), the round-trip error per element is bounded by scale / 2 =
amax / (2 * QMAX) — the bound the property tests assert per page per head.
Attention/MoE compute stays fp32: values are dequantized in-kernel
(kernels/paged_attn.py) or at the gather (models/attention.py), and GO
rows are dequantized to f32 at the layer boundary (f32, NOT the cfg
compute dtype: in f32 the dequant->requant cycle of an UNCHANGED row
recovers its int8 values exactly, so idle rows are bit-stable across
ticks).
"""
from __future__ import annotations

import jax.numpy as jnp

QMAX = 127.0                # int8 symmetric range; fp8 variants would
                            # swap this + the storage dtype via cfg.kv_quant

KV_QUANT_MODES = ("none", "int8")


def validate_kv_quant(kv_quant: str) -> None:
    if kv_quant not in KV_QUANT_MODES:
        raise ValueError(
            f"kv_quant={kv_quant!r} is not a known mode {KV_QUANT_MODES}")


def _safe(scales):
    """Divide-safe scales: all-zero pages (scale 0) quantize to 0."""
    return jnp.where(scales > 0, scales, 1.0)


def quantize_pages(pages):
    """Quantize float pages [..., ps, Hkv, hd] -> (int8 pages, f32 scales
    [..., Hkv]): one symmetric amax scale per (page, kv-head)."""
    x = pages.astype(jnp.float32)
    amax = jnp.abs(x).max(axis=(-3, -1))              # [..., Hkv]
    scales = amax / QMAX
    q = jnp.clip(jnp.rint(x / _safe(scales)[..., None, :, None]),
                 -QMAX, QMAX).astype(jnp.int8)
    return q, scales.astype(jnp.float32)


def dequantize_pages(q, scales):
    """int8 pages [..., ps, Hkv, hd] + scales [..., Hkv] -> f32 pages."""
    return q.astype(jnp.float32) * scales[..., None, :, None]


def quantize_rows(x):
    """Quantize float rows [..., d] -> (int8 rows, f32 scales [...]): one
    symmetric amax scale per row (the GO-cache layout)."""
    xf = x.astype(jnp.float32)
    scales = jnp.abs(xf).max(axis=-1) / QMAX
    q = jnp.clip(jnp.rint(xf / _safe(scales)[..., None]),
                 -QMAX, QMAX).astype(jnp.int8)
    return q, scales.astype(jnp.float32)


def dequantize_rows(q, scales):
    return q.astype(jnp.float32) * scales[..., None]


def scatter_token(cache, scales, page, off, val):
    """Decode-tick token write into int8 pages with rescale-on-write.

    cache  int8 [NP, ps, Hkv, hd]     scales f32 [NP, Hkv]
    page   int32 [B]   off int32 [B]  val float [B, Hkv, hd]

    The page's scale grows to cover the new token's amax (never shrinks);
    when it grows, the page's existing values are re-quantized by the f32
    ratio old/new — a ratio of exactly 1.0 is an int8 identity through
    rint, so untouched pages stay bit-stable. Duplicate page indices only
    occur on the null page 0 (masked rows), whose contents are trash by
    design and are never read.
    """
    val = val.astype(jnp.float32)
    amax_new = jnp.abs(val).max(axis=-1)              # [B, Hkv]
    old_s = scales[page]                              # [B, Hkv]
    scales = scales.at[page].max(amax_new / QMAX)
    new_s = scales[page]                              # post-update
    factor = jnp.where(new_s > 0, old_s / _safe(new_s), 1.0)
    repaged = jnp.rint(cache[page].astype(jnp.float32)
                       * factor[:, None, :, None]).astype(jnp.int8)
    cache = cache.at[page].set(repaged)
    q = jnp.clip(jnp.rint(val / _safe(new_s)[..., None]),
                 -QMAX, QMAX).astype(jnp.int8)
    cache = cache.at[page, off].set(q)
    return cache, scales


def scatter_chunk(cache, scales, pages, offs, vals):
    """Chunked-prefill scatter into int8 pages with rescale-on-write.

    cache  int8 [NP, ps, Hkv, hd]        scales f32 [NP, Hkv]
    pages  int32 [B, Cs]  offs [B, Cs]   vals float [B, Cs, Hkv, hd]

    Same contract as scatter_token. Several chunk positions may land on
    the SAME page: the scale update is a scatter-max (order-free), and the
    whole-page re-quantization writes IDENTICAL values for every duplicate
    index (old and new scales are read outside the scatter), so the
    duplicate scatter is deterministic.
    """
    vals = vals.astype(jnp.float32)
    tok_amax = jnp.abs(vals).max(axis=-1)             # [B, Cs, Hkv]
    old_s = scales[pages]                             # [B, Cs, Hkv]
    scales = scales.at[pages].max(tok_amax / QMAX)
    new_s = scales[pages]                             # final page scales
    factor = jnp.where(new_s > 0, old_s / _safe(new_s), 1.0)
    repaged = jnp.rint(cache[pages].astype(jnp.float32)
                       * factor[:, :, None, :, None]).astype(jnp.int8)
    cache = cache.at[pages].set(repaged)
    q = jnp.clip(jnp.rint(vals / _safe(new_s)[..., None]),
                 -QMAX, QMAX).astype(jnp.int8)
    cache = cache.at[pages, offs].set(q)
    return cache, scales


def kv_bytes_per_token(cfg, page_size: int) -> float:
    """Resident KV bytes per token across all layers: K + V values plus the
    per-page scales amortized over the page's tokens."""
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    if cfg.kv_quant == "int8":
        per_page = 2 * (page_size * hkv * hd * 1 + hkv * 4)
    else:
        per_page = 2 * page_size * hkv * hd * jnp.dtype(cfg.dtype).itemsize
    return cfg.num_layers * per_page / page_size
