"""C4 — the gate-output (GO) cache for expert-choice routing during
autoregressive generation (paper §III.C, eq. 4-5).

Problem: expert-choice routing lets each expert pick its top-k tokens over the
WHOLE sequence, so a naive decoder must re-run the gate (and potentially the
experts) over all retained hidden states at every step. The GO cache stores:

  scores    [B, E, k]      cached top-k gate affinities per expert (S_prev)
  token_ids [B, E, k]      which absolute token each slot holds
  outputs   [B, E, k, d]   cached weighted expert outputs G[t,e] * E_e(x_t)
                           (static size — does NOT grow with sequence length)

Each decode step processes ONLY the incoming token: one gate row, a
TopKUpdate against the cached mins, and expert FFNs only for the experts that
actually selected the token (at most one slot changes per expert per step).
The cache lives in HBM next to the KV cache and is sharded the same way.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.routing import topk_update


class GOCache(NamedTuple):
    scores: jax.Array       # [B, E, k] fp32
    token_ids: jax.Array    # [B, E, k] int32
    outputs: jax.Array      # [B, E, k, d]  (cfg dtype)


def go_cache_init(batch: int, num_experts: int, k: int, d: int, dtype) -> GOCache:
    return GOCache(
        scores=jnp.full((batch, num_experts, k), -jnp.inf, jnp.float32),
        token_ids=jnp.full((batch, num_experts, k), -1, jnp.int32),
        outputs=jnp.zeros((batch, num_experts, k, d), dtype),
    )


def go_cache_init_slot(cache: GOCache, slot) -> GOCache:
    """Reset ONE batch slot to the empty-cache state (scores -inf, ids -1,
    outputs 0). `slot` may be a traced int32. Leading axes before the batch
    dim (e.g. a stacked layer axis) are handled by the caller via vmap;
    here the batch dim is axis 0."""
    return GOCache(
        scores=cache.scores.at[slot].set(-jnp.inf),
        token_ids=cache.token_ids.at[slot].set(-1),
        outputs=cache.outputs.at[slot].set(0),
    )


def go_cache_write_slot(cache: GOCache, slot, src: GOCache) -> GOCache:
    """Write a batch-1 cache (e.g. from a single-request prefill) into batch
    slot `slot` of a pooled cache. Batch dim is axis 0 on both sides."""
    return GOCache(
        scores=cache.scores.at[slot].set(src.scores[0]),
        token_ids=cache.token_ids.at[slot].set(src.token_ids[0]),
        outputs=cache.outputs.at[slot].set(src.outputs[0].astype(cache.outputs.dtype)),
    )


def go_cache_prefill(
    scores: jax.Array,       # [B, T, E] gate affinities (softmax over E)
    token_ids: jax.Array,    # [T] absolute positions
    expert_outputs: jax.Array,  # [B, E, C, d] weighted outputs for chosen tokens
    chosen_tokens: jax.Array,   # [B, E, C] token ids chosen per expert
    chosen_scores: jax.Array,   # [B, E, C] their affinities
    k: int,
) -> GOCache:
    """Build the cache from a prefill pass. C (expert-choice capacity) may
    exceed k (we keep each expert's k best) or fall short of it (short
    chunked-prefill chunks — the spare slots stay empty: -inf / -1 / 0)."""
    C = chosen_scores.shape[-1]
    if C < k:
        pad = [(0, 0)] * (chosen_scores.ndim - 1) + [(0, k - C)]
        chosen_scores = jnp.pad(chosen_scores, pad, constant_values=-jnp.inf)
        chosen_tokens = jnp.pad(chosen_tokens, pad, constant_values=-1)
        expert_outputs = jnp.pad(expert_outputs, pad + [(0, 0)])
    top_s, top_slot = jax.lax.top_k(chosen_scores, k)            # [B, E, k]
    tok = jnp.take_along_axis(chosen_tokens, top_slot, axis=-1)
    out = jnp.take_along_axis(
        expert_outputs, top_slot[..., None], axis=2)             # [B, E, k, d]
    del scores, token_ids
    return GOCache(top_s.astype(jnp.float32), tok.astype(jnp.int32), out)


def go_cache_merge(old: GOCache, new: GOCache) -> GOCache:
    """Merge two caches over the same [B, E] grid: per expert, keep the k
    best-scoring entries of the union. The chunked-prefill hook: each prompt
    chunk builds its own cache (capacity derives from the chunk length) and
    folds into the accumulated one, mirroring what TopKUpdate would do if
    the chunk's tokens arrived one by one. Pass the OLDER cache first —
    `top_k` keeps the earlier operand on ties, so merge order (and therefore
    the chunked stream) is deterministic."""
    k = old.scores.shape[-1]
    scores = jnp.concatenate([old.scores, new.scores], axis=-1)   # [B, E, 2k]
    top_s, idx = jax.lax.top_k(scores, k)
    tok = jnp.take_along_axis(
        jnp.concatenate([old.token_ids, new.token_ids], axis=-1), idx, axis=-1)
    out = jnp.take_along_axis(
        jnp.concatenate(
            [old.outputs, new.outputs.astype(old.outputs.dtype)], axis=2),
        idx[..., None], axis=2)
    return GOCache(top_s, tok, out)


class GOStepResult(NamedTuple):
    y: jax.Array            # [B, d] MoE output for the incoming token
    cache: GOCache
    selected: jax.Array     # [B, E] bool — which experts took the token
    flops_active: jax.Array # [B] number of expert FFNs actually needed


def go_cache_step(
    cache: GOCache,
    x_t: jax.Array,          # [B, d] incoming token hidden state
    token_id,                # int32 absolute position: scalar or [B] per-slot
    gate_w: jax.Array,       # [d, E]
    expert_fn=None,          # (x [B, d]) -> [B, E, d] all-expert outputs
    *,
    retain_outputs: bool = True,
    contrib_fn=None,         # (x, selected, g) -> [B, E, d] fp32 weighted
                             # contributions (zeros where unselected)
) -> GOStepResult:
    """One decode step under expert-choice routing with the GO cache.

    eq. (4): G(x) = softmax(TopKUpdate(S_prev, x W_G, k)) — realized as the
    per-expert cached-min comparison; the incoming token's combine weight is
    its softmax affinity, and only selecting experts contribute.

    Expert compute comes from ONE of two callables: `expert_fn` (dense
    fallback: all E expert FFNs, masked afterwards) or `contrib_fn` (the
    multiplexed grouped-GEMM path, kernels/ops.py:go_selected_ffn: sees the
    `selected` mask and streams ONLY the selected experts' tiles, returning
    the already-weighted contributions). Both are correct; `selected`
    carries the mask either way.
    """
    if (expert_fn is None) == (contrib_fn is None):
        raise ValueError("pass exactly one of expert_fn / contrib_fn")
    B, E, k = cache.scores.shape
    s_raw = x_t.astype(jnp.float32) @ gate_w.astype(jnp.float32)   # [B, E]
    g = jax.nn.softmax(s_raw, axis=-1)

    # Scalar token_id (static batch) broadcasts to the per-slot vector form
    # used by the continuous-batching engine (each slot at its own position).
    tid = jnp.broadcast_to(jnp.asarray(token_id, jnp.int32).reshape(-1), (B,))
    upd = jax.vmap(topk_update)(cache.scores, cache.token_ids, g, tid)
    selected = upd.selected                                        # [B, E]

    if contrib_fn is not None:
        # contract: contrib is ALREADY zero where unselected (the planner
        # elides unselected pairs), so no second masking pass is needed
        contrib = contrib_fn(x_t, selected, g)                     # [B, E, d]
        y = contrib.sum(axis=1)
    else:
        eo = expert_fn(x_t)                                        # [B, E, d]
        contrib = g[..., None] * eo.astype(jnp.float32)
        y = jnp.where(selected[..., None], contrib, 0.0).sum(axis=1)

    if retain_outputs:
        onehot = jax.nn.one_hot(upd.slot, k, dtype=bool)           # [B, E, k]
        write = selected[..., None] & onehot
        new_out = jnp.where(
            write[..., None], contrib[:, :, None, :].astype(cache.outputs.dtype),
            cache.outputs)
    else:
        new_out = cache.outputs

    new_cache = GOCache(upd.new_scores, upd.new_token_ids, new_out)
    return GOStepResult(
        y.astype(x_t.dtype), new_cache, selected,
        selected.sum(axis=-1).astype(jnp.int32))


def go_cache_bytes(batch: int, num_experts: int, k: int, d: int,
                   out_bytes: int = 2) -> int:
    """Static cache footprint (paper: 'k x #experts x d ... will not grow
    with token length'; score adds 32B/token-step in their DRAM layout)."""
    scores = batch * num_experts * k * 4
    toks = batch * num_experts * k * 4
    outs = batch * num_experts * k * d * out_bytes
    return scores + toks + outs


def naive_expert_choice_step_flops(seq_len: int, num_experts: int, capacity_frac: float,
                                   d: int, d_ff: int) -> int:
    """Cost of a decode step WITHOUT the GO cache: the gate + experts re-run
    over all retained hidden states (the inefficiency the paper removes)."""
    gate = seq_len * d * num_experts
    experts = int(seq_len * capacity_frac) * num_experts * 3 * d * d_ff
    return 2 * (gate + experts)


def go_step_flops(num_selected: int, d: int, d_ff: int, num_experts: int) -> int:
    """Cost WITH the GO cache: one gate row + selected experts only."""
    return 2 * (d * num_experts + num_selected * 3 * d * d_ff)
