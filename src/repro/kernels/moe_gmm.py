"""Grouped expert GEMM — the TPU realization of the paper's C1 crossbar-level
multiplexing.

PIM mapping: several expert crossbars share one peripheral (ADC) set; MoE
sparsity bounds contention. TPU mapping: all experts of a multiplexing group
stream their selected tokens through ONE execution lane; the shared
"peripheral" is the HBM->VMEM weight-staging buffer + MXU issue slot. Rows
(dispatched token slots) are sorted by expert and PADDED to row-tile
boundaries, so every (row-tile, k, f) grid step stages exactly one expert's
weight tile into VMEM — each expert tile is fetched once per column stripe,
never per token (the dispatch-locality analogue of Algorithm 1).

Kernels:
  gmm(x, w, tile_expert)            y[i] = x[i] @ w[e(i)]
  gmm_swiglu(x, wg, wi, tile_expert) h[i] = silu(x[i] @ wg[e(i)]) * (x[i] @ wi[e(i)])

Grid: (num_row_tiles, F/bf, K/bk); fp32 VMEM scratch accumulates over k.
Block shapes default to MXU-aligned (128, 512, 128). Validated on CPU with
interpret=True against kernels/ref.py; on TPU the same pallas_call lowers to
Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(te_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gmm_swiglu_kernel(te_ref, x_ref, wg_ref, wi_ref, o_ref,
                       accg_ref, acci_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        acci_ref[...] = jnp.zeros_like(acci_ref)

    accg_ref[...] += jnp.dot(x_ref[...], wg_ref[0],
                             preferred_element_type=jnp.float32)
    acci_ref[...] += jnp.dot(x_ref[...], wi_ref[0],
                             preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        h = jax.nn.silu(accg_ref[...]) * acci_ref[...]
        o_ref[...] = h.astype(o_ref.dtype)


def _blocks(N, K, F, bn, bk, bf):
    bn = min(bn, N)
    bk = min(bk, K)
    bf = min(bf, F)
    assert N % bn == 0 and K % bk == 0 and F % bf == 0, (N, K, F, bn, bk, bf)
    return bn, bk, bf


@functools.partial(jax.jit, static_argnames=("bn", "bk", "bf", "interpret"))
def gmm(x: jax.Array, w: jax.Array, tile_expert: jax.Array, *,
        bn: int = 128, bk: int = 512, bf: int = 128,
        interpret: bool = False) -> jax.Array:
    """x [N, K] (rows tile-aligned by expert), w [E, K, F],
    tile_expert [N//bn] int32 -> y [N, F]."""
    N, K = x.shape
    E, _, F = w.shape
    bn, bk, bf = _blocks(N, K, F, bn, bk, bf)
    ni, nk, nf = N // bn, K // bk, F // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te: (i, k)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_gmm_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, F), x.dtype),
        interpret=interpret,
    )(tile_expert.astype(jnp.int32), x, w)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "bf", "interpret"))
def gmm_swiglu(x: jax.Array, wg: jax.Array, wi: jax.Array,
               tile_expert: jax.Array, *, bn: int = 128, bk: int = 512,
               bf: int = 128, interpret: bool = False) -> jax.Array:
    """Fused per-expert SwiGLU up-projection: silu(x@wg[e]) * (x@wi[e]).
    One x-tile staging feeds BOTH weight streams (multiplexed operand reuse)."""
    N, K = x.shape
    E, _, F = wg.shape
    bn, bk, bf = _blocks(N, K, F, bn, bk, bf)
    ni, nk, nf = N // bn, K // bk, F // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te: (i, k)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te: (te[i], k, j)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32),
                        pltpu.VMEM((bn, bf), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_gmm_swiglu_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, F), x.dtype),
        interpret=interpret,
    )(tile_expert.astype(jnp.int32), x, wg, wi)
