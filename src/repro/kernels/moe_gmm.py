"""Grouped expert GEMM — the TPU realization of the paper's C1 crossbar-level
multiplexing.

PIM mapping: several expert crossbars share one peripheral (ADC) set; MoE
sparsity bounds contention. TPU mapping: all experts of a multiplexing group
stream their selected tokens through ONE execution lane; the shared
"peripheral" is the HBM->VMEM weight-staging buffer + MXU issue slot. Rows
(dispatched token slots) are sorted by expert and PADDED to row-tile
boundaries, so every (row-tile, k, f) grid step stages exactly one expert's
weight tile into VMEM — each expert tile is fetched once per column stripe,
never per token (the dispatch-locality analogue of Algorithm 1).

Kernels:
  gmm(x, w, tile_expert[, tile_valid])     y[i] = x[i] @ w[e(i)]
  gmm_scaled(..., row_scale)               y[i] = (x[i] @ w[e(i)]) * s[i]
                                           (fused combine: per-row weights
                                           applied in-kernel at the fp32
                                           accumulator, out_dtype=fp32)
  gmm_swiglu(x, wg, wi, tile_expert[, tile_valid])
                                           h[i] = silu(x[i] @ wg[e(i)])
                                                  * (x[i] @ wi[e(i)])

Grid: (num_row_tiles, F/bf, K/bk); fp32 VMEM scratch accumulates over k.
Block shapes default to MXU-aligned (128, 512, 128).

Alignment: non-tile-aligned shapes are zero-padded to block multiples — K and
F on both operands (dot products unchanged; extra output columns sliced off),
rows up to the row-tile boundary. `tile_valid` marks row tiles that carry at
least one real dispatched row: invalid tiles (alignment padding, empty expert
runs, the drop lane of the selected-decode path) SKIP the MXU work entirely
via `pl.when`, so the executed FLOPs track the planner's occupied tiles, not
the static worst-case shape. The planner emits constant weight indices across
invalid tail tiles, so the pipeline re-uses the staged VMEM buffer instead of
issuing fresh HBM copies for tiles it will not compute.

`interpret=None` auto-selects from the LOWERING context, not the host default:
inside a mesh (`with mesh:` — shard_map bodies, sharded jits) the kernel lowers
for the mesh's devices, which may differ from `jax.default_backend()` (a forced
CPU host mesh on a TPU host, or explicit device placement). The resolved value
is part of the jit cache key — the public entry points resolve it BEFORE the
jit boundary, so a process that lowers for both platforms (TPU eager + CPU
mesh tests) compiles both variants instead of replaying whichever traced
first. Validated against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mesh_lib():
    from jax._src import mesh as mesh_lib
    return mesh_lib


def lowering_platform() -> str:
    """The platform the next pallas_call actually lowers for: the active
    mesh's devices when inside a `with mesh:` context (shard_map / sharded
    jit tracing happens there), the host default backend otherwise."""
    m = _mesh_lib().thread_resources.env.physical_mesh
    if m is not None and not m.empty:
        return m.devices.flat[0].platform
    return jax.default_backend()


def default_interpret() -> bool:
    """Interpret unless we can actually lower via Mosaic (i.e. for TPU)."""
    return lowering_platform() != "tpu"


def replicate_for_gspmd(*arrays):
    """Pin arrays to a fully-replicated layout when tracing under a GSPMD
    mesh (`with mesh:` + jit).

    The grouped-GEMM pipeline — the planner's small scatter/gather chains
    feeding a pallas_call — has no SPMD partitioning rule; letting the
    partitioner guess per-op shardings for it is slow (collective chatter on
    [N]-sized index vectors) and, for the interpret-mode lowering, produces
    WRONG results on CPU host meshes (sharding-dependent gather/scatter
    miscompiles — caught by tests/test_moe_mesh.py). Pinning the branch's
    inputs replicated keeps every downstream op replicated, which matches
    the unsharded numerics exactly.

    Callers that run inside a shard_map body (manual mesh axes — the EP
    path, where data is already shard-local) must NOT call this: a sharding
    constraint has no meaning there (and jax rejects it under check_rep).
    The distinction is static at every call site, so it is the caller's
    switch (`moe_ffn_fused(replicate_under_mesh=...)`) rather than a
    runtime axis-env probe."""
    from jax.sharding import NamedSharding, PartitionSpec
    m = _mesh_lib().thread_resources.env.physical_mesh
    if m is not None and not m.empty:
        rep = NamedSharding(m, PartitionSpec())
        arrays = tuple(jax.lax.with_sharding_constraint(a, rep)
                       for a in arrays)
    return arrays if len(arrays) > 1 else arrays[0]


def _pad_to(a: jax.Array, axis: int, size: int) -> jax.Array:
    if a.shape[axis] == size:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, size - a.shape[axis])
    return jnp.pad(a, pads)


def _row_tiles(N: int, bn: int, tile_expert: jax.Array, tile_valid):
    """Validate the (tile_expert, tile_valid) map against ceil(N/bn) row
    tiles. The map must cover every row — a short map means it was built with
    a different bn and auto-extending it would silently zero real rows, so
    fail fast (the planner always emits tile-aligned buffers). A LONGER map
    is fine: the extra rows are zero-padded."""
    ni = -(-N // bn)
    if tile_expert.shape[0] < ni:
        raise ValueError(
            f"tile_expert covers {tile_expert.shape[0]} tiles but x has "
            f"{N} rows at bn={bn} ({ni} tiles) — tile map built with a "
            "different bn, or rows not padded to the tile boundary?")
    ni = tile_expert.shape[0]
    te = tile_expert.astype(jnp.int32)
    tv = (jnp.ones(te.shape, jnp.int32) if tile_valid is None
          else tile_valid.astype(jnp.int32))
    return ni, te, tv


def _gmm_kernel(te_ref, tv_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(tv_ref[i] != 0)
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gmm_scaled_kernel(te_ref, tv_ref, x_ref, w_ref, s_ref, o_ref, acc_ref,
                       *, nk: int):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(tv_ref[i] != 0)
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


# Fused-pair kernel variants: a STRADDLE tile of a fused lane pair carries
# rows of two experts (primary rows first — the planner guarantees at most
# one boundary per tile). `sel_ref` is the per-row primary mask; the primary
# dot masks rows to the primary run, and a second dot over the complement
# streams the secondary expert's weights (w2_ref, indexed by tile_expert2).
# Non-straddle tiles (te2 == te) skip the second dot and the row masking, so
# they cost exactly what the unfused kernels cost.

def _gmm_scaled_fused_kernel(te_ref, te2_ref, tv_ref, x_ref, w_ref, w2_ref,
                             sel_ref, s_ref, o_ref, acc_ref, *, nk: int):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    straddle = te2_ref[i] != te_ref[i]

    @pl.when(tv_ref[i] != 0)
    def _mac():
        x = x_ref[...]
        sel = sel_ref[...].astype(x.dtype)
        x1 = jnp.where(straddle, x * sel, x)
        acc_ref[...] += jnp.dot(x1, w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when((tv_ref[i] != 0) & straddle)
    def _mac2():
        x2 = x_ref[...] * (1.0 - sel_ref[...]).astype(x_ref.dtype)
        acc_ref[...] += jnp.dot(x2, w2_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def _gmm_swiglu_fused_kernel(te_ref, te2_ref, tv_ref, x_ref, wg_ref, wi_ref,
                             wg2_ref, wi2_ref, sel_ref, o_ref, accg_ref,
                             acci_ref, *, nk: int):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        acci_ref[...] = jnp.zeros_like(acci_ref)

    straddle = te2_ref[i] != te_ref[i]

    @pl.when(tv_ref[i] != 0)
    def _mac():
        x = x_ref[...]
        sel = sel_ref[...].astype(x.dtype)
        x1 = jnp.where(straddle, x * sel, x)
        accg_ref[...] += jnp.dot(x1, wg_ref[0],
                                 preferred_element_type=jnp.float32)
        acci_ref[...] += jnp.dot(x1, wi_ref[0],
                                 preferred_element_type=jnp.float32)

    @pl.when((tv_ref[i] != 0) & straddle)
    def _mac2():
        x2 = x_ref[...] * (1.0 - sel_ref[...]).astype(x_ref.dtype)
        accg_ref[...] += jnp.dot(x2, wg2_ref[0],
                                 preferred_element_type=jnp.float32)
        acci_ref[...] += jnp.dot(x2, wi2_ref[0],
                                 preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        h = jax.nn.silu(accg_ref[...]) * acci_ref[...]
        o_ref[...] = h.astype(o_ref.dtype)


def _gmm_swiglu_kernel(te_ref, tv_ref, x_ref, wg_ref, wi_ref, o_ref,
                       accg_ref, acci_ref, *, nk: int):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        acci_ref[...] = jnp.zeros_like(acci_ref)

    @pl.when(tv_ref[i] != 0)
    def _mac():
        accg_ref[...] += jnp.dot(x_ref[...], wg_ref[0],
                                 preferred_element_type=jnp.float32)
        acci_ref[...] += jnp.dot(x_ref[...], wi_ref[0],
                                 preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        h = jax.nn.silu(accg_ref[...]) * acci_ref[...]
        o_ref[...] = h.astype(o_ref.dtype)


def gmm(x: jax.Array, w: jax.Array, tile_expert: jax.Array,
        tile_valid: jax.Array | None = None, *, bn: int = 128, bk: int = 512,
        bf: int = 128, interpret: bool | None = None,
        out_dtype=None) -> jax.Array:
    """x [N, K] (rows tile-aligned by expert), w [E, K, F],
    tile_expert [n_tiles] int32, tile_valid [n_tiles] optional -> y [N, F]."""
    if interpret is None:
        interpret = default_interpret()
    return _gmm(x, w, tile_expert, tile_valid, bn=bn, bk=bk, bf=bf,
                interpret=interpret, out_dtype=out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "bf", "interpret", "out_dtype"))
def _gmm(x, w, tile_expert, tile_valid, *, bn, bk, bf, interpret, out_dtype):
    N, K = x.shape
    E, _, F = w.shape
    bk, bf = min(bk, K), min(bf, F)
    ni, te, tv = _row_tiles(N, bn, tile_expert, tile_valid)
    Kp, Fp = -(-K // bk) * bk, -(-F // bf) * bf
    xp = _pad_to(_pad_to(x, 0, ni * bn), 1, Kp)
    wp = _pad_to(_pad_to(w, 1, Kp), 2, Fp)
    nk, nf = Kp // bk, Fp // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te, tv: (i, k)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te, tv: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te, tv: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_gmm_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni * bn, Fp), out_dtype or x.dtype),
        interpret=interpret,
    )(te, tv, xp, wp)
    return y[:N, :F]


def gmm_scaled(x: jax.Array, w: jax.Array, tile_expert: jax.Array,
               tile_valid: jax.Array | None, row_scale: jax.Array, *,
               tile_expert2: jax.Array | None = None,
               row_sel: jax.Array | None = None,
               bn: int = 128, bk: int = 512, bf: int = 128,
               interpret: bool | None = None,
               out_dtype=jnp.float32) -> jax.Array:
    """Fused-combine grouped GEMM: y[i] = (x[i] @ w[e(i)]) * row_scale[i].

    The per-row combine weight is applied against the fp32 accumulator in the
    kernel's epilogue, so the caller can scatter-add the rows straight into the
    token buffer — no separate gather + fp32 multiply pass. row_scale [N, 1].

    With `tile_expert2`/`row_sel` (fused lane pairs), a straddle tile's rows
    split between two experts: rows where row_sel==1 hit tile_expert's
    weights, the complement hits tile_expert2's."""
    if interpret is None:
        interpret = default_interpret()
    if tile_expert2 is None:
        return _gmm_scaled(x, w, tile_expert, tile_valid, row_scale, bn=bn,
                           bk=bk, bf=bf, interpret=interpret,
                           out_dtype=out_dtype)
    return _gmm_scaled_fused(x, w, tile_expert, tile_expert2, tile_valid,
                             row_scale, row_sel, bn=bn, bk=bk, bf=bf,
                             interpret=interpret, out_dtype=out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "bf", "interpret", "out_dtype"))
def _gmm_scaled(x, w, tile_expert, tile_valid, row_scale, *, bn, bk, bf,
                interpret, out_dtype):
    N, K = x.shape
    E, _, F = w.shape
    bk, bf = min(bk, K), min(bf, F)
    ni, te, tv = _row_tiles(N, bn, tile_expert, tile_valid)
    Kp, Fp = -(-K // bk) * bk, -(-F // bf) * bf
    xp = _pad_to(_pad_to(x, 0, ni * bn), 1, Kp)
    wp = _pad_to(_pad_to(w, 1, Kp), 2, Fp)
    sp = _pad_to(row_scale.astype(jnp.float32), 0, ni * bn)
    nk, nf = Kp // bk, Fp // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te, tv: (i, k)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te, tv: (te[i], k, j)),
            pl.BlockSpec((bn, 1), lambda i, j, k, te, tv: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te, tv: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_gmm_scaled_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni * bn, Fp), out_dtype),
        interpret=interpret,
    )(te, tv, xp, wp, sp)
    return y[:N, :F]


def gmm_swiglu(x: jax.Array, wg: jax.Array, wi: jax.Array,
               tile_expert: jax.Array, tile_valid: jax.Array | None = None, *,
               tile_expert2: jax.Array | None = None,
               row_sel: jax.Array | None = None,
               bn: int = 128, bk: int = 512, bf: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Fused per-expert SwiGLU up-projection: silu(x@wg[e]) * (x@wi[e]).
    One x-tile staging feeds BOTH weight streams (multiplexed operand reuse).
    `tile_expert2`/`row_sel` resolve fused-pair straddle tiles per row."""
    if interpret is None:
        interpret = default_interpret()
    if tile_expert2 is None:
        return _gmm_swiglu(x, wg, wi, tile_expert, tile_valid, bn=bn, bk=bk,
                           bf=bf, interpret=interpret)
    return _gmm_swiglu_fused(x, wg, wi, tile_expert, tile_expert2, tile_valid,
                             row_sel, bn=bn, bk=bk, bf=bf, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "bf", "interpret", "out_dtype"))
def _gmm_scaled_fused(x, w, tile_expert, tile_expert2, tile_valid, row_scale,
                      row_sel, *, bn, bk, bf, interpret, out_dtype):
    N, K = x.shape
    E, _, F = w.shape
    bk, bf = min(bk, K), min(bf, F)
    ni, te, tv = _row_tiles(N, bn, tile_expert, tile_valid)
    te2 = tile_expert2.astype(jnp.int32)
    Kp, Fp = -(-K // bk) * bk, -(-F // bf) * bf
    xp = _pad_to(_pad_to(x, 0, ni * bn), 1, Kp)
    wp = _pad_to(_pad_to(w, 1, Kp), 2, Fp)
    sp = _pad_to(row_scale.astype(jnp.float32), 0, ni * bn)
    selp = _pad_to(row_sel.astype(jnp.float32), 0, ni * bn)
    nk, nf = Kp // bk, Fp // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te, te2, tv: (i, k)),
            pl.BlockSpec((1, bk, bf),
                         lambda i, j, k, te, te2, tv: (te[i], k, j)),
            pl.BlockSpec((1, bk, bf),
                         lambda i, j, k, te, te2, tv: (te2[i], k, j)),
            pl.BlockSpec((bn, 1), lambda i, j, k, te, te2, tv: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k, te, te2, tv: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te, te2, tv: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_gmm_scaled_fused_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni * bn, Fp), out_dtype),
        interpret=interpret,
    )(te, te2, tv, xp, wp, wp, selp, sp)
    return y[:N, :F]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "bf", "interpret"))
def _gmm_swiglu_fused(x, wg, wi, tile_expert, tile_expert2, tile_valid,
                      row_sel, *, bn, bk, bf, interpret):
    N, K = x.shape
    E, _, F = wg.shape
    bk, bf = min(bk, K), min(bf, F)
    ni, te, tv = _row_tiles(N, bn, tile_expert, tile_valid)
    te2 = tile_expert2.astype(jnp.int32)
    Kp, Fp = -(-K // bk) * bk, -(-F // bf) * bf
    xp = _pad_to(_pad_to(x, 0, ni * bn), 1, Kp)
    wgp = _pad_to(_pad_to(wg, 1, Kp), 2, Fp)
    wip = _pad_to(_pad_to(wi, 1, Kp), 2, Fp)
    selp = _pad_to(row_sel.astype(jnp.float32), 0, ni * bn)
    nk, nf = Kp // bk, Fp // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te, te2, tv: (i, k)),
            pl.BlockSpec((1, bk, bf),
                         lambda i, j, k, te, te2, tv: (te[i], k, j)),
            pl.BlockSpec((1, bk, bf),
                         lambda i, j, k, te, te2, tv: (te[i], k, j)),
            pl.BlockSpec((1, bk, bf),
                         lambda i, j, k, te, te2, tv: (te2[i], k, j)),
            pl.BlockSpec((1, bk, bf),
                         lambda i, j, k, te, te2, tv: (te2[i], k, j)),
            pl.BlockSpec((bn, 1), lambda i, j, k, te, te2, tv: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te, te2, tv: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32),
                        pltpu.VMEM((bn, bf), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_gmm_swiglu_fused_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni * bn, Fp), x.dtype),
        interpret=interpret,
    )(te, te2, tv, xp, wgp, wip, wgp, wip, selp)
    return y[:N, :F]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "bf", "interpret"))
def _gmm_swiglu(x, wg, wi, tile_expert, tile_valid, *, bn, bk, bf, interpret):
    N, K = x.shape
    E, _, F = wg.shape
    bk, bf = min(bk, K), min(bf, F)
    ni, te, tv = _row_tiles(N, bn, tile_expert, tile_valid)
    Kp, Fp = -(-K // bk) * bk, -(-F // bf) * bf
    xp = _pad_to(_pad_to(x, 0, ni * bn), 1, Kp)
    wgp = _pad_to(_pad_to(wg, 1, Kp), 2, Fp)
    wip = _pad_to(_pad_to(wi, 1, Kp), 2, Fp)
    nk, nf = Kp // bk, Fp // bf

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ni, nf, nk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k, te, tv: (i, k)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te, tv: (te[i], k, j)),
            pl.BlockSpec((1, bk, bf), lambda i, j, k, te, tv: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j, k, te, tv: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32),
                        pltpu.VMEM((bn, bf), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_gmm_swiglu_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni * bn, Fp), x.dtype),
        interpret=interpret,
    )(te, tv, xp, wgp, wip)
    return y[:N, :F]
