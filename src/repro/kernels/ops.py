"""jit'd wrappers around the Pallas kernels + the tile-aligned dispatch planner
that connects them to the MoE layer.

`plan_tile_dispatch` realizes the paper's scheduling insight in TPU terms:
tokens are sorted by (group, expert) and each expert's run is padded to the
row-tile boundary, so the grouped GEMM stages every expert weight tile into
VMEM exactly once per column stripe (Algorithm 1's "no repeated transfers"),
and idle slots become zero rows aligned to the MXU tile.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm import gmm, gmm_swiglu


class TilePlan(NamedTuple):
    dest: jax.Array           # [N] row slot per (token, expert) pair; N_pad = dropped
    tile_expert: jax.Array    # [n_tiles] expert id per row tile
    row_valid: jax.Array      # [N_pad] bool — real row vs alignment padding
    counts: jax.Array         # [E] pairs per expert (pre-capacity)
    n_pad: int                # static padded row count


def padded_rows(num_pairs: int, num_experts: int, bn: int) -> int:
    """Static worst-case padded row count (every expert run padded up)."""
    return num_pairs + num_experts * bn


def plan_tile_dispatch(expert_flat: jax.Array, num_experts: int,
                       bn: int) -> TilePlan:
    """expert_flat [N] int32 (one entry per (token, expert) pair) ->
    tile-aligned layout. All shapes static; pure jnp (jit/pjit-safe)."""
    N = expert_flat.shape[0]
    E = num_experts
    n_pad = padded_rows(N, E, bn)

    counts = jnp.bincount(expert_flat, length=E)                  # [E]
    padded = ((counts + bn - 1) // bn) * bn                       # per-expert
    offsets = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(padded)[:-1]])  # [E]

    order = jnp.argsort(expert_flat, stable=True)
    se = expert_flat[order]
    pos = jnp.arange(N, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left").astype(jnp.int32)
    dest_sorted = offsets[se].astype(jnp.int32) + pos
    inv = jnp.argsort(order, stable=True)
    dest = dest_sorted[inv]

    # expert id per row tile: tile t covers rows [t*bn, (t+1)*bn) — constant
    # expert by construction. Padding tiles (beyond an expert's run) map to
    # expert of that stripe; fully-unused tail tiles map to expert 0 (zero rows
    # in, output discarded via row_valid).
    n_tiles = n_pad // bn
    tile_start = jnp.arange(n_tiles, dtype=jnp.int32) * bn
    ends = jnp.cumsum(padded)
    tile_expert = jnp.searchsorted(ends, tile_start, side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, E - 1)

    row_idx = jnp.arange(n_pad, dtype=jnp.int32)
    row_expert = jnp.searchsorted(ends, row_idx, side="right")
    row_expert = jnp.minimum(row_expert, E - 1)
    row_valid = row_idx < (offsets[row_expert] + counts[row_expert])

    return TilePlan(dest, tile_expert, row_valid, counts, n_pad)


def scatter_rows(x_pairs: jax.Array, plan: TilePlan) -> jax.Array:
    """x_pairs [N, d] -> tile-aligned rows [n_pad, d] (zeros in padding)."""
    buf = jnp.zeros((plan.n_pad, x_pairs.shape[-1]), x_pairs.dtype)
    return buf.at[plan.dest].set(x_pairs, mode="drop")


def gather_rows(y_rows: jax.Array, plan: TilePlan) -> jax.Array:
    """Tile-aligned rows back to pair order [N, d]."""
    return y_rows[plan.dest]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def expert_ffn_gmm(x_rows: jax.Array, wg: jax.Array, wi: jax.Array,
                   wo: jax.Array, tile_expert: jax.Array, *, bn: int = 128,
                   interpret: bool = True) -> jax.Array:
    """Tile-aligned rows [N_pad, d] through per-expert SwiGLU FFNs.
    interpret=True on CPU; on TPU pass interpret=False to lower via Mosaic."""
    h = gmm_swiglu(x_rows, wg, wi, tile_expert, bn=bn, interpret=interpret)
    return gmm(h, wo, tile_expert, bn=bn, interpret=interpret)


def moe_ffn_pallas(x: jax.Array, expert_idx: jax.Array, weights: jax.Array,
                   bank: dict, num_experts: int, *, bn: int = 128,
                   interpret: bool = True) -> jax.Array:
    """Full MoE FFN through the Pallas path.

    x [T, d]; expert_idx [T, k]; weights [T, k] -> y [T, d].
    Zero-redundancy counterpart of core.moe.group_forward's XLA fallback: no
    masked duplicate member passes, no capacity drops (worst-case padding)."""
    T, d = x.shape
    k = expert_idx.shape[1]
    ef = expert_idx.reshape(-1).astype(jnp.int32)
    wf = weights.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    plan = plan_tile_dispatch(ef, num_experts, bn)
    x_rows = scatter_rows(x[tok], plan)
    y_rows = expert_ffn_gmm(x_rows, bank["wg"], bank["wi"], bank["wo"],
                            plan.tile_expert, bn=bn, interpret=interpret)
    y_pairs = gather_rows(y_rows, plan).astype(jnp.float32) * wf[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(y_pairs)
    return out.astype(x.dtype)
