"""jit'd wrappers around the Pallas kernels + the tile-aligned dispatch planner
that connects them to the MoE layer.

`plan_tile_dispatch` realizes the paper's scheduling insight in TPU terms:
tokens are sorted by (group, expert) and each expert's run is padded to the
row-tile boundary, so the grouped GEMM stages every expert weight tile into
VMEM exactly once per column stripe (Algorithm 1's "no repeated transfers"),
and idle slots become zero rows aligned to the MXU tile. The plan also marks
which row tiles actually carry data (`tile_valid`) so the kernels skip the
MXU work for pure-padding tiles — executed FLOPs track the real token count,
not the static worst-case buffer.

Production entry points (what core/moe.py's `backend="pallas"` routes to):

  moe_ffn_fused       (token, expert) pairs -> combined [T, d] output with
                      the per-pair combine weights applied IN-KERNEL
                      (gmm_scaled) and rows scatter-added straight into the
                      token buffer — no gather + fp32 multiply pass.
  go_selected_ffn     C4 decode: flattens the GO cache's [B, E] `selected`
                      mask into (token, expert) pairs, plans ONLY the
                      selected pairs (unselected pairs ride in a skipped
                      drop lane), and runs one grouped GEMM over ~B*k rows
                      instead of B*E dense FFNs.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm import (default_interpret, gmm, gmm_scaled,
                                   gmm_swiglu, lowering_platform)


def default_block_rows() -> int:
    """Row-tile height: MXU-aligned when lowering for TPU; small otherwise so
    the interpreted correctness path does not drown in padding tiles."""
    return 128 if lowering_platform() == "tpu" else 8


class TilePlan(NamedTuple):
    dest: jax.Array           # [N] row slot per (token, expert) pair
    tile_expert: jax.Array    # [n_tiles] expert id per row tile
    tile_valid: jax.Array     # [n_tiles] bool — tile carries >=1 real row
    row_valid: jax.Array      # [N_pad] bool — real row vs alignment padding
    counts: jax.Array         # [lanes] pairs per lane (pre-capacity)
    pos: jax.Array            # [N] pair's position within its lane's stable
                              # run (dest - lane offset; no extra sort) — the
                              # capacity-eviction rank shared with the xla
                              # dispatch buffer
    n_pad: int                # static padded row count


def padded_rows(num_pairs: int, num_experts: int, bn: int) -> int:
    """Static worst-case padded row count (every expert run padded up),
    rounded to the tile boundary so the row buffer is always whole tiles."""
    worst = num_pairs + num_experts * bn
    return -(-worst // bn) * bn


def plan_tile_dispatch(expert_flat: jax.Array, num_experts: int, bn: int, *,
                       expert_offset: jax.Array | int = 0,
                       num_local: int = 0) -> TilePlan:
    """expert_flat [N] int32 (one entry per (token, expert) pair) ->
    tile-aligned layout. All shapes static; pure jnp (jit/pjit-safe).

    With `num_local > 0` the plan covers ONLY the local expert window
    [expert_offset, expert_offset + num_local): pairs outside it ride a
    trailing DROP lane whose tiles are planned (static shapes) but marked
    invalid, so the kernel skips their MXU work. `tile_expert` then indexes
    the LOCAL weight bank [0, num_local) — this is what lets every EP shard
    of a `shard_map` body plan tiles for its own expert slice (the offset may
    be a traced `axis_index`; `num_local` is static so shapes agree across
    shards). `counts` covers the planned lanes (num_local + 1, drop last).
    """
    if num_local:
        local_idx = expert_flat - expert_offset
        local = (local_idx >= 0) & (local_idx < num_local)
        expert_flat = jnp.where(local, local_idx, num_local).astype(jnp.int32)
        E = num_local + 1                      # lane num_local = drop lane
    else:
        E = num_experts
    N = expert_flat.shape[0]
    n_pad = padded_rows(N, E, bn)

    counts = jnp.bincount(expert_flat, length=E)                  # [E]
    padded = ((counts + bn - 1) // bn) * bn                       # per-expert
    offsets = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(padded)[:-1]])  # [E]

    order = jnp.argsort(expert_flat, stable=True)
    se = expert_flat[order]
    pos = jnp.arange(N, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left").astype(jnp.int32)
    dest_sorted = offsets[se].astype(jnp.int32) + pos
    # O(N) scatter inversion of the sort permutation (was a second argsort)
    dest = jnp.zeros((N,), jnp.int32).at[order].set(dest_sorted)

    # expert id per row tile: tile t covers rows [t*bn, (t+1)*bn) — constant
    # expert by construction. Fully-unused tail tiles clamp to expert E-1
    # (constant weight index -> the pipeline re-uses the staged buffer) and
    # are marked invalid so the kernel skips their MXU work.
    n_tiles = n_pad // bn
    tile_start = jnp.arange(n_tiles, dtype=jnp.int32) * bn
    ends = jnp.cumsum(padded)
    te_raw = jnp.searchsorted(ends, tile_start, side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(te_raw, E - 1)
    tile_valid = (te_raw < E) & (
        tile_start < (offsets + counts)[tile_expert])

    row_idx = jnp.arange(n_pad, dtype=jnp.int32)
    row_expert = jnp.searchsorted(ends, row_idx, side="right")
    row_expert = jnp.minimum(row_expert, E - 1)
    row_valid = row_idx < (offsets[row_expert] + counts[row_expert])

    if num_local:
        # drop-lane tiles stay planned (static shapes) but never compute;
        # clamp their weight index so the pipeline re-uses the staged buffer
        tile_valid = tile_valid & (tile_expert < num_local)
        tile_expert = jnp.minimum(tile_expert, num_local - 1)
        row_valid = row_valid & (row_expert < num_local)

    pos = dest - offsets[expert_flat].astype(jnp.int32)
    return TilePlan(dest, tile_expert, tile_valid, row_valid, counts, pos,
                    n_pad)


def scatter_rows(x_pairs: jax.Array, plan: TilePlan) -> jax.Array:
    """x_pairs [N, d] -> tile-aligned rows [n_pad, d] (zeros in padding)."""
    buf = jnp.zeros((plan.n_pad, x_pairs.shape[-1]), x_pairs.dtype)
    return buf.at[plan.dest].set(x_pairs, mode="drop")


def gather_rows(y_rows: jax.Array, plan: TilePlan) -> jax.Array:
    """Tile-aligned rows back to pair order [N, d]."""
    return y_rows[plan.dest]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def expert_ffn_gmm(x_rows: jax.Array, wg: jax.Array, wi: jax.Array,
                   wo: jax.Array, tile_expert: jax.Array,
                   tile_valid: jax.Array | None = None, *, bn: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """Tile-aligned rows [N_pad, d] through per-expert SwiGLU FFNs.
    interpret=None auto-selects: Mosaic on TPU, interpreter elsewhere."""
    h = gmm_swiglu(x_rows, wg, wi, tile_expert, tile_valid, bn=bn,
                   interpret=interpret)
    return gmm(h, wo, tile_expert, tile_valid, bn=bn, interpret=interpret)


def moe_ffn_fused(x_src: jax.Array, tok: jax.Array, ef: jax.Array,
                  wf: jax.Array, bank: dict, num_experts: int,
                  num_tokens: int, *, expert_of_lane: jax.Array | None = None,
                  bn: int = 0, interpret: bool | None = None,
                  expert_offset: jax.Array | int = 0, num_local: int = 0,
                  capacity: int = 0):
    """Grouped-GEMM MoE FFN over (token, expert) pairs with fused combine.

    x_src [T_src, d] source rows; tok [N] source row per pair; ef [N] lane id
    per pair (expert id, or a group-major lane rank when `expert_of_lane`
    maps lanes back to weight indices); wf [N] combine weights (zeroed pairs
    contribute nothing — capacity drops reduce to zero weights).

    With `num_local > 0`, `bank` holds only the LOCAL expert slice and `ef`
    carries GLOBAL ids: pairs outside [expert_offset, expert_offset +
    num_local) land in the planner's skipped drop lane and contribute zero
    rows — the per-shard EP path (each model shard runs this over its own
    slice and psums the partial outputs).

    With `capacity > 0`, pairs past that position in their lane's stable run
    (`plan.pos`, the same rank the xla dispatch buffer evicts at) get a ZERO
    combine weight — capacity drops without a second sort; read the kept
    mask back off `plan.pos < capacity`.

    Returns (y [num_tokens, d] fp32 combined output, y_rows [n_pad, d] fp32
    weighted per-row outputs, plan). The combine weight is applied in-kernel
    (gmm_scaled) and rows are scatter-added directly into the token buffer.
    """
    bn = bn or default_block_rows()
    plan = plan_tile_dispatch(ef, num_experts, bn,
                              expert_offset=expert_offset, num_local=num_local)
    if capacity:
        wf = jnp.where(plan.pos < capacity, wf, 0.0)
    te = (plan.tile_expert if expert_of_lane is None
          else expert_of_lane[plan.tile_expert])
    x_rows = scatter_rows(x_src[tok], plan)
    scale = jnp.zeros((plan.n_pad, 1), jnp.float32).at[plan.dest].set(
        wf.astype(jnp.float32)[:, None], mode="drop")
    h = gmm_swiglu(x_rows, bank["wg"], bank["wi"], te, plan.tile_valid,
                   bn=bn, interpret=interpret)
    y_rows = gmm_scaled(h, bank["wo"], te, plan.tile_valid, scale, bn=bn,
                        interpret=interpret)
    row_token = jnp.full((plan.n_pad,), num_tokens, jnp.int32).at[
        plan.dest].set(tok.astype(jnp.int32), mode="drop")
    y = jnp.zeros((num_tokens, x_src.shape[-1]), jnp.float32).at[
        row_token].add(y_rows, mode="drop")
    return y, y_rows, plan


def go_selected_ffn(x: jax.Array, selected: jax.Array, g: jax.Array,
                    bank: dict, num_experts: int, *, bn: int = 0,
                    interpret: bool | None = None):
    """C4 decode FFN over ONLY the (token, expert) pairs the TopKUpdate
    selected. x [B, d]; selected [B, E] bool; g [B, E] softmax affinities.

    Unselected pairs are routed to a drop lane whose tiles are planned but
    marked invalid — the kernel skips their MXU work, so the executed row
    count is sum(selected) padded to tile boundaries (vs B*E for the dense
    fallback `expert_ffn_all`). Returns (contrib [B, E, d] fp32 weighted
    outputs, zeros where unselected; plan) — exactly what `go_cache_step`
    caches and combines.
    """
    B, d = x.shape
    E = num_experts
    bn = bn or default_block_rows()
    sel = selected.reshape(-1)
    pair_b = jnp.repeat(jnp.arange(B, dtype=jnp.int32), E)
    pair_e = jnp.tile(jnp.arange(E, dtype=jnp.int32), B)
    ef = jnp.where(sel, pair_e, E)                       # lane E = drop lane
    plan = plan_tile_dispatch(ef, E, bn, num_local=E)
    x_rows = scatter_rows(x[pair_b], plan)
    scale = jnp.zeros((plan.n_pad, 1), jnp.float32).at[plan.dest].set(
        jnp.where(sel, g.reshape(-1), 0.0).astype(jnp.float32)[:, None],
        mode="drop")
    h = gmm_swiglu(x_rows, bank["wg"], bank["wi"], plan.tile_expert,
                   plan.tile_valid, bn=bn, interpret=interpret)
    y_rows = gmm_scaled(h, bank["wo"], plan.tile_expert, plan.tile_valid,
                        scale, bn=bn, interpret=interpret)
    contrib = gather_rows(y_rows, plan).reshape(B, E, d)
    return contrib, plan


def moe_ffn_pallas(x: jax.Array, expert_idx: jax.Array, weights: jax.Array,
                   bank: dict, num_experts: int, *, bn: int = 0,
                   interpret: bool | None = None) -> jax.Array:
    """Full MoE FFN through the Pallas path.

    x [T, d]; expert_idx [T, k]; weights [T, k] -> y [T, d].
    Zero-redundancy counterpart of core.moe.group_forward's XLA fallback: no
    masked duplicate member passes, no capacity drops (worst-case padding)."""
    T, d = x.shape
    k = expert_idx.shape[1]
    ef = expert_idx.reshape(-1).astype(jnp.int32)
    wf = weights.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    y, _, _ = moe_ffn_fused(x, tok, ef, wf, bank, num_experts, T, bn=bn,
                            interpret=interpret)
    return y.astype(x.dtype)
