"""jit'd wrappers around the Pallas kernels + the PACKED tile-dispatch planner
that connects them to the MoE layer.

`plan_tile_dispatch` realizes the paper's scheduling insight in TPU terms:
tokens are sorted by (group, expert) and packed into row tiles so the grouped
GEMM stages every expert weight tile into VMEM exactly once per column stripe
(Algorithm 1's "no repeated transfers"). Three packing rules keep the grid at
a static occupancy bound instead of the padded worst case:

  elision   dropped pairs (the EP non-local window, capacity-evicted rows of
            a foreign shard) consume NO buffer rows: their `dest` is the
            n_pad sentinel, so the packed buffer holds only planned lanes.
  fusion    lanes of one C2 group are PAIRED: a pair's two runs concatenate
            unpadded and round to the tile boundary together (the roadmap's
            dynamic lane fusion). At most one tile per pair straddles both
            lanes; the kernels resolve it with a per-row selector
            (`row_sel`) and a secondary weight stream (`tile_expert2`).
            Static tiles drop from N/bn + L to N/bn + P (P = lane pairs).
  counting  for decode-sized inputs the stable per-lane ranks come from an
            O(N·L) one-hot cumsum (no argsort); the structural layout
            (pairing, lane order, static tile count) is host-computed once
            per shape (`_fusion_layout`, lru-cached) and reused by every
            tick, layer and trace — the persistent part of the planner.

Concrete (non-traced) routing outputs additionally hit a host-side
`PlanCache`, so repeated eager planning over the same routing is free.

Production entry points (what core/moe.py's `backend="pallas"` routes to):

  moe_ffn_fused       (token, expert) pairs -> combined [T, d] output with
                      the per-pair combine weights applied IN-KERNEL
                      (gmm_scaled) and rows scatter-added straight into the
                      token buffer — no gather + fp32 multiply pass.
  go_selected_ffn     C4 decode: the per-tick shape is fixed (B tokens, at
                      most B rows per expert), so the decode plan is STATIC
                      per-lane capacity slots — one `top_k` builds the whole
                      gather map, the tile map is a compile-time constant,
                      and a `lax.cond` executes the C_fast ≈ 2·B·k/E budget
                      tiles unless a tick overflows it (then the full B-row
                      plan runs — always correct, never dropped).
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_gmm import (default_interpret, gmm, gmm_scaled,
                                   gmm_swiglu, lowering_platform,
                                   replicate_for_gspmd)


def default_block_rows() -> int:
    """Row-tile height: MXU-aligned when lowering for TPU; small otherwise so
    the interpreted correctness path does not drown in padding tiles."""
    return 128 if lowering_platform() == "tpu" else 8


class TilePlan(NamedTuple):
    dest: jax.Array           # [N] packed row per pair (n_pad = elided/dropped)
    row_pair: jax.Array       # [n_pad] source pair per packed row (N = padding)
    row_sel: jax.Array        # [n_pad, 1] fp32 1.0 primary-lane row, 0.0
                              # secondary-lane row of a fused pair
    tile_expert: jax.Array    # [n_tiles] primary lane per row tile
    tile_expert2: jax.Array   # [n_tiles] secondary lane (== tile_expert
                              # except on a fused pair's straddle tile)
    tile_valid: jax.Array     # [n_tiles] bool — tile carries >=1 real row
    row_valid: jax.Array      # [n_pad] bool — real row vs alignment padding
    counts: jax.Array         # [lanes] pairs per lane (pre-capacity);
                              # windowed plans append the drop-lane count
    pos: jax.Array            # [N] pair's rank within its lane's stable run —
                              # THE capacity-eviction order shared with the
                              # xla dispatch buffer (0 for dropped pairs)
    occupied: jax.Array       # [] traced number of valid tiles
    n_pad: int                # static packed row count
    n_tiles: int              # static grid size (n_pad // bn)


def padded_rows(num_pairs: int, num_lanes: int, bn: int,
                num_pairs_fused: int = 0) -> int:
    """Static packed row bound: whole-N tiles plus one boundary tile per lane
    pair (every lane its own pair without fusion — the pre-packing worst
    case padded_rows(N, L) == round_up(N + L*bn))."""
    P = num_pairs_fused or num_lanes
    return -(-num_pairs // bn) * bn + P * bn


class _FusionLayout(NamedTuple):
    prim: np.ndarray          # [P] primary lane of each pair
    sec: np.ndarray           # [P] secondary lane (== prim for singletons)
    pair_of: np.ndarray       # [L] pair id per lane
    is_sec: np.ndarray        # [L] lane is its pair's secondary member
    P: int


@functools.lru_cache(maxsize=None)
def _fusion_layout(L: int, fuse: tuple | None) -> _FusionLayout:
    """Host-side structural plan, computed once per (lane count, pairing) and
    shared by every tick/layer/trace of that shape. `fuse` maps each lane to
    a fusion-pair id; each id may own one or two lanes."""
    if fuse is None:
        ar = np.arange(L)
        return _FusionLayout(ar, ar.copy(), ar.copy(),
                             np.zeros(L, bool), L)
    fuse = np.asarray(fuse, np.int64)
    assert fuse.shape == (L,), f"fuse covers {fuse.shape} of {L} lanes"
    ids = np.unique(fuse)
    prim = np.empty(len(ids), np.int64)
    sec = np.empty(len(ids), np.int64)
    pair_of = np.empty(L, np.int64)
    is_sec = np.zeros(L, bool)
    for j, fid in enumerate(ids):
        members = np.where(fuse == fid)[0]
        assert 1 <= len(members) <= 2, \
            f"fusion pair {fid} has {len(members)} lanes (max 2)"
        prim[j], sec[j] = members[0], members[-1]
        pair_of[members] = j
        if len(members) == 2:
            is_sec[members[1]] = True
    return _FusionLayout(prim, sec, pair_of, is_sec, len(ids))


def _lane_rank(lane: jax.Array, L: int):
    """Stable rank of each pair within its lane + per-lane counts [L].
    Entries == L (the drop sentinel) are excluded from counts and get rank 0.
    Decode-sized inputs use an O(N·L) one-hot cumsum (a vectorized counting
    sort — no argsort); large inputs fall back to the argsort ranking. Both
    produce the SAME stable order, so capacity parity is path-independent."""
    N = lane.shape[0]
    if N * (L + 1) <= (1 << 16):
        oh = (lane[:, None] == jnp.arange(L, dtype=lane.dtype)[None, :])
        cs = jnp.cumsum(oh.astype(jnp.int32), axis=0)
        pos = jnp.take_along_axis(
            cs, jnp.minimum(lane, L - 1).astype(jnp.int32)[:, None], 1)[:, 0] - 1
        counts = cs[-1]
    else:
        order = jnp.argsort(lane, stable=True)
        se = lane[order]
        ps = jnp.arange(N, dtype=jnp.int32) - jnp.searchsorted(
            se, se, side="left").astype(jnp.int32)
        # O(N) scatter inversion of the sort permutation (no second argsort)
        pos = jnp.zeros((N,), jnp.int32).at[order].set(ps)
        counts = jnp.bincount(lane, length=L)
    return jnp.where(lane < L, pos, 0).astype(jnp.int32), counts


class PlanCache:
    """Host-side memo over CONCRETE routing outputs: eager planning (tools,
    benchmarks, repeated decode ticks outside jit) reuses the finished plan
    instead of re-dispatching the planner ops. Traced inputs bypass it —
    inside jit the plan is part of the compiled step already."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._store: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        plan = self._store.get(key)
        if plan is not None:
            self._store.move_to_end(key)
            self.hits += 1
        else:
            self.misses += 1
        return plan

    def put(self, key, plan):
        self._store[key] = plan
        if len(self._store) > self.maxsize:
            self._store.popitem(last=False)

    def clear(self):
        self._store.clear()
        self.hits = self.misses = 0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._store)}


_PLAN_CACHE = PlanCache()


def plan_cache_stats() -> dict:
    return _PLAN_CACHE.stats()


def _fuse_key(fuse):
    if fuse is None:
        return None
    return tuple(int(v) for v in np.asarray(fuse).reshape(-1))


def plan_tile_dispatch(expert_flat: jax.Array, num_experts: int, bn: int, *,
                       expert_offset: jax.Array | int = 0,
                       num_local: int = 0, fuse=None) -> TilePlan:
    """expert_flat [N] int32 (one entry per (token, expert) pair) -> packed
    tile layout. All shapes static; pure jnp (jit/pjit-safe).

    With `num_local > 0` the plan covers ONLY the local expert window
    [expert_offset, expert_offset + num_local): pairs outside it are ELIDED —
    they take no buffer rows (`dest` = the n_pad sentinel) and no tiles, so
    each EP shard's packed buffer scales with its local traffic. `counts`
    still appends the drop-lane tally. `tile_expert` indexes the LOCAL weight
    bank [0, num_local); the offset may be a traced `axis_index` (`num_local`
    is static so shapes agree across shards).

    `fuse` (static, [lanes] pair ids with <= 2 lanes per id) turns on lane
    fusion: a pair's runs pack into shared tiles, cutting the static grid
    from N/bn + L to N/bn + P tiles.
    """
    fuse_t = _fuse_key(fuse)
    cacheable = (not isinstance(expert_flat, jax.core.Tracer)
                 and not isinstance(expert_offset, jax.core.Tracer))
    if cacheable:
        key = (np.asarray(expert_flat).tobytes(), expert_flat.shape[0],
               int(num_experts), int(bn), int(expert_offset), int(num_local),
               fuse_t)
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            return hit
    plan = _plan_tile_dispatch(expert_flat, num_experts, bn, expert_offset,
                               num_local, fuse_t)
    if cacheable:
        _PLAN_CACHE.put(key, plan)
    return plan


def _plan_tile_dispatch(expert_flat, num_experts, bn, expert_offset,
                        num_local, fuse_t) -> TilePlan:
    if num_local:
        local_idx = expert_flat - expert_offset
        local = (local_idx >= 0) & (local_idx < num_local)
        lane = jnp.where(local, local_idx, num_local).astype(jnp.int32)
        L = num_local
        has_drop = True
    else:
        lane = expert_flat.astype(jnp.int32)
        L = num_experts
        has_drop = False
    N = lane.shape[0]
    lay = _fusion_layout(L, fuse_t)
    n_pad = padded_rows(N, L, bn, lay.P)
    n_tiles = n_pad // bn

    pos, counts = _lane_rank(lane, L)
    prim = jnp.asarray(lay.prim, jnp.int32)
    sec = jnp.asarray(lay.sec, jnp.int32)
    cA = counts[prim]
    cB = jnp.where(jnp.asarray(lay.sec != lay.prim), counts[sec], 0)
    pair_rows = (cA + cB).astype(jnp.int32)
    pair_pad = ((pair_rows + bn - 1) // bn) * bn
    ends = jnp.cumsum(pair_pad)
    pair_off = (ends - pair_pad).astype(jnp.int32)
    pair_of = jnp.asarray(lay.pair_of, jnp.int32)
    lane_start = pair_off[pair_of] + jnp.where(
        jnp.asarray(lay.is_sec), cA[pair_of], 0).astype(jnp.int32)
    dest = jnp.where(lane < L,
                     lane_start[jnp.minimum(lane, L - 1)] + pos,
                     n_pad).astype(jnp.int32)
    row_pair = jnp.full((n_pad,), N, jnp.int32).at[dest].set(
        jnp.arange(N, dtype=jnp.int32), mode="drop")

    # tile map: tile t covers packed rows [t*bn, (t+1)*bn); within one pair,
    # primary rows precede secondary rows, so at most ONE boundary (the
    # straddle) falls inside a tile. Trailing/empty tiles clamp to an
    # in-range lane (constant weight index -> the pipeline re-uses the
    # staged buffer) and are marked invalid so the kernel skips their MXU
    # work.
    ts = jnp.arange(n_tiles, dtype=jnp.int32) * bn
    tp_raw = jnp.searchsorted(ends, ts, side="right").astype(jnp.int32)
    tp = jnp.minimum(tp_raw, lay.P - 1)
    bound = pair_off[tp] + cA[tp]
    real_end = pair_off[tp] + pair_rows[tp]
    te = jnp.where(ts < bound, prim[tp], sec[tp]).astype(jnp.int32)
    te2 = jnp.where((bound > ts) & (bound < jnp.minimum(ts + bn, real_end)),
                    sec[tp], te).astype(jnp.int32)
    tile_valid = (tp_raw < lay.P) & (ts < real_end)

    ri = jnp.arange(n_pad, dtype=jnp.int32)
    rp = jnp.minimum(jnp.searchsorted(ends, ri, side="right"),
                     lay.P - 1).astype(jnp.int32)
    row_sel = (ri < (pair_off + cA)[rp]).astype(jnp.float32)[:, None]
    row_valid = ri < (pair_off + pair_rows)[rp]

    if has_drop:
        counts = jnp.concatenate(
            [counts, (N - counts.sum())[None].astype(counts.dtype)])
    return TilePlan(dest, row_pair, row_sel, te, te2, tile_valid, row_valid,
                    counts, pos, tile_valid.sum(), n_pad, n_tiles)


def scatter_rows(x_pairs: jax.Array, plan: TilePlan) -> jax.Array:
    """x_pairs [N, d] -> packed rows [n_pad, d] (zeros in padding and for
    elided pairs) — one gather through the plan's row_pair map."""
    xz = jnp.concatenate(
        [x_pairs, jnp.zeros((1, x_pairs.shape[-1]), x_pairs.dtype)])
    return xz[plan.row_pair]


def gather_rows(y_rows: jax.Array, plan: TilePlan) -> jax.Array:
    """Packed rows back to pair order [N, d]; elided pairs read zeros."""
    yz = jnp.concatenate(
        [y_rows, jnp.zeros((1, y_rows.shape[-1]), y_rows.dtype)])
    return yz[plan.dest]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def expert_ffn_gmm(x_rows: jax.Array, wg: jax.Array, wi: jax.Array,
                   wo: jax.Array, tile_expert: jax.Array,
                   tile_valid: jax.Array | None = None, *, bn: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """Tile-aligned rows [N_pad, d] through per-expert SwiGLU FFNs.
    interpret=None auto-selects: Mosaic on TPU, interpreter elsewhere."""
    h = gmm_swiglu(x_rows, wg, wi, tile_expert, tile_valid, bn=bn,
                   interpret=interpret)
    return gmm(h, wo, tile_expert, tile_valid, bn=bn, interpret=interpret)


def moe_ffn_fused(x_src: jax.Array, tok: jax.Array, ef: jax.Array,
                  wf: jax.Array, bank: dict, num_experts: int,
                  num_tokens: int, *, expert_of_lane: jax.Array | None = None,
                  bn: int = 0, interpret: bool | None = None,
                  expert_offset: jax.Array | int = 0, num_local: int = 0,
                  capacity: int = 0, fuse=None,
                  replicate_under_mesh: bool = True):
    """Grouped-GEMM MoE FFN over (token, expert) pairs with fused combine.

    x_src [T_src, d] source rows; tok [N] source row per pair; ef [N] lane id
    per pair (expert id, or a group-major lane rank when `expert_of_lane`
    maps lanes back to weight indices); wf [N] combine weights (zeroed pairs
    contribute nothing — capacity drops reduce to zero weights).

    With `num_local > 0`, `bank` holds only the LOCAL expert slice and `ef`
    carries GLOBAL ids: pairs outside [expert_offset, expert_offset +
    num_local) are elided from the packed buffer and contribute zero rows —
    the per-shard EP path (each model shard runs this over its own slice and
    psums the partial outputs).

    With `capacity > 0`, pairs past that position in their lane's stable run
    (`plan.pos`, the same rank the xla dispatch buffer evicts at) get a ZERO
    combine weight — capacity drops without a second sort; read the kept
    mask back off `plan.pos < capacity`.

    `replicate_under_mesh=False` is for callers tracing inside a shard_map
    body (the EP path): their operands are shard-local and must not get the
    GSPMD replication pin.

    `fuse` (static pair ids per lane) packs paired lanes into shared tiles;
    the straddle tile's rows are resolved in-kernel via the plan's per-row
    selector, so fusion is numerically exact.

    Returns (y [num_tokens, d] fp32 combined output, y_rows [n_pad, d] fp32
    weighted per-row outputs, plan). The combine weight is applied in-kernel
    (gmm_scaled) and rows are scatter-added directly into the token buffer.
    """
    bn = bn or default_block_rows()
    # under a GSPMD mesh the whole branch computes replicated (see
    # replicate_for_gspmd); shard_map callers (the EP body, whose data is
    # already shard-local) pass replicate_under_mesh=False
    if replicate_under_mesh:
        x_src, tok, ef, wf = replicate_for_gspmd(x_src, tok, ef, wf)
    plan = plan_tile_dispatch(ef, num_experts, bn, expert_offset=expert_offset,
                              num_local=num_local, fuse=fuse)
    if capacity:
        wf = jnp.where(plan.pos < capacity, wf, 0.0)
    te = (plan.tile_expert if expert_of_lane is None
          else expert_of_lane[plan.tile_expert])
    te2 = (plan.tile_expert2 if expert_of_lane is None
           else expert_of_lane[plan.tile_expert2])
    fused = fuse is not None
    N = ef.shape[0]
    # one gather per operand through the plan's row_pair map (sentinel N ->
    # the appended zero/sink entry)
    tok_z = jnp.concatenate(
        [tok.astype(jnp.int32), jnp.full((1,), num_tokens, jnp.int32)])
    row_token = tok_z[plan.row_pair]
    x_z = jnp.concatenate([x_src, jnp.zeros((1, x_src.shape[-1]), x_src.dtype)])
    x_rows = x_z[row_token]
    wf_z = jnp.concatenate([wf.astype(jnp.float32), jnp.zeros((1,))])
    scale = wf_z[plan.row_pair][:, None]
    h = gmm_swiglu(x_rows, bank["wg"], bank["wi"], te, plan.tile_valid,
                   tile_expert2=te2 if fused else None,
                   row_sel=plan.row_sel if fused else None,
                   bn=bn, interpret=interpret)
    y_rows = gmm_scaled(h, bank["wo"], te, plan.tile_valid, scale,
                        tile_expert2=te2 if fused else None,
                        row_sel=plan.row_sel if fused else None,
                        bn=bn, interpret=interpret)
    y = jnp.zeros((num_tokens, x_src.shape[-1]), jnp.float32).at[
        row_token].add(y_rows, mode="drop")
    return y, y_rows, plan


# ------------------------------------------------------------ GO decode plan

class GoDecodePlan(NamedTuple):
    counts: jax.Array         # [E] selected pairs per expert this tick
    C_fast: int               # static per-lane budget (rows) of the fast path
    C_full: int               # static per-lane rows of the fallback (== B)
    n_tiles_fast: int         # static grid of the fast path (E * C_fast / bn)
    n_tiles_full: int
    fallback: jax.Array       # [] traced bool — this tick overflowed C_fast


def go_decode_budget(batch: int, num_experts: int, topk_hint: int,
                     bn: int) -> int:
    """Static per-lane row budget for the fast decode path: with a warm GO
    cache each tick selects ~B·k pairs, so 2·B·k/E rows per expert plus two
    rows of small-batch headroom (rounded to the row tile) covers the
    steady state; the lax.cond fallback keeps overflow ticks exact."""
    if topk_hint <= 0:
        return batch
    c = -(-2 * batch * topk_hint // num_experts) + 2
    return min(-(-c // bn) * bn, batch)


def go_selected_ffn(x: jax.Array, selected: jax.Array, g: jax.Array,
                    bank: dict, num_experts: int, *, bn: int = 0,
                    interpret: bool | None = None, topk_hint: int = 0,
                    executor: str = "auto"):
    """C4 decode FFN over ONLY the (token, expert) pairs the TopKUpdate
    selected. x [B, d]; selected [B, E] bool; g [B, E] softmax affinities.

    The decode tick's shape is FIXED ([B, E] mask, at most B rows per
    expert), so the plan is static per-lane capacity slots: lane e owns rows
    [e*C, (e+1)*C), the tile map is a compile-time constant, and ONE
    `top_k` per tick recovers the selected row gather (the persistent decode
    planner — no sort, no cumsum offsets). With `topk_hint` (the router's k)
    a `lax.cond` executes only the C_fast = ~2·B·k/E budget rows unless the
    tick overflows the budget, in which case the full B-row plan runs —
    always exact, nothing is dropped.

    `executor` picks how the planned tiles execute: "pallas" streams them
    through gmm_swiglu/gmm_scaled (the TPU path; per-lane tiles, static
    tile_expert, dynamic tile_valid from the counts), "xla" runs the
    identical layout as a batched per-lane einsum (what interpret-mode hosts
    use — same plan, no interpreter overhead), "auto" resolves per platform.

    Returns (contrib [B, E, d] fp32 weighted outputs, zeros where
    unselected; GoDecodePlan) — exactly what `go_cache_step` caches and
    combines.
    """
    B, d = x.shape
    E = num_experts
    bn = bn or default_block_rows()
    if interpret is None:
        interpret = default_interpret()
    if executor == "auto":
        executor = "xla" if interpret else "pallas"
    selT = selected.T                                    # [E, B]
    counts = selT.sum(axis=1).astype(jnp.int32)
    # selected b's per expert in ascending order, via one top_k: selected
    # rows get descending positive keys, unselected distinct negatives
    ar = jnp.arange(B, dtype=jnp.int32)
    keys = jnp.where(selT, B - ar[None, :], -1 - ar[None, :])
    gT = g.T

    gsel = jnp.where(selT, gT, 0.0)           # softmax affinities are > 0

    def run(C: int):
        idx = jax.lax.top_k(keys, C)[1]                  # [E, C]
        w = jnp.take_along_axis(gsel, idx, axis=1)       # 0 on invalid slots
        if executor == "xla":
            x_disp = x[idx]                              # [E, C, d]
            h = jax.nn.silu(
                jnp.einsum("ecd,edf->ecf", x_disp, bank["wg"])) * jnp.einsum(
                "ecd,edf->ecf", x_disp, bank["wi"])
            y = jnp.einsum("ecf,efd->ecd", h,
                           bank["wo"]).astype(jnp.float32) * w[..., None]
        else:
            Cp = -(-C // bn) * bn
            idx_p = jnp.pad(idx, ((0, 0), (0, Cp - C)))
            x_rows = x[idx_p].reshape(E * Cp, d)
            scale = jnp.pad(w, ((0, 0), (0, Cp - C))).reshape(E * Cp, 1)
            te = jnp.repeat(jnp.arange(E, dtype=jnp.int32), Cp // bn)
            slot = jnp.arange(Cp // bn, dtype=jnp.int32) * bn
            tv = (slot[None, :] < counts[:, None]).reshape(-1)
            h = gmm_swiglu(x_rows, bank["wg"], bank["wi"], te, tv, bn=bn,
                           interpret=interpret)
            y_rows = gmm_scaled(h, bank["wo"], te, tv, scale, bn=bn,
                                interpret=interpret)
            y = y_rows.reshape(E, Cp, d)[:, :C]
        # scatter straight into the token-major contrib buffer (invalid
        # slots land in the sink row B) — no [E, B, d] transpose pass
        z = jnp.zeros((B + 1, E, d), jnp.float32)
        eix = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[:, None],
                               idx.shape)
        z = z.at[jnp.where(w > 0, idx, B), eix].set(y)
        return z[:B]

    C_full = B
    C_fast = go_decode_budget(B, E, topk_hint, bn if executor != "xla" else 1)
    n_fast = E * (-(-C_fast // bn))
    n_full = E * (-(-C_full // bn))
    if C_fast >= C_full:
        contrib = run(C_full)
        fallback = jnp.zeros((), bool)
    else:
        fallback = counts.max() > C_fast
        contrib = jax.lax.cond(fallback, lambda: run(C_full),
                               lambda: run(C_fast))
    plan = GoDecodePlan(counts, C_fast, C_full, n_fast, n_full, fallback)
    return contrib, plan


def moe_ffn_pallas(x: jax.Array, expert_idx: jax.Array, weights: jax.Array,
                   bank: dict, num_experts: int, *, bn: int = 0,
                   interpret: bool | None = None) -> jax.Array:
    """Full MoE FFN through the Pallas path.

    x [T, d]; expert_idx [T, k]; weights [T, k] -> y [T, d].
    Zero-redundancy counterpart of core.moe.group_forward's XLA fallback: no
    masked duplicate member passes, no capacity drops (worst-case padding)."""
    T, d = x.shape
    k = expert_idx.shape[1]
    ef = expert_idx.reshape(-1).astype(jnp.int32)
    wf = weights.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    y, _, _ = moe_ffn_fused(x, tok, ef, wf, bank, num_experts, T, bn=bn,
                            interpret=interpret)
    return y.astype(x.dtype)
