"""Fused sLSTM sequence kernel — the consequence of §Perf Cell A.

The sLSTM recurrence is token-sequential; under XLA/GSPMD each of the S scan
steps round-trips the cell state and re-streams the recurrent weights
(EXPERIMENTS.md §Perf: two refuted scheduling hypotheses showed the term is
unreachable above the kernel layer). This kernel runs the WHOLE sequence for
one batch row inside a single pallas_call: the recurrent weights r and the
(c, n, m, h) state live in VMEM scratch across all S steps; HBM traffic is
exactly the xs (gate pre-activations) stream in and the h stream out — the
~4-orders-of-magnitude term reduction quantified in the perf log.

Grid: (B,). Per-step math matches models/xlstm._slstm_cell exactly
(stabilized exponential gating). Validated in interpret mode against the
pure-jnp reference (tests/test_kernels.py); on TPU the same body lowers via
Mosaic with r resident in VMEM (4*H*hd*hd fp32 — 16 MB at the xlstm-1.3b
shard size, well under the 128 MB VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_seq_kernel(u_ref, r_ref, h_out_ref, c_ref, n_ref, m_ref, h_ref,
                      *, seq_len: int, H: int, hd: int):
    # state scratch: [H, hd] each, fp32, persistent across the fori_loop
    c_ref[...] = jnp.zeros_like(c_ref)
    n_ref[...] = jnp.zeros_like(n_ref)
    m_ref[...] = jnp.zeros_like(m_ref)
    h_ref[...] = jnp.zeros_like(h_ref)
    r = r_ref[...].astype(jnp.float32)            # [4, H, hd, hd] — VMEM-resident

    def step(t, _):
        u_t = u_ref[0, t].astype(jnp.float32)     # [4*H*hd]
        gates_in = u_t.reshape(4, H, hd)
        h_prev = h_ref[...]                       # [H, hd]
        rec = jnp.einsum("ghij,hj->ghi", r, h_prev)
        g = gates_in + rec
        li, lf, z, o = g[0], g[1], g[2], g[3]
        lf = jax.nn.log_sigmoid(lf)
        m_new = jnp.maximum(lf + m_ref[...], li)
        fi = jnp.exp(lf + m_ref[...] - m_new)
        ii = jnp.exp(li - m_new)
        c_new = fi * c_ref[...] + ii * jnp.tanh(z)
        n_new = fi * n_ref[...] + ii
        h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1e-6)
        c_ref[...] = c_new
        n_ref[...] = n_new
        m_ref[...] = m_new
        h_ref[...] = h_new
        h_out_ref[0, t] = h_new.reshape(-1).astype(h_out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_seq(u: jax.Array, r: jax.Array, *, interpret: bool = True):
    """u [B, S, 4*H*hd] gate pre-activations; r [4, H, hd, hd] recurrent
    weights -> h [B, S, H*hd] (fp32 state carried on-chip)."""
    B, S, four_d = u.shape
    _, H, hd, _ = r.shape
    assert four_d == 4 * H * hd
    kernel = functools.partial(_slstm_seq_kernel, seq_len=S, H=H, hd=hd)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, four_d), lambda b: (b, 0, 0)),
            pl.BlockSpec((4, H, hd, hd), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, H * hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H * hd), u.dtype),
        scratch_shapes=[pltpu.VMEM((H, hd), jnp.float32) for _ in range(4)],
        interpret=interpret,
    )(u, r)
