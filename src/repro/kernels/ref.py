"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def row_experts(tile_expert: jax.Array, bn: int) -> jax.Array:
    """tile_expert [T] -> per-row expert ids [T*bn]."""
    return jnp.repeat(tile_expert, bn)


def gmm_ref(x: jax.Array, w: jax.Array, tile_expert: jax.Array,
            bn: int) -> jax.Array:
    """y[i] = x[i] @ w[expert(i)] — gather-based oracle."""
    e = row_experts(tile_expert, bn)                     # [N]
    wr = w[e]                                            # [N, K, F]
    return jnp.einsum("nk,nkf->nf", x.astype(jnp.float32),
                      wr.astype(jnp.float32)).astype(x.dtype)


def gmm_scaled_ref(x: jax.Array, w: jax.Array, tile_expert: jax.Array,
                   row_scale: jax.Array, bn: int) -> jax.Array:
    """Fused-combine oracle: y[i] = (x[i] @ w[expert(i)]) * row_scale[i]."""
    y = gmm_ref(x, w, tile_expert, bn).astype(jnp.float32)
    return y * row_scale.reshape(-1, 1).astype(jnp.float32)


def gmm_swiglu_ref(x: jax.Array, wg: jax.Array, wi: jax.Array,
                   tile_expert: jax.Array, bn: int) -> jax.Array:
    e = row_experts(tile_expert, bn)
    g = jnp.einsum("nk,nkf->nf", x.astype(jnp.float32), wg[e].astype(jnp.float32))
    i = jnp.einsum("nk,nkf->nf", x.astype(jnp.float32), wi[e].astype(jnp.float32))
    return (jax.nn.silu(g) * i).astype(x.dtype)


def go_topk_ref(s_prev: jax.Array, tok_prev: jax.Array, s_new: jax.Array,
                token_id) -> tuple:
    """Vectorized eq. (5) oracle (same semantics as core.routing.topk_update,
    batched)."""
    from repro.core.routing import topk_update
    upd = jax.vmap(lambda sp, tp, sn: topk_update(sp, tp, sn, token_id))(
        s_prev, tok_prev, s_new)
    return upd.new_scores, upd.new_token_ids, upd.selected, upd.slot
