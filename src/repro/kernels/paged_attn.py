"""Fused paged-attention — decode (and chunked-prefill) attention that walks
the block table in-kernel instead of gathering pages back into the dense
[B, max_tokens] layout per layer per tick.

PIM mapping: the paper caches decode state near the compute instead of
re-materializing it (the GO cache's "cache, don't recompute" discipline);
this kernel is the attention-side sibling. The dense gather reads EVERY
page slot of every block table each tick — bandwidth scales with
`max_tokens`. Here the grid walks (batch row, logical page) with the block
table scalar-prefetched, so each step stages exactly ONE physical page into
VMEM; pages past the row's position `t` (always mapped to the null page 0)
skip their FLOPs via `pl.when` AND resolve to the constant block index 0,
so the pipeline re-uses the staged null-page buffer instead of issuing
fresh HBM copies — per-tick traffic scales with LIVE tokens.

Kernels:
  paged_attn_decode(q, k_pages, v_pages, block_table, t)   -> [B, Hq, hd]
      one query per row, online softmax over the row's pages; reproduces
      models/attention.py::_decode_sdpa over the gathered layout (masking
      `k_pos <= t` + sliding window, GQA head broadcast, logit softcap) to
      within fp accumulation-order differences (online vs one-shot softmax).
  paged_attn_chunk(q, k_pages, v_pages, block_table, start, kv_len)
      chunked prefill: a [B, Cs] query chunk attends over the prefix's
      pages (causal within the chunk) without re-materializing the dense
      layout per chunk.

Masking rules (matching the gather path exactly):
  decode   k_pos <= t,              and k_pos > t - window     (window > 0)
  chunk    k_pos <  kv_len,  k_pos <= q_pos,  k_pos > q_pos - window

Null pages need no special-casing for CORRECTNESS — every position they
back is already masked by the rules above (block tables only map live
positions to real pages) — but they are where the bandwidth win comes
from: a dead page's block index is 0, constant across the tail of the row,
so only compute-live pages cost HBM traffic.

`interpret=None` auto-selects from the lowering context exactly like
kernels/moe_gmm.py (pallas lowers via Mosaic only on TPU; CPU CI runs the
same kernel body in interpret mode), and the resolved value is part of the
jit cache key. Under a GSPMD mesh the inputs are pinned replicated
(`replicate_for_gspmd`) — pallas_call has no SPMD partitioning rule, and
the interpret lowering miscompiles on sharded CPU host meshes (the
moe_gmm.py precedent); a shard_mapped page-parallel variant is the ROADMAP
follow-up for real multi-chip TPU.

`resolve_mode(cfg)` is the path selector consumed by models/attention.py
and launch/sharding.py: cfg.paged_attn "kernel" / "gather" are explicit,
"auto" picks the kernel wherever Mosaic can lower it (TPU) and the gather
fallback elsewhere — CPU CI opts into the kernel explicitly (the
REPRO_FORCE_PAGED_KERNEL lane) or per-test via cfg overrides.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.moe_gmm import (default_interpret, lowering_platform,
                                   replicate_for_gspmd)

NEG_INF = -1e30


def resolve_mode(cfg) -> str:
    """The paged-attention realization for `cfg`: "kernel" (this module) or
    "gather" (attention.py's dense re-materialization). cfg.paged_attn
    "auto" resolves per lowering platform, like the MoE backend."""
    mode = getattr(cfg, "paged_attn", "auto")
    if mode == "auto":
        return "kernel" if lowering_platform() == "tpu" else "gather"
    if mode not in ("kernel", "gather"):
        raise ValueError(
            f"cfg.paged_attn={mode!r} (want 'auto', 'kernel' or 'gather')")
    return mode


# --------------------------------------------------------------------- decode

def _decode_kernel(bt_ref, tv_ref, wv_ref, q_ref, k_ref, v_ref, *rest,
                   ps: int, num_pages: int, softcap: float, scale: float,
                   quant: bool = False):
    """Grid (b, j): batch row b, logical page j (j innermost — the online-
    softmax reduction axis). Scalar-prefetched refs: block table [B, P],
    positions [B], window [1]. With `quant`, two extra operands carry the
    page's per-kv-head scales ([1, Hkv] blocks gathered by the same
    block-table index map) and the int8 page dequantizes in-VMEM — HBM
    traffic drops with the storage dtype while compute stays fp32."""
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, j = pl.program_id(0), pl.program_id(1)
    t = tv_ref[b]
    w = wv_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    base = j * ps
    # live <=> the page holds at least one attendable position: some
    # k_pos in [base, base+ps-1] with k_pos <= t (and inside the window).
    # Dead pages (always block-table index 0, the null page) skip ALL work.
    live = base <= t
    live = jnp.logical_and(
        live, jnp.where(w > 0, base + ps - 1 > t - w, True))

    @pl.when(live)
    def _attend():
        q = q_ref[0]                                   # [Hq, hd]
        k = k_ref[0]                                   # [ps, Hkv, hd]
        v = v_ref[0]
        if quant:
            k = k.astype(jnp.float32) * ks_ref[0][None, :, None]
            v = v.astype(jnp.float32) * vs_ref[0][None, :, None]
        Hkv, G = m_ref.shape
        hd = q.shape[-1]
        qg = q.reshape(Hkv, G, hd)
        s = jnp.einsum("hgd,phd->hgp", qg, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        msk = k_pos <= t
        msk = jnp.logical_and(msk, jnp.where(w > 0, k_pos > t - w, True))
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "hgp,phd->hgd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-20)
        out = acc_ref[...] / l[..., None]               # [Hkv, G, hd]
        o_ref[0] = out.reshape(o_ref.shape[1], o_ref.shape[2])


def paged_attn_decode(q, k_pages, v_pages, block_table, t, *, window=0,
                      softcap: float = 0.0, k_scales=None, v_scales=None,
                      interpret: bool | None = None) -> jax.Array:
    """Single-token paged decode attention.

    q [B, Hq, hd] (post-RoPE); k_pages/v_pages [NP, ps, Hkv, hd] (one
    layer's pool, the new token already scattered in); block_table [B, P]
    int32; t scalar or [B] int32 (current position per row); window a
    traced int32 scalar (0 = global). `k_scales`/`v_scales` [NP, Hkv] f32
    mark a QUANTIZED pool (int8 pages): the kernel gathers each page's
    scales alongside it and dequantizes in-VMEM. Returns fp32 [B, Hq, hd]
    — the pre-`wo` attention output, matching _decode_sdpa's epilogue
    dtype."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    if Hq % Hkv:
        raise ValueError(f"num_heads={Hq} must be a multiple of "
                         f"num_kv_heads={Hkv}")
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales or neither")
    t_vec = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (B,))
    return _paged_attn_decode(q, k_pages, v_pages, block_table, t_vec,
                              jnp.asarray(window, jnp.int32),
                              k_scales, v_scales,
                              softcap=float(softcap), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def _paged_attn_decode(q, k_pages, v_pages, block_table, t_vec, window,
                       k_scales, v_scales, *, softcap, interpret):
    B, Hq, hd = q.shape
    NP, ps, Hkv, _ = k_pages.shape
    P = block_table.shape[1]
    G = Hq // Hkv
    quant = k_scales is not None
    scale = 1.0 / (hd ** 0.5)
    bt = block_table.astype(jnp.int32)
    tv = t_vec.astype(jnp.int32)
    wv = window.astype(jnp.int32).reshape(1)
    ops = [q, k_pages, v_pages, bt, tv, wv]
    if quant:
        ops += [k_scales.astype(jnp.float32), v_scales.astype(jnp.float32)]
    ops = replicate_for_gspmd(*ops)
    q, k_pages, v_pages, bt, tv, wv = ops[:6]

    page_spec = pl.BlockSpec((1, ps, Hkv, hd),
                             lambda b, j, bt, tv, wv: (bt[b, j], 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, Hq, hd), lambda b, j, bt, tv, wv: (b, 0, 0)),
        page_spec, page_spec,
    ]
    if quant:
        scale_spec = pl.BlockSpec((1, Hkv),
                                  lambda b, j, bt, tv, wv: (bt[b, j], 0))
        in_specs += [scale_spec, scale_spec]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, hd), lambda b, j, bt, tv, wv: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Hkv, G), jnp.float32),
                        pltpu.VMEM((Hkv, G), jnp.float32),
                        pltpu.VMEM((Hkv, G, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, ps=ps, num_pages=P,
                          softcap=softcap, scale=scale, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), jnp.float32),
        interpret=interpret,
    )(bt, tv, wv, q, k_pages, v_pages, *ops[6:])


# -------------------------------------------------------------- chunk prefill

def _chunk_kernel(bt_ref, sv_ref, kl_ref, wv_ref, q_ref, k_ref, v_ref, *rest,
                  ps: int, num_pages: int, softcap: float, scale: float,
                  quant: bool = False):
    """Grid (b, j): one [Cs]-query chunk per batch row against the row's
    pages. Scalar-prefetched: block table [B, P], start [1], kv_len [1],
    window [1]. `quant` adds per-page scale operands exactly as in
    _decode_kernel."""
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, j = pl.program_id(0), pl.program_id(1)
    start = sv_ref[0]
    kvl = kl_ref[0]
    w = wv_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    base = j * ps
    # queries sit at start..start+Cs-1 < kv_len; a page is live iff it can
    # hold a key some query attends: k_pos < kv_len and (window) k_pos
    # reaches past the EARLIEST query's window start.
    live = base < kvl
    live = jnp.logical_and(
        live, jnp.where(w > 0, base + ps - 1 > start - w, True))

    @pl.when(live)
    def _attend():
        q = q_ref[0]                                   # [Cs, Hq, hd]
        k = k_ref[0]                                   # [ps, Hkv, hd]
        v = v_ref[0]
        if quant:
            k = k.astype(jnp.float32) * ks_ref[0][None, :, None]
            v = v.astype(jnp.float32) * vs_ref[0][None, :, None]
        Cs, Hkv, G = m_ref.shape
        hd = q.shape[-1]
        qg = q.reshape(Cs, Hkv, G, hd)
        s = jnp.einsum("chgd,phd->chgp", qg, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = base + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, 1, ps), 3)               # [1,1,1,ps]
        q_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (Cs, 1, 1, 1), 0)               # [Cs,1,1,1]
        msk = jnp.logical_and(k_pos < kvl, k_pos <= q_pos)
        msk = jnp.logical_and(msk, jnp.where(w > 0, k_pos > q_pos - w, True))
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "chgp,phd->chgd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-20)
        out = acc_ref[...] / l[..., None]               # [Cs, Hkv, G, hd]
        o_ref[0] = out.reshape(o_ref.shape[1], o_ref.shape[2], o_ref.shape[3])


def paged_attn_chunk(q, k_pages, v_pages, block_table, start, kv_len, *,
                     window=0, softcap: float = 0.0,
                     k_scales=None, v_scales=None,
                     interpret: bool | None = None) -> jax.Array:
    """Chunked-prefill attention over a paged pool.

    q [B, Cs, Hq, hd] (post-RoPE, the chunk's K/V already scattered into
    the pool's pages); block_table [B, P]; start / kv_len traced int32
    scalars (chunk-absolute start, total valid key count — pads in the
    last chunk carry q_pos >= kv_len and are discarded by the caller).
    `k_scales`/`v_scales` [NP, Hkv] f32 mark a quantized (int8) pool —
    see paged_attn_decode. Returns fp32 [B, Cs, Hq, hd]."""
    if interpret is None:
        interpret = default_interpret()
    B, Cs, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    if Hq % Hkv:
        raise ValueError(f"num_heads={Hq} must be a multiple of "
                         f"num_kv_heads={Hkv}")
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales or neither")
    return _paged_attn_chunk(q, k_pages, v_pages, block_table,
                             jnp.asarray(start, jnp.int32),
                             jnp.asarray(kv_len, jnp.int32),
                             jnp.asarray(window, jnp.int32),
                             k_scales, v_scales,
                             softcap=float(softcap), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def _paged_attn_chunk(q, k_pages, v_pages, block_table, start, kv_len,
                      window, k_scales, v_scales, *, softcap, interpret):
    B, Cs, Hq, hd = q.shape
    NP, ps, Hkv, _ = k_pages.shape
    P = block_table.shape[1]
    G = Hq // Hkv
    quant = k_scales is not None
    scale = 1.0 / (hd ** 0.5)
    bt = block_table.astype(jnp.int32)
    sv = start.astype(jnp.int32).reshape(1)
    kl = kv_len.astype(jnp.int32).reshape(1)
    wv = window.astype(jnp.int32).reshape(1)
    ops = [q, k_pages, v_pages, bt, sv, kl, wv]
    if quant:
        ops += [k_scales.astype(jnp.float32), v_scales.astype(jnp.float32)]
    ops = replicate_for_gspmd(*ops)
    q, k_pages, v_pages, bt, sv, kl, wv = ops[:7]

    page_spec = pl.BlockSpec((1, ps, Hkv, hd),
                             lambda b, j, bt, sv, kl, wv: (bt[b, j], 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, Cs, Hq, hd),
                     lambda b, j, bt, sv, kl, wv: (b, 0, 0, 0)),
        page_spec, page_spec,
    ]
    if quant:
        scale_spec = pl.BlockSpec((1, Hkv),
                                  lambda b, j, bt, sv, kl, wv: (bt[b, j], 0))
        in_specs += [scale_spec, scale_spec]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Cs, Hq, hd),
                               lambda b, j, bt, sv, kl, wv: (b, 0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Cs, Hkv, G), jnp.float32),
                        pltpu.VMEM((Cs, Hkv, G), jnp.float32),
                        pltpu.VMEM((Cs, Hkv, G, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_chunk_kernel, ps=ps, num_pages=P,
                          softcap=softcap, scale=scale, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Cs, Hq, hd), jnp.float32),
        interpret=interpret,
    )(bt, sv, kl, wv, q, k_pages, v_pages, *ops[7:])


# ------------------------------------------------------------ traffic model

def page_bytes(cfg, page_size: int) -> int:
    """HBM bytes one physical page costs to stage (K + V), per layer.
    Quantized pools pay int8 values plus one f32 scale per (page, kv
    head) — the scale operand the kernel gathers alongside the page."""
    hd = cfg.resolved_head_dim()
    if getattr(cfg, "kv_quant", "none") == "int8":
        return 2 * (page_size * cfg.num_kv_heads * hd
                    + cfg.num_kv_heads * 4)
    item = jnp.dtype(cfg.dtype).itemsize
    return 2 * page_size * cfg.num_kv_heads * hd * item


def decode_tick_pages(t_host, active, page_size: int, num_slots: int,
                      pages_per_slot: int) -> tuple[int, int]:
    """Deterministic per-tick page-traffic model for one decode tick:
    (kernel_pages, gather_pages). The kernel stages each active row's live
    pages — floor(t/ps)+1 — while the gather re-materializes every block
    table entry of every slot. Pure host arithmetic; what the
    serve_throughput `paged_attn` section (and its regression gate) uses."""
    live = sum(int(t_host[i]) // page_size + 1
               for i in range(num_slots) if active[i])
    return live, num_slots * pages_per_slot
