"""Fused GO-cache TopKUpdate kernel (paper eq. 4-5) — the C4 decode hot path.

Per (batch row, expert): find the min slot of the cached top-k scores, compare
the incoming token's affinity, conditionally replace score/token-id and emit
the selection mask. One VMEM pass over the [E, k] cache per batch row — no
gather/scatter through HBM, no recompute over history.

Grid: (B,). Blocks: the full [E, k] cache page of one batch row (E*k is tiny:
16*4 .. 64*8 entries). Validated with interpret=True against ref.topk_update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _go_topk_kernel(sp_ref, tp_ref, sn_ref, tid_ref,
                    so_ref, to_ref, sel_ref, slot_ref):
    s_prev = sp_ref[0]                       # [E, k] fp32
    t_prev = tp_ref[0]                       # [E, k] int32
    s_new = sn_ref[0]                        # [E]
    tid = tid_ref[0]                         # scalar int32

    k = s_prev.shape[1]
    cur_min = jnp.min(s_prev, axis=1)        # [E]
    # one-hot of the FIRST min slot per expert
    is_min = s_prev == cur_min[:, None]
    first = jnp.cumsum(is_min.astype(jnp.int32), axis=1) == 1
    onehot = is_min & first                  # [E, k]
    selected = s_new >= cur_min              # [E]
    upd = onehot & selected[:, None]

    so_ref[0] = jnp.where(upd, s_new[:, None], s_prev)
    to_ref[0] = jnp.where(upd, tid, t_prev)
    sel_ref[0] = selected
    slot_ref[0] = jnp.argmax(onehot.astype(jnp.int32), axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def go_topk_update(s_prev: jax.Array, tok_prev: jax.Array, s_new: jax.Array,
                   token_id: jax.Array, *, interpret: bool = False):
    """s_prev [B,E,k] fp32; tok_prev [B,E,k] int32; s_new [B,E] fp32;
    token_id [] int32 -> (new_scores, new_tok, selected [B,E], slot [B,E])."""
    B, E, k = s_prev.shape
    tid = jnp.broadcast_to(jnp.asarray(token_id, jnp.int32), (B,))

    out_shapes = (
        jax.ShapeDtypeStruct((B, E, k), jnp.float32),
        jax.ShapeDtypeStruct((B, E, k), jnp.int32),
        jax.ShapeDtypeStruct((B, E), bool),
        jax.ShapeDtypeStruct((B, E), jnp.int32),
    )
    return pl.pallas_call(
        _go_topk_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, E, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, E, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, E), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=(
            pl.BlockSpec((1, E, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, E, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, E), lambda b: (b, 0)),
            pl.BlockSpec((1, E), lambda b: (b, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(s_prev.astype(jnp.float32), tok_prev.astype(jnp.int32),
      s_new.astype(jnp.float32), tid)
