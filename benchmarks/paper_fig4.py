"""Paper Fig. 4 — generation latency/energy vs cache configuration.

(a) latency & energy generating 8 tokens: {no cache, KV, GO, KVGO};
(b) latency vs generated length 8..64 (KVGO grows linearly).
Paper claims reproduced: 4.2x lat / 10.1x energy @8 (6.7x / 14.1x @64),
KVGO vs KV-only 2.7x.
"""
from __future__ import annotations

import dataclasses

from repro.pim.hermes import LLAMA_MOE_4_16
from repro.pim.simulator import BASELINE, SimConfig, simulate
from repro.pim.simulator import _phase_lin


def _phase_cost(b, spec, kind):
    pim_ns, pim_nj = _phase_lin(b, LLAMA_MOE_4_16, spec)
    if kind == "lat":
        return (pim_ns + b.dig_calls * spec.t_dig_call_ns
                + b.dig_ops / spec.dig_ops_per_s * 1e9
                + b.dram_bytes_crit / (spec.dram_gbps * 1e9) * 1e9)
    return (pim_nj + b.dig_ops * spec.dig_j_per_op * 1e9
            + b.dram_bytes * spec.dram_j_per_byte * 1e9)


def run(spec=None) -> dict:
    from repro.pim.hermes import HERMES
    spec = spec or HERMES
    variants = {
        "none": {},
        "KV": {"kv_cache": True},
        "GO": {"go_cache": True},
        "KVGO": {"kv_cache": True, "go_cache": True},
    }
    out = {"fig4a": {}, "fig4b": {}}
    for name, kw in variants.items():
        r = simulate(dataclasses.replace(BASELINE, gen=8, **kw), spec=spec)
        g = r.buckets.phase["generate"]
        out["fig4a"][name] = {
            "gen_latency_ns": _phase_cost(g, spec, "lat"),
            "gen_energy_nj": _phase_cost(g, spec, "en"),
            "total_latency_ns": r.latency_ns,
            "total_energy_nj": r.energy_nj,
        }
    base8 = out["fig4a"]["none"]
    kvgo8 = out["fig4a"]["KVGO"]
    kv8 = out["fig4a"]["KV"]
    out["claims"] = {
        "lat_x_vs_none@8": base8["gen_latency_ns"] / kvgo8["gen_latency_ns"],
        "en_x_vs_none@8": base8["gen_energy_nj"] / kvgo8["gen_energy_nj"],
        "lat_x_vs_kv@8": kv8["gen_latency_ns"] / kvgo8["gen_latency_ns"],
        "paper": {"lat@8": 4.2, "en@8": 10.1, "vs_kv@8": 2.7,
                  "lat@64": 6.7, "en@64": 14.1},
    }
    for gen in (8, 16, 32, 64):
        b = simulate(dataclasses.replace(BASELINE, gen=gen), spec=spec)
        k = simulate(dataclasses.replace(BASELINE, kv_cache=True,
                                         go_cache=True, gen=gen), spec=spec)
        bg, kg = b.buckets.phase["generate"], k.buckets.phase["generate"]
        out["fig4b"][gen] = {
            "none_ns": _phase_cost(bg, spec, "lat"),
            "kvgo_ns": _phase_cost(kg, spec, "lat"),
            "lat_x": _phase_cost(bg, spec, "lat") / _phase_cost(kg, spec, "lat"),
            "en_x": _phase_cost(bg, spec, "en") / _phase_cost(kg, spec, "en"),
        }
    return out


def main():
    out = run()
    print("== Fig4(a): generation phase, 8 tokens ==")
    for k, v in out["fig4a"].items():
        print(f"  {k:5s} lat={v['gen_latency_ns']:12,.0f} ns  "
              f"en={v['gen_energy_nj']:12,.0f} nJ")
    c = out["claims"]
    print(f"  KVGO vs none: x{c['lat_x_vs_none@8']:.1f} lat (paper 4.2), "
          f"x{c['en_x_vs_none@8']:.1f} en (paper 10.1); "
          f"vs KV x{c['lat_x_vs_kv@8']:.1f} (paper 2.7)")
    print("== Fig4(b): latency vs length ==")
    for g, v in out["fig4b"].items():
        print(f"  gen={g:3d} none={v['none_ns']:12,.0f}  "
              f"kvgo={v['kvgo_ns']:11,.0f}  x{v['lat_x']:.1f} lat  x{v['en_x']:.1f} en")


if __name__ == "__main__":
    main()
