"""CI bench-regression guard over ``BENCH_moe_path.json`` (and, with
--serve-*, the serving report ``BENCH_serve_throughput.json``).

Compares a freshly measured report against the committed baseline and fails
(exit 1) when a DETERMINISTIC efficiency metric regresses. The gated
metrics — redundant-FLOP ratios, packed-grid tile counts, executed decode
rows, paged-pool occupancy — are pure functions of (bench config, RNG
seed), so they are bit-identical across hosts; the µs/wall timings are host
noise and are never gated (CI archives them as artifacts instead).

Gates:
  * ``redundant_flop_ratio_pallas`` (forward and, when the sharded row ran,
    forward_sharded) must not exceed the committed value;
  * the packed grid must stay strictly below the pre-packing padded grid
    (``grid_tiles_packed < grid_tiles_padded``) for forward AND decode;
  * the packed grid and the decode plan's executed rows must not grow;
  * serving (``paged_vs_dense``, deterministic: tick-based trace,
    length-based retirement): at the same simulated HBM token budget the
    paged pool must sustain STRICTLY more concurrent streams than the
    dense pool, and at least as many as the committed baseline;
  * serving (``paged_attn``, deterministic: analytic per-tick page
    traffic): the Pallas paged-attention kernel's HBM attention bytes
    must stay strictly below the gather path's, the kernel/gather token
    streams must match, and the traffic ratio must not regress;
  * serving (``preemption``, deterministic: tick turnarounds on a
    mixed-priority page-starved trace): at least one preemption must
    fire, the preempting and blocking engines must produce bit-identical
    token streams (eviction/resume is invisible in the output), the
    high-priority p95 turnaround in engine ticks must stay strictly
    below admission blocking, and neither it nor the preemption count
    may drift against the committed baseline;
  * serving (``prefix_sharing``, deterministic: exact hit/page/token
    integers on the shared-system-prompt trace): the prefix cache must
    score hits, share pages, and skip prefill tokens, with sharing and
    non-sharing streams bit-identical and no drift vs the baseline;
  * serving (``expert_balance``, deterministic: tick windows on the
    alternating two-class trace): expert-aware admission must touch
    strictly fewer experts per decode tick than FIFO with bit-identical
    streams, and the aware mean must not regress;
  * serving (``crash_recovery``, deterministic: tick-based trace, greedy
    decode): recovering a journaled engine abandoned mid-decode must
    restore live streams and replay journal-tail events (both exact
    integers, no drift vs baseline) and drain every stream bit-identical
    to the uninterrupted engine; the recovery wall clock is archived,
    never gated;
  * serving (``kv_quant``, deterministic: tick-based trace on two pools
    funded by the same simulated HBM byte budget): the int8 paged pool
    must sustain >= 1.8x the fp32 pool's concurrent streams OR cut the
    analytic resident-KV bytes per token to <= 0.55x, the int8 trace
    rerun must be bit-identical (quantized decode stays deterministic),
    and neither the stream counts nor the byte ratio may drift/regress
    vs the committed baseline. The int8-vs-fp32 token agreement is
    archived, never gated — bounded quantization error legitimately
    flips near-tied greedy argmaxes.

A failed gate always names the report section and key it tripped on; a
checker that crashes on a missing key is converted into a failure naming
that section and key rather than a bare traceback.

Usage:  python benchmarks/check_regression.py \
            --baseline BENCH_moe_path.json --fresh /tmp/bench_fresh.json \
            [--serve-baseline BENCH_serve_throughput.json \
             --serve-fresh /tmp/bench_serve_fresh.json]
"""
from __future__ import annotations

import argparse
import json
import sys

EPS = 1e-6


def check(baseline: dict, fresh: dict) -> list[str]:
    errs = []
    gates_run = 0

    def gate_le(path: str, what: str):
        nonlocal gates_run
        sect, key = path.split(".")
        b, f = baseline.get(sect, {}), fresh.get(sect, {})
        if key not in f:
            # a missing FRESH key means schema drift silently disarmed the
            # gate — that is itself a failure, not a skip
            errs.append(f"{what}: fresh report lacks gated key {path}")
            return
        if key not in b:
            return            # metric newer than the committed baseline
        gates_run += 1
        if f[key] > b[key] + EPS:
            errs.append(f"{what}: {path} regressed "
                        f"{b[key]} -> {f[key]}")

    for sect in ("forward", "decode"):
        f = fresh.get(sect, {})
        if "grid_tiles_packed" in f and \
                not f["grid_tiles_packed"] < f["grid_tiles_padded"]:
            errs.append(
                f"{sect}: packed grid ({f['grid_tiles_packed']}) must stay "
                f"below the padded grid ({f['grid_tiles_padded']})")

    gate_le("forward.redundant_flop_ratio_pallas", "packed-plan FLOP ratio")
    gate_le("forward.grid_tiles_packed", "packed-grid occupancy")
    gate_le("forward.occupied_tiles", "packed-grid occupancy")
    gate_le("decode.grid_tiles_packed", "decode plan grid")
    gate_le("decode.rows_selected_per_steps", "decode executed rows")

    b_sh, f_sh = baseline.get("forward_sharded", {}), \
        fresh.get("forward_sharded", {})
    if "skipped" not in b_sh and "skipped" not in f_sh:
        if f_sh.get("redundant_flop_ratio_pallas", 0) > \
                b_sh.get("redundant_flop_ratio_pallas", float("inf")) + EPS:
            errs.append(
                "forward_sharded.redundant_flop_ratio_pallas regressed "
                f"{b_sh['redundant_flop_ratio_pallas']} -> "
                f"{f_sh['redundant_flop_ratio_pallas']}")
    if not errs and gates_run == 0:
        errs.append("no gate ran — baseline/fresh schema mismatch?")
    return errs


def check_serve(baseline: dict, fresh: dict) -> list[str]:
    """Gate the deterministic paged-occupancy and paged-attention-traffic
    rows of the serving report."""
    errs = []
    f_pd = fresh.get("paged_vs_dense")
    if f_pd is None:
        return ["serve: fresh report lacks the paged_vs_dense section "
                "(schema drift silently disarmed the occupancy gate)"]
    d, p = f_pd["dense"]["max_concurrent"], f_pd["paged"]["max_concurrent"]
    if not p > d:
        errs.append(
            f"serve: paged pool must sustain STRICTLY more concurrent "
            f"streams than dense at the same HBM budget "
            f"({f_pd['budget_tokens']} tokens): paged {p} vs dense {d}")
    b_pd = baseline.get("paged_vs_dense")
    if b_pd is not None:
        if p < b_pd["paged"]["max_concurrent"]:
            errs.append(
                f"serve: paged max_concurrent regressed "
                f"{b_pd['paged']['max_concurrent']} -> {p}")
        if d != b_pd["dense"]["max_concurrent"]:
            errs.append(
                f"serve: dense max_concurrent drifted "
                f"{b_pd['dense']['max_concurrent']} -> {d} (the trace is "
                "deterministic — config/seed changed without a baseline "
                "refresh?)")
    for name, checker in (("paged_attn", check_paged_attn),
                          ("preemption", check_preemption),
                          ("prefix_sharing", check_prefix_sharing),
                          ("expert_balance", check_expert_balance),
                          ("crash_recovery", check_crash_recovery),
                          ("kv_quant", check_kv_quant)):
        try:
            errs += checker(baseline, fresh)
        except KeyError as e:
            # schema drift inside a section: fail the gate naming the
            # section and key instead of dying with a bare traceback
            errs.append(f"serve: {name} section is missing key "
                        f"{e.args[0]!r} — schema drift; refresh the "
                        "baseline or fix the bench")
    return errs


def check_crash_recovery(baseline: dict, fresh: dict) -> list[str]:
    """Gate the kill–recover–resume section: the crash point must leave
    real work to recover (live streams restored, journal-tail events
    replayed — exact integers over a deterministic trace), the drained
    streams must be bit-identical to the uninterrupted engine, and neither
    integer may drift against the committed baseline. recovery_wall_ms is
    host noise and is archived only."""
    errs = []
    f_cr = fresh.get("crash_recovery")
    if f_cr is None:
        return ["serve: fresh report lacks the crash_recovery section "
                "(schema drift silently disarmed the recovery gate)"]
    if "skipped" in f_cr:
        return []             # arch without a paged path — nothing to gate
    if not f_cr.get("streams_match", False):
        errs.append("serve: recovered engine produced different token "
                    "streams than the uninterrupted one — crash recovery "
                    "is no longer bit-identical")
    if f_cr["recovered_streams"] < 1:
        errs.append("serve: recovery restored 0 live streams — the crash "
                    "point no longer exercises slot restore")
    if f_cr["replayed_events"] < 1:
        errs.append("serve: recovery replayed 0 journal events — the "
                    "crash point no longer exercises tail replay")
    b_cr = baseline.get("crash_recovery")
    if b_cr is not None and "skipped" not in b_cr:
        for key in ("recovered_streams", "replayed_events"):
            if f_cr[key] != b_cr[key]:
                errs.append(
                    f"serve: crash_recovery {key} drifted "
                    f"{b_cr[key]} -> {f_cr[key]} (the trace is "
                    "deterministic — config/seed changed without a "
                    "baseline refresh?)")
    return errs


def check_paged_attn(baseline: dict, fresh: dict) -> list[str]:
    """Gate the paged-attention traffic section: the Pallas kernel's
    analytic per-tick HBM attention traffic must stay STRICTLY below the
    gather path's (it scales with live tokens, not num_slots x max_tokens),
    the kernel/gather token streams must agree, and the traffic ratio must
    not regress vs the committed baseline. All three are deterministic
    (analytic bytes over a tick-based trace)."""
    errs = []
    f_pa = fresh.get("paged_attn")
    if f_pa is None:
        return ["serve: fresh report lacks the paged_attn section "
                "(schema drift silently disarmed the traffic gate)"]
    if "skipped" in f_pa:
        return []             # arch without a paged path — nothing to gate
    if not f_pa["hbm_kernel_bytes"] < f_pa["hbm_gather_bytes"]:
        errs.append(
            f"serve: paged-attention kernel HBM traffic "
            f"({f_pa['hbm_kernel_bytes']}B) must stay STRICTLY below the "
            f"gather path's ({f_pa['hbm_gather_bytes']}B) — the kernel no "
            "longer scales with live tokens")
    if not f_pa.get("streams_match", False):
        errs.append("serve: kernel and gather engines produced different "
                    "token streams on the paged_attn trace")
    b_pa = baseline.get("paged_attn")
    if b_pa is not None and "skipped" not in b_pa:
        if f_pa["traffic_ratio"] > b_pa["traffic_ratio"] + EPS:
            errs.append(
                f"serve: paged_attn traffic_ratio regressed "
                f"{b_pa['traffic_ratio']} -> {f_pa['traffic_ratio']}")
    return errs


def check_preemption(baseline: dict, fresh: dict) -> list[str]:
    """Gate the page-pressure preemption section: preemption must actually
    fire on the starved trace, must be invisible in the token streams
    (bit-identical to admission blocking), and must strictly improve the
    high-priority p95 turnaround — all deterministic (tick-based trace,
    greedy decode, length-based retirement)."""
    errs = []
    f_pe = fresh.get("preemption")
    if f_pe is None:
        return ["serve: fresh report lacks the preemption section "
                "(schema drift silently disarmed the preemption gate)"]
    if "skipped" in f_pe:
        return []             # arch without a paged path — nothing to gate
    if f_pe["preempt"]["preemptions"] < 1:
        errs.append("serve: the page-starved preemption trace fired 0 "
                    "preemptions — the eviction path went dead")
    if not f_pe.get("streams_match", False):
        errs.append("serve: preempting and blocking engines produced "
                    "different token streams — eviction/resume is no "
                    "longer bit-identical")
    hi_p, hi_b = f_pe["preempt"]["hi_p95_turnaround_ticks"], \
        f_pe["blocking"]["hi_p95_turnaround_ticks"]
    if not hi_p < hi_b:
        errs.append(
            f"serve: preemption must strictly improve the high-priority "
            f"p95 turnaround: preempt {hi_p} ticks vs blocking {hi_b}")
    b_pe = baseline.get("preemption")
    if b_pe is not None and "skipped" not in b_pe:
        if hi_p > b_pe["preempt"]["hi_p95_turnaround_ticks"]:
            errs.append(
                f"serve: preemption hi-class p95 turnaround regressed "
                f"{b_pe['preempt']['hi_p95_turnaround_ticks']} -> {hi_p} "
                "ticks")
        if f_pe["preempt"]["preemptions"] != b_pe["preempt"]["preemptions"]:
            errs.append(
                f"serve: preemption count drifted "
                f"{b_pe['preempt']['preemptions']} -> "
                f"{f_pe['preempt']['preemptions']} (the trace is "
                "deterministic — config/seed changed without a baseline "
                "refresh?)")
    return errs


def check_prefix_sharing(baseline: dict, fresh: dict) -> list[str]:
    """Gate the prefix-sharing section: the shared-system-prompt trace must
    actually hit the cache (hits, shared pages, skipped prefill tokens all
    positive — every one an exact integer over a deterministic trace), the
    sharing and non-sharing engines must produce bit-identical streams
    (sharing is correctness-neutral by construction), and none of the
    integers may drift against the committed baseline."""
    errs = []
    f_px = fresh.get("prefix_sharing")
    if f_px is None:
        return ["serve: fresh report lacks the prefix_sharing section "
                "(schema drift silently disarmed the sharing gate)"]
    if "skipped" in f_px:
        return []             # arch without a paged path — nothing to gate
    on = f_px["on"]
    if on["prefix_hits"] < 1:
        errs.append("serve: the shared-system-prompt trace scored 0 prefix "
                    "hits — the prefix cache went dead")
    if not on["prefill_tokens_skipped"] > 0:
        errs.append("serve: prefix sharing skipped 0 prefill tokens — "
                    "cache hits no longer bypass prefill")
    if not on["pages_shared"] > 0:
        errs.append("serve: prefix sharing mapped 0 shared pages — "
                    "copy-on-write page mapping went dead")
    if not f_px.get("streams_match", False):
        errs.append("serve: sharing and non-sharing engines produced "
                    "different token streams — prefix sharing is no "
                    "longer bit-identical")
    b_px = baseline.get("prefix_sharing")
    if b_px is not None and "skipped" not in b_px:
        for key in ("prefix_hits", "pages_shared", "prefill_tokens_skipped"):
            if on[key] != b_px["on"][key]:
                errs.append(
                    f"serve: prefix_sharing {key} drifted "
                    f"{b_px['on'][key]} -> {on[key]} (the trace is "
                    "deterministic — config/seed changed without a "
                    "baseline refresh?)")
    return errs


def check_expert_balance(baseline: dict, fresh: dict) -> list[str]:
    """Gate the expert-balance section: on the alternating two-class trace
    the expert-aware scheduler must touch STRICTLY fewer experts per decode
    tick than FIFO (the tiles-per-tick objective, reconstructed from
    deterministic admit/finish windows), with bit-identical streams, and
    the aware mean must not regress against the committed baseline."""
    errs = []
    f_eb = fresh.get("expert_balance")
    if f_eb is None:
        return ["serve: fresh report lacks the expert_balance section "
                "(schema drift silently disarmed the balance gate)"]
    if "skipped" in f_eb:
        return []             # no MoE gate / no disjoint classes found
    aware, fifo = f_eb["aware"]["mean_experts_per_tick"], \
        f_eb["fifo"]["mean_experts_per_tick"]
    if not aware < fifo:
        errs.append(
            f"serve: expert-aware admission must touch STRICTLY fewer "
            f"experts per tick than FIFO: aware {aware} vs fifo {fifo}")
    if not f_eb.get("streams_match", False):
        errs.append("serve: expert-aware and FIFO engines produced "
                    "different token streams — admission reordering is no "
                    "longer correctness-neutral")
    b_eb = baseline.get("expert_balance")
    if b_eb is not None and "skipped" not in b_eb:
        if aware > b_eb["aware"]["mean_experts_per_tick"] + EPS:
            errs.append(
                f"serve: expert_balance aware mean_experts_per_tick "
                f"regressed {b_eb['aware']['mean_experts_per_tick']} -> "
                f"{aware}")
        if abs(fifo - b_eb["fifo"]["mean_experts_per_tick"]) > EPS:
            errs.append(
                f"serve: expert_balance fifo mean_experts_per_tick drifted "
                f"{b_eb['fifo']['mean_experts_per_tick']} -> {fifo} (the "
                "trace is deterministic — config/seed changed without a "
                "baseline refresh?)")
    return errs


def check_kv_quant(baseline: dict, fresh: dict) -> list[str]:
    """Gate the quantized-page section: funded by the same simulated HBM
    byte budget, the int8 pool must either sustain >= 1.8x the fp32 pool's
    concurrent streams or cut the analytic resident-KV bytes per token to
    <= 0.55x; the int8 trace rerun must be bit-identical (quantized decode
    stays deterministic); and neither the exact stream counts nor the byte
    ratio may drift/regress vs the committed baseline."""
    errs = []
    f_kq = fresh.get("kv_quant")
    if f_kq is None:
        return ["serve: fresh report lacks the kv_quant section "
                "(schema drift silently disarmed the quantization gate)"]
    if "skipped" in f_kq:
        return []             # arch without a paged path — nothing to gate
    if not f_kq.get("streams_deterministic", False):
        errs.append("serve: kv_quant int8 rerun produced different token "
                    "streams — quantized decode is no longer deterministic")
    sr = f_kq["stream_ratio"]
    br = f_kq["bytes_per_token_ratio"]
    if not (sr >= 1.8 - EPS or br <= 0.55 + EPS):
        errs.append(
            f"serve: kv_quant must buy >= 1.8x concurrent streams or "
            f"<= 0.55x KV bytes/token at the same HBM byte budget: "
            f"stream_ratio {sr:.3f}, bytes_per_token_ratio {br:.3f}")
    b_kq = baseline.get("kv_quant")
    if b_kq is not None and "skipped" not in b_kq:
        for mode in ("fp32", "int8"):
            if f_kq[mode]["max_concurrent"] != b_kq[mode]["max_concurrent"]:
                errs.append(
                    f"serve: kv_quant {mode} max_concurrent drifted "
                    f"{b_kq[mode]['max_concurrent']} -> "
                    f"{f_kq[mode]['max_concurrent']} (the trace is "
                    "deterministic — config/seed changed without a "
                    "baseline refresh?)")
        if br > b_kq["bytes_per_token_ratio"] + EPS:
            errs.append(
                f"serve: kv_quant bytes_per_token_ratio regressed "
                f"{b_kq['bytes_per_token_ratio']} -> {br}")
    return errs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_moe_path.json",
                    help="committed reference report")
    ap.add_argument("--fresh", required=True,
                    help="freshly measured report to validate")
    ap.add_argument("--serve-baseline", default="",
                    help="committed BENCH_serve_throughput.json")
    ap.add_argument("--serve-fresh", default="",
                    help="freshly measured serving report (enables the "
                         "paged-occupancy gates)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    errs = check(baseline, fresh)
    serve_msg = ""
    if args.serve_fresh:
        with open(args.serve_fresh) as f:
            serve_fresh = json.load(f)
        serve_baseline = {}
        if args.serve_baseline:
            with open(args.serve_baseline) as f:
                serve_baseline = json.load(f)
        errs += check_serve(serve_baseline, serve_fresh)
        if not errs:
            pd = serve_fresh["paged_vs_dense"]
            serve_msg = (f"; serve occupancy paged "
                         f"{pd['paged']['max_concurrent']} > dense "
                         f"{pd['dense']['max_concurrent']} streams")
            pa = serve_fresh.get("paged_attn", {})
            if "hbm_kernel_bytes" in pa:
                serve_msg += (f"; paged_attn traffic ratio "
                              f"{pa['traffic_ratio']:.3f} (kernel "
                              f"{pa['hbm_kernel_bytes']}B < gather "
                              f"{pa['hbm_gather_bytes']}B)")
            px = serve_fresh.get("prefix_sharing", {})
            if "on" in px:
                serve_msg += (
                    f"; prefix_sharing {px['on']['prefix_hits']} hits / "
                    f"{px['on']['prefill_tokens_skipped']} prefill tokens "
                    f"skipped (streams_match={px['streams_match']})")
            eb = serve_fresh.get("expert_balance", {})
            if "aware" in eb:
                serve_msg += (
                    f"; expert_balance "
                    f"{eb['fifo']['mean_experts_per_tick']:.2f} -> "
                    f"{eb['aware']['mean_experts_per_tick']:.2f} "
                    f"experts/tick")
            cr = serve_fresh.get("crash_recovery", {})
            if "recovered_streams" in cr:
                serve_msg += (
                    f"; crash_recovery {cr['recovered_streams']} streams / "
                    f"{cr['replayed_events']} events in "
                    f"{cr['recovery_wall_ms']:.0f}ms "
                    f"(streams_match={cr['streams_match']})")
            kq = serve_fresh.get("kv_quant", {})
            if "int8" in kq:
                serve_msg += (
                    f"; kv_quant {kq['fp32']['max_concurrent']} -> "
                    f"{kq['int8']['max_concurrent']} streams "
                    f"(x{kq['stream_ratio']:.2f}) at "
                    f"{kq['budget_bytes'] / 1e6:.2f}MB, bytes/token "
                    f"x{kq['bytes_per_token_ratio']:.3f} "
                    f"(deterministic={kq['streams_deterministic']})")
            pe = serve_fresh.get("preemption", {})
            if "preempt" in pe:
                serve_msg += (
                    f"; preemption hi-p95 "
                    f"{pe['preempt']['hi_p95_turnaround_ticks']} < "
                    f"{pe['blocking']['hi_p95_turnaround_ticks']} ticks "
                    f"({pe['preempt']['preemptions']} evictions, "
                    f"streams_match={pe['streams_match']})")
    if errs:
        for e in errs:
            print(f"REGRESSION: {e}", file=sys.stderr)
        sys.exit(1)
    print("bench-regression guard: OK "
          f"(fwd ratio {fresh['forward']['redundant_flop_ratio_pallas']}, "
          f"grid {fresh['forward']['grid_tiles_packed']}/"
          f"{fresh['forward']['grid_tiles_padded']}; decode grid "
          f"{fresh['decode']['grid_tiles_packed']}/"
          f"{fresh['decode']['grid_tiles_padded']}" + serve_msg + ")")


if __name__ == "__main__":
    main()
