"""Continuous-batching serving throughput under a Poisson arrival trace.

Replays one open-loop trace (exponential inter-arrival times in engine
ticks, mixed generation lengths) against the ServingEngine at several slot
counts and reports, per slot count:

  tok_per_s       generated tokens / wall-clock of the whole trace
  p50_ms / p95_ms request latency (arrival -> final token), wall-clock
  steps           engine ticks to drain the trace
  max_concurrent  peak simultaneously-active slots (deterministic)

Two head-to-head sections ride along in the JSON report:

  paged_vs_dense   the same trace against a dense pool and a PAGED pool
                   holding the SAME simulated HBM budget (token capacity).
                   Dense spends budget/max_tokens slots; paged spends a
                   page per page_size tokens, so short requests pack — the
                   paged pool must sustain strictly MORE concurrent streams
                   (max_concurrent, deterministic, gated by
                   benchmarks/check_regression.py).
  chunked_prefill  a long-prompt trace served with one-shot vs chunked
                   prefill (prefill_chunk tokens/tick): a one-shot long
                   prefill stalls every in-flight stream for its full wall
                   time, so the p95 ENGINE-TICK latency (p95_tick_ms — the
                   inter-token stall a stream experiences) spikes to the
                   prefill cost; chunking bounds per-tick prefill work to
                   one chunk, collapsing that tail (wall-clock — archived,
                   not gated).
  paged_attn       the same paged trace under cfg.paged_attn="kernel" vs
                   "gather": analytic per-decode-tick HBM attention
                   traffic (deterministic — gated: kernel bytes strictly
                   below gather bytes, ratio must not regress, token
                   streams must match), plus archived wall clocks.
  preemption       a mixed-priority Poisson trace on a page-starved pool,
                   preemption ON (blocked high-priority admissions evict
                   the lowest-priority stream, which later resumes from
                   its snapshot) vs OFF (admission blocking). Gated,
                   deterministic: >= 1 preemption fires, every stream is
                   bit-identical across the two modes (eviction/resume is
                   invisible in the output), and the high-priority p95
                   turnaround in ENGINE TICKS under preemption stays
                   strictly below blocking. Wall clocks archived.
  prefix_sharing   the shared-system-prompt trace with prefix sharing OFF
                   vs ON: followers admit from the page-aligned prefix
                   cache (refcounted copy-on-write pages, cached prefill
                   logits) instead of re-prefilling. Gated, deterministic:
                   prefix_hits / pages_shared / prefill_tokens_skipped are
                   exact integers and the streams must be bit-identical.
  crash_recovery   kill–recover–resume on a journaled trace: the engine is
                   abandoned mid-decode at a fixed tick (the in-process
                   SIGKILL analogue), ServingEngine.recover restores the
                   latest committed snapshot and replays the journal tail,
                   and the drained streams must be bit-identical to an
                   uninterrupted engine (gated, with the replayed-event and
                   restored-stream counts exact; recovery wall-ms archived).
  expert_balance   an alternating two-routing-class workload under FIFO vs
                   expert-aware admission: the mean experts touched per
                   decode tick (reconstructed from the deterministic
                   admit/finish windows — the planner's tiles-per-tick
                   objective) must drop strictly, streams bit-identical.

Compilation is excluded: each engine variant warms up prefill + its
pool-width decode step on a throwaway request before the timed run.

With --out the rows are also written as machine-readable JSON
(``BENCH_serve_throughput.json``); the deterministic occupancy fields are
CI-gated against the committed baseline, the wall-clock fields are
archived only.

  PYTHONPATH=src python -m benchmarks.serve_throughput --smoke
  PYTHONPATH=src python benchmarks/serve_throughput.py --smoke --slots 1,4,8
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def build_trace(rng, num_requests: int, prompt_len: int, gen: int,
                rate: float, vocab: int, long_every: int = 0,
                long_prompt_len: int = 0):
    """Open-loop Poisson trace: arrival tick, prompt, gen length per request.
    With `long_every` > 0, every long_every-th request carries a
    `long_prompt_len`-token prompt (the chunked-prefill stressor)."""
    gaps = rng.exponential(1.0 / rate, size=num_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    arrivals[0] = 0
    lens = [long_prompt_len if long_every and i % long_every == 0
            else prompt_len for i in range(num_requests)]
    prompts = [rng.integers(0, vocab, size=n, dtype=np.int32) for n in lens]
    gens = rng.integers(max(1, gen // 2), gen + 1, size=num_requests)
    return arrivals, prompts, gens


def run_trace(params, cfg, *, num_slots: int, max_tokens: int,
              arrivals, prompts, gens, **engine_kw) -> dict:
    from repro.serving import ServingEngine

    # warmup: compile prefill (every distinct prompt length in the trace)
    # + this pool width's decode step off the clock
    warm = ServingEngine(params, cfg, num_slots=num_slots,
                         max_tokens=max_tokens, **engine_kw)
    seen = set()
    for p in prompts:
        if len(p) not in seen:
            seen.add(len(p))
            warm.submit(p, 2)
    warm.run()

    eng = ServingEngine(params, cfg, num_slots=num_slots,
                        max_tokens=max_tokens, **engine_kw)
    ids = [eng.submit(p, int(g), arrival_step=int(a))
           for p, g, a in zip(prompts, gens, arrivals)]
    t0 = time.monotonic()
    ticks = []                 # wall time per busy engine tick (inter-token
    while eng.has_work():      # stall seen by streams)
        busy = eng.pool.any_active()
        before_chunks = eng.chunk_ticks
        tt = time.monotonic()
        eng.step()
        if busy or eng.chunk_ticks > before_chunks:
            ticks.append(time.monotonic() - tt)
    dt = time.monotonic() - t0
    fin = eng.finished

    lats = np.array([fin[i].latency_s for i in ids])
    toks = sum(len(fin[i].tokens) for i in ids)
    ticks = np.array(ticks) if ticks else np.zeros(1)
    row = {
        "slots": num_slots,
        "tok_per_s": toks / dt,
        "p50_ms": float(np.percentile(lats, 50) * 1e3),
        "p95_ms": float(np.percentile(lats, 95) * 1e3),
        "p95_tick_ms": float(np.percentile(ticks, 95) * 1e3),
        "max_tick_ms": float(ticks.max() * 1e3),
        "steps": eng.step_count,
        "wall_s": dt,
        "tokens": toks,
        # engine-tracked peak occupancy (after admissions, before same-tick
        # retirements — the true concurrent-stream count; deterministic)
        "max_concurrent": eng.peak_active,
    }
    if eng.pool.paged:
        row["num_pages"] = eng.pool.num_pages
        row["page_size"] = eng.pool.page_size
    if eng.chunk_ticks:
        row["chunk_ticks"] = eng.chunk_ticks
    return row


def paged_vs_dense(params, cfg, rng, *, budget_tokens: int, max_tokens: int,
                   page_size: int, num_requests: int, prompt_len: int,
                   gen: int, rate: float) -> dict:
    """Same trace, same simulated HBM token budget: dense carves the budget
    into budget/max_tokens fixed slots; paged carves it into pages and lets
    the allocator pack short requests. max_concurrent is deterministic
    (tick-based trace, length-based retirement)."""
    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    dense_slots = max(1, budget_tokens // max_tokens)
    num_pages = budget_tokens // page_size + 1           # +1: the null page
    paged_slots = min(3 * dense_slots,
                      budget_tokens // max(1, prompt_len + gen))
    trace_kw = dict(max_tokens=max_tokens, arrivals=arrivals,
                    prompts=prompts, gens=gens)
    dense = run_trace(params, cfg, num_slots=dense_slots, **trace_kw)
    paged = run_trace(params, cfg, num_slots=paged_slots, paged=True,
                      page_size=page_size, num_pages=num_pages, **trace_kw)
    return {
        "budget_tokens": budget_tokens,
        "max_tokens": max_tokens,
        "page_size": page_size,
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "gen": gen, "rate": rate},
        "dense": dense,
        "paged": paged,
    }


def chunked_prefill_compare(params, cfg, rng, *, max_tokens: int,
                            chunk: int, num_requests: int, prompt_len: int,
                            long_prompt_len: int, gen: int, rate: float,
                            num_slots: int) -> dict:
    """Long-prompt Poisson trace served one-shot vs chunked: the chunked
    engine bounds per-tick prefill work to `chunk` tokens, so in-flight
    decodes never wait a full long prefill between tokens — the p95
    engine-tick (inter-token) latency collapses from the one-shot prefill
    cost down to roughly one chunk of work."""
    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size,
        long_every=3, long_prompt_len=long_prompt_len)
    trace_kw = dict(num_slots=num_slots, max_tokens=max_tokens,
                    arrivals=arrivals, prompts=prompts, gens=gens)
    one_shot = run_trace(params, cfg, **trace_kw)
    chunked = run_trace(params, cfg, prefill_chunk=chunk, **trace_kw)
    return {
        "chunk": chunk,
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "long_prompt_len": long_prompt_len, "long_every": 3,
                  "gen": gen, "rate": rate, "slots": num_slots},
        "one_shot": one_shot,
        "chunked": chunked,
    }


def paged_attn_compare(params, cfg, rng, *, num_slots: int, max_tokens: int,
                       page_size: int, num_requests: int, prompt_len: int,
                       gen: int, rate: float) -> dict:
    """Per-tick HBM attention traffic on one paged trace, kernel vs gather.

    The gather path re-materializes EVERY slot's full block table each
    decode tick, so its attention traffic scales with num_slots x
    max_tokens regardless of how short the live sequences are. The Pallas
    kernel (kernels/paged_attn.py) walks the block table and stages only
    each active row's live pages — floor(t/ps)+1 — so traffic scales with
    the live token count. Both byte counts are ANALYTIC
    (paged_attn.decode_tick_pages over the deterministic tick schedule:
    tick-based trace, length-based retirement — bit-identical across
    hosts) and CI-gated; the wall clocks of the two engine runs are
    archived only (the kernel runs in interpret mode off-TPU). The two
    engines' token streams must agree exactly — also gated."""
    from repro.kernels.paged_attn import decode_tick_pages, page_bytes
    from repro.serving import ServingEngine

    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    pages_per_slot = max_tokens // page_size
    num_pages = num_slots * pages_per_slot + 1        # +1: the null page

    def run_mode(mode: str):
        c = cfg.with_overrides(paged_attn=mode)
        kw = dict(num_slots=num_slots, max_tokens=max_tokens, paged=True,
                  page_size=page_size, num_pages=num_pages)
        warm = ServingEngine(params, c, **kw)
        warm.submit(prompts[0], 2)
        warm.run()
        eng = ServingEngine(params, c, **kw)
        ids = [eng.submit(p, int(g), arrival_step=int(a))
               for p, g, a in zip(prompts, gens, arrivals)]
        live_pages = total_pages = decode_ticks = 0
        t0 = time.monotonic()
        while eng.has_work():
            if eng.pool.any_active():       # a decode step runs this tick
                decode_ticks += 1
                lp, tp = decode_tick_pages(
                    np.asarray(eng.pool.state["t"]), eng.pool.active_mask(),
                    page_size, num_slots, pages_per_slot)
                live_pages += lp
                total_pages += tp
            eng.step()
        dt = time.monotonic() - t0
        stream = tuple(tuple(int(t) for t in eng.finished[i].tokens)
                       for i in ids)
        return {"decode_ticks": decode_ticks, "live_pages": live_pages,
                "total_pages": total_pages, "wall_s": dt}, stream

    kernel, ks = run_mode("kernel")
    gather, gs = run_mode("gather")
    # the page tallies are a pure function of the tick schedule — both runs
    # must see the same one, or the modes scheduled differently
    for key in ("live_pages", "total_pages"):
        if kernel[key] != gather[key]:
            raise RuntimeError(
                f"paged_attn section, key {key!r}: kernel={kernel[key]} "
                f"gather={gather[key]} — the two modes diverged on the "
                "tick schedule, so the traffic ratio would be meaningless")
    pb = page_bytes(cfg, page_size)                   # per page, per layer
    hbm_kernel = kernel["live_pages"] * pb * cfg.num_layers
    hbm_gather = gather["total_pages"] * pb * cfg.num_layers
    return {
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "gen": gen, "rate": rate, "slots": num_slots},
        "max_tokens": max_tokens,
        "page_size": page_size,
        "page_kv_bytes_per_layer": pb,
        "hbm_kernel_bytes": int(hbm_kernel),
        "hbm_gather_bytes": int(hbm_gather),
        "traffic_ratio": hbm_kernel / hbm_gather,
        "streams_match": ks == gs,
        "kernel": kernel,
        "gather": gather,
    }


def preemption_compare(params, cfg, rng, *, num_slots: int, max_tokens: int,
                       page_size: int, num_pages: int, num_requests: int,
                       prompt_len: int, gen: int, rate: float,
                       hi_every: int) -> dict:
    """Mixed-priority Poisson trace on a page-starved pool: every
    `hi_every`-th request is priority 0 (interactive), the rest priority 5
    (batch). With the page budget sized for ~half the offered load, the
    high-priority class either EVICTS a batch stream (preemption on) or
    waits for pages like everyone else (admission blocking).

    Everything gated is deterministic (tick-based trace, length-based
    retirement, greedy decode): at least one preemption fires, the two
    modes produce bit-identical token streams for EVERY request (the
    snapshot/resume path is invisible in the output — the whole point),
    and the high-priority p95 turnaround in engine ticks (arrival ->
    finish) drops strictly below the blocking mode's. The price —
    extra ticks added to the evicted batch streams — is reported as
    `lo_turnaround_overhead_ticks` (archived, it is the knob's cost)."""
    from repro.serving import ServingEngine

    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    prios = [0 if i % hi_every == hi_every - 1 else 5
             for i in range(num_requests)]

    def run_mode(preempt: bool):
        kw = dict(num_slots=num_slots, max_tokens=max_tokens, paged=True,
                  page_size=page_size, num_pages=num_pages,
                  preemption=preempt)
        warm = ServingEngine(params, cfg, **kw)
        warm.submit(prompts[0], 2)
        warm.run()
        eng = ServingEngine(params, cfg, **kw)
        ids = [eng.submit(p, int(g), arrival_step=int(a), priority=pr)
               for p, g, a, pr in zip(prompts, gens, arrivals, prios)]
        t0 = time.monotonic()
        fin = eng.run()
        dt = time.monotonic() - t0

        def turnaround(sel):
            return [fin[i].finish_step - fin[i].arrival_step
                    for i, pr in zip(ids, prios) if pr == sel]

        hi_t, lo_t = turnaround(0), turnaround(5)
        hi_lat = [fin[i].latency_s for i, pr in zip(ids, prios) if pr == 0]
        stream = tuple(tuple(int(t) for t in fin[i].tokens) for i in ids)
        return {
            "preemptions": eng.stats()["preemptions"],
            "resumes": eng.stats()["resumes"],
            "hi_p95_turnaround_ticks": int(np.percentile(hi_t, 95)),
            "hi_mean_turnaround_ticks": float(np.mean(hi_t)),
            "lo_mean_turnaround_ticks": float(np.mean(lo_t)),
            "hi_p95_ms": float(np.percentile(hi_lat, 95) * 1e3),
            "steps": eng.step_count,
            "wall_s": dt,
            "statuses": eng.stats()["statuses"],
        }, stream

    blocking, bs = run_mode(False)
    preempting, ps = run_mode(True)
    return {
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "gen": gen, "rate": rate, "slots": num_slots,
                  "hi_every": hi_every, "num_pages": num_pages,
                  "page_size": page_size},
        "streams_match": bs == ps,
        # what eviction costs the batch class (archived, not gated)
        "lo_turnaround_overhead_ticks":
            preempting["lo_mean_turnaround_ticks"]
            - blocking["lo_mean_turnaround_ticks"],
        "blocking": blocking,
        "preempt": preempting,
    }


def prefix_sharing_compare(params, cfg, rng, *, num_slots: int,
                           max_tokens: int, page_size: int,
                           num_requests: int, prompt_len: int,
                           gen: int) -> dict:
    """The shared-system-prompt workload: every request carries the SAME
    page-aligned prompt, arrivals staggered one per tick so the first
    admission's deposit is live before the rest look it up. With sharing
    OFF each admission pays a full prefill and private pages; with sharing
    ON the followers map the donor's pages copy-on-write and emit their
    first token from the cached prefill logits — zero prefill tokens.

    Everything gated is deterministic (tick-based trace, greedy decode):
    prefix_hits / pages_shared / prefill_tokens_skipped are exact integers,
    and the two modes' token streams must match bit for bit (sharing is
    correctness-neutral by construction). Wall clocks are archived only."""
    from repro.serving import ServingEngine

    prompt = rng.integers(0, cfg.vocab_size, size=prompt_len, dtype=np.int32)
    gens = rng.integers(max(1, gen // 2), gen + 1, size=num_requests)
    arrivals = np.arange(num_requests)

    def run_mode(share: bool):
        kw = dict(num_slots=num_slots, max_tokens=max_tokens, paged=True,
                  page_size=page_size, prefix_share=share)
        warm = ServingEngine(params, cfg, **kw)
        warm.submit(prompt, 2)
        warm.run()
        eng = ServingEngine(params, cfg, **kw)
        ids = [eng.submit(prompt, int(g), arrival_step=int(a))
               for g, a in zip(gens, arrivals)]
        t0 = time.monotonic()
        fin = eng.run()
        dt = time.monotonic() - t0
        st = eng.stats()
        stream = tuple(tuple(int(t) for t in fin[i].tokens) for i in ids)
        return {
            "prefix_hits": st["prefix_hits"],
            "pages_shared": st["pages_shared"],
            "prefill_tokens_skipped": st["prefill_tokens_skipped"],
            "steps": eng.step_count,
            "wall_s": dt,
            "statuses": st["statuses"],
        }, stream

    off, so = run_mode(False)
    on, sn = run_mode(True)
    return {
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "gen": gen, "slots": num_slots, "page_size": page_size},
        "streams_match": so == sn,
        "off": off,
        "on": on,
    }


def crash_recovery_compare(params, cfg, rng, *, num_slots: int,
                           max_tokens: int, page_size: int,
                           num_requests: int, prompt_len: int, gen: int,
                           rate: float, crash_step: int,
                           snapshot_every: int) -> dict:
    """Kill–recover–resume on a journaled trace: run the Poisson trace on a
    journaled engine, abandon it at `crash_step` ticks (the in-process
    SIGKILL analogue — everything durable is already fsync'd), recover from
    the journal directory, and drain.

    Gated and deterministic (tick-based trace, greedy decode): the
    recovered engine must finish EVERY stream bit-identical to an
    uninterrupted engine (streams_match), the crash point must actually
    leave live slots and journal-tail events to replay (recovered_streams,
    replayed_events — exact integers, no drift vs baseline). The recovery
    wall clock (restore + replay, before any decode tick) is archived as
    `recovery_wall_ms`, not gated."""
    import shutil
    import tempfile

    from repro.serving import ServingEngine

    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    kw = dict(num_slots=num_slots, max_tokens=max_tokens, paged=True,
              page_size=page_size)

    warm = ServingEngine(params, cfg, **kw)
    warm.submit(prompts[0], 2)
    warm.run()

    ref_eng = ServingEngine(params, cfg, **kw)
    ids = [ref_eng.submit(p, int(g), arrival_step=int(a))
           for p, g, a in zip(prompts, gens, arrivals)]
    ref_fin = ref_eng.run()
    ref_stream = tuple(tuple(int(t) for t in ref_fin[i].tokens) for i in ids)

    jdir = tempfile.mkdtemp(prefix="repro_crash_bench_")
    try:
        eng = ServingEngine(params, cfg, journal_dir=jdir,
                            snapshot_every=snapshot_every, **kw)
        for p, g, a in zip(prompts, gens, arrivals):
            eng.submit(p, int(g), arrival_step=int(a))
        for _ in range(crash_step):
            eng.step()
        live_at_crash = eng.pool.num_active()

        t0 = time.monotonic()
        rec = ServingEngine.recover(jdir, params, cfg)
        recovery_wall_ms = (time.monotonic() - t0) * 1e3
        recovered_streams = rec.pool.num_active()
        fin = rec.run()
        stream = tuple(tuple(int(t) for t in fin[i].tokens) for i in ids)
        return {
            "trace": {"requests": num_requests, "prompt_len": prompt_len,
                      "gen": gen, "rate": rate, "slots": num_slots,
                      "page_size": page_size},
            "crash_step": crash_step,
            "snapshot_every": snapshot_every,
            "live_at_crash": live_at_crash,
            "recovered_streams": recovered_streams,
            "replayed_events": rec.replayed_events,
            "snapshot_seq": rec.recovered_info["snapshot_seq"],
            "recovery_wall_ms": recovery_wall_ms,       # archived, not gated
            "journal_bytes": rec.stats()["journal_bytes"],
            "streams_match": stream == ref_stream,
            "statuses": rec.stats()["statuses"],
        }
    finally:
        shutil.rmtree(jdir, ignore_errors=True)


def kv_quant_compare(params, cfg, rng, *, num_slots: int, max_tokens: int,
                     page_size: int, budget_fp32_pages: int,
                     num_requests: int, prompt_len: int, gen: int,
                     rate: float) -> dict:
    """Same Poisson trace, same simulated HBM BYTE budget, fp32 vs int8
    pages: the fp32 paged pool spends the budget on `budget_fp32_pages`
    pages; int8 pages (values + per-page per-kv-head scales) cost ~4x
    fewer bytes, so the same budget buys ~4x the pages and the allocator
    admits more concurrent streams.

    Gated and deterministic (tick-based trace, length-based retirement,
    greedy decode): the int8 engine must sustain >= 1.8x the fp32 engine's
    max_concurrent OR the analytic resident-KV bytes per token must drop
    to <= 0.55x (kv_bytes_per_token — both pure functions of the config),
    and a rerun of the int8 trace must be bit-identical (quantized decode
    is deterministic). The int8-vs-fp32 token agreement and the observed
    dequant round-trip error are ARCHIVED, not gated: quantized logits sit
    a bounded distance from fp32, which legitimately flips near-tied
    greedy argmaxes."""
    from repro.core.quant import kv_bytes_per_token
    from repro.kernels.paged_attn import page_bytes
    from repro.serving import ServingEngine

    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    qcfg = cfg.with_overrides(kv_quant="int8")
    pb_fp32 = page_bytes(cfg, page_size) * cfg.num_layers
    pb_int8 = page_bytes(qcfg, page_size) * cfg.num_layers
    budget_bytes = budget_fp32_pages * pb_fp32
    int8_pages = int(budget_bytes // pb_int8)

    def run_mode(kv_quant, usable_pages):
        kw = dict(num_slots=num_slots, max_tokens=max_tokens, paged=True,
                  page_size=page_size, num_pages=usable_pages + 1,  # + null
                  kv_quant=kv_quant)
        warm = ServingEngine(params, cfg, **kw)
        warm.submit(prompts[0], 2)
        warm.run()
        eng = ServingEngine(params, cfg, **kw)
        ids = [eng.submit(p, int(g), arrival_step=int(a))
               for p, g, a in zip(prompts, gens, arrivals)]
        t0 = time.monotonic()
        fin = eng.run()
        dt = time.monotonic() - t0
        st = eng.stats()
        stream = tuple(tuple(int(t) for t in fin[i].tokens) for i in ids)
        return {
            "num_pages": usable_pages,
            "max_concurrent": eng.peak_active,
            "kv_quant_dtype": st["kv_quant_dtype"],
            # stats() reports it only for quantized pools; fp32 rows get
            # the same analytic figure so the ratio reads off the report
            "kv_bytes_per_token": st["kv_bytes_per_token"]
            or kv_bytes_per_token(eng.cfg, page_size),
            "dequant_max_abs_err": st["dequant_max_abs_err"],
            "steps": eng.step_count,
            "wall_s": dt,
            "statuses": st["statuses"],
        }, stream

    fp32, fs = run_mode("none", budget_fp32_pages)  # pin fp32 even if the
    # REPRO_KV_QUANT env lane is exported in this shell
    int8, qs = run_mode("int8", int8_pages)
    int8_rerun, qs2 = run_mode("int8", int8_pages)
    return {
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "gen": gen, "rate": rate, "slots": num_slots,
                  "page_size": page_size},
        "budget_bytes": int(budget_bytes),
        "page_bytes_fp32": int(pb_fp32),
        "page_bytes_int8": int(pb_int8),
        "stream_ratio": int8["max_concurrent"] / fp32["max_concurrent"],
        "bytes_per_token_ratio":
            kv_bytes_per_token(qcfg, page_size)
            / kv_bytes_per_token(cfg, page_size),
        "streams_deterministic": qs == qs2,
        "streams_match_fp32": qs == fs,       # archived — argmax flips OK
        "fp32": fp32,
        "int8": int8,
    }


def expert_balance_compare(params, cfg, rng, *, num_slots: int,
                           max_tokens: int, num_requests: int,
                           prompt_len: int, gen: int) -> dict:
    """Expert-aware admission vs FIFO on a two-class workload: scan the
    vocabulary for two repeated-token prompts whose layer-0 gate probes
    route to DISJOINT expert sets, then submit them alternating (worst case
    for FIFO — every tick's batch unions both classes' experts). The
    expert-aware scheduler groups same-class requests instead, so the mean
    experts-touched-per-decode-tick (reconstructed from the deterministic
    admit/finish tick windows against the probe signatures — the planner's
    tiles-per-tick objective) drops strictly below FIFO, while every stream
    stays bit-identical (admission order is correctness-neutral)."""
    from repro.serving import ServingEngine
    from repro.serving.engine import expert_signature

    base_sig = base_prompt = None
    pair = None
    for tok in range(min(cfg.vocab_size, 256)):
        p = np.full(prompt_len, tok, np.int32)
        sig = np.asarray(expert_signature(params, p, cfg), bool)
        if base_sig is None:
            base_sig, base_prompt = sig, p
        elif not (sig & base_sig).any():
            pair = [(base_prompt, base_sig), (p, sig)]
            break
    if pair is None:
        return {"skipped": "vocab scan found no disjoint expert signatures"}

    prompts = [pair[i % 2][0] for i in range(num_requests)]
    sigs = [pair[i % 2][1] for i in range(num_requests)]

    def run_mode(aware: bool):
        kw = dict(num_slots=num_slots, max_tokens=max_tokens,
                  expert_aware=aware)
        warm = ServingEngine(params, cfg, **kw)
        warm.submit(prompts[0], 2)
        warm.run()
        eng = ServingEngine(params, cfg, **kw)
        ids = [eng.submit(p, gen) for p in prompts]
        t0 = time.monotonic()
        fin = eng.run()
        dt = time.monotonic() - t0
        # experts the decode tick pays for = union of the active requests'
        # probe signatures, per tick (admit/finish steps are deterministic)
        per_tick = []
        for t in range(eng.step_count):
            union = np.zeros_like(sigs[0])
            n = 0
            for i, s in zip(ids, sigs):
                if fin[i].admit_step <= t < fin[i].finish_step:
                    union |= s
                    n += 1
            if n:
                per_tick.append(int(union.sum()))
        stream = tuple(tuple(int(t) for t in fin[i].tokens) for i in ids)
        return {
            "mean_experts_per_tick": float(np.mean(per_tick)),
            "steps": eng.step_count,
            "wall_s": dt,
            "statuses": eng.stats()["statuses"],
        }, stream

    fifo, sf = run_mode(False)
    aware, sa = run_mode(True)
    return {
        "trace": {"requests": num_requests, "prompt_len": prompt_len,
                  "gen": gen, "slots": num_slots,
                  "class_sizes": [int(pair[0][1].sum()),
                                  int(pair[1][1].sum())]},
        "streams_match": sf == sa,
        "fifo": fifo,
        "aware": aware,
    }


def run(arch: str = "llama_moe_4_16", smoke: bool = True,
        slot_counts=(1, 4, 8), num_requests: int = 8, prompt_len: int = 16,
        gen: int = 8, rate: float = 0.5, seed: int = 0,
        paged: bool = False, page_size: int = 16,
        compare: bool = True, out: str = "") -> dict:
    """Returns the full report dict ({"rows": [...per-slot-count...],
    "paged_vs_dense": ..., "chunked_prefill": ...}); with `out` it is also
    written as JSON."""
    import jax

    from repro.configs.registry import get_config
    from repro.models.model import model_init

    cfg = get_config(arch, smoke=smoke)
    params = model_init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    max_tokens = prompt_len + gen + 1
    kw = {}
    if paged:
        max_tokens += -max_tokens % page_size
        kw = dict(paged=True, page_size=page_size)

    rows = []
    for s in slot_counts:
        rows.append(run_trace(params, cfg, num_slots=s, max_tokens=max_tokens,
                              arrivals=arrivals, prompts=prompts, gens=gens,
                              **kw))
    report = {
        "host_backend": jax.default_backend(),
        "config": {"arch": arch, "smoke": smoke,
                   "requests": num_requests, "prompt_len": prompt_len,
                   "gen": gen, "rate": rate, "seed": seed, "paged": paged},
        "rows": rows,
    }
    if compare:
        # fixed-budget head-to-head: short requests against a generous
        # max_tokens, arrivals fast enough to saturate the pool
        report["paged_vs_dense"] = paged_vs_dense(
            params, cfg, np.random.default_rng(seed),
            budget_tokens=256 if smoke else 4096,
            max_tokens=64 if smoke else 256, page_size=16,
            num_requests=16 if smoke else 64,
            prompt_len=prompt_len, gen=gen, rate=2.0)
        report["chunked_prefill"] = chunked_prefill_compare(
            params, cfg, np.random.default_rng(seed),
            max_tokens=1024 if smoke else 2048, chunk=64,
            num_requests=9 if smoke else 33,
            prompt_len=8, long_prompt_len=960 if smoke else 1920,
            gen=gen, rate=0.7, num_slots=2 if smoke else 8)
        from repro.models.model import paged_supported
        if paged_supported(cfg):
            # tiny trace: off-TPU the kernel engine runs in interpret mode
            report["paged_attn"] = paged_attn_compare(
                params, cfg, np.random.default_rng(seed),
                num_slots=3, max_tokens=32 if smoke else 64, page_size=8,
                num_requests=6 if smoke else 12, prompt_len=8,
                gen=6, rate=1.0)
            # page-starved mixed-priority trace: pages for ~2 concurrent
            # streams, every 3rd request interactive (priority 0)
            report["preemption"] = preemption_compare(
                params, cfg, np.random.default_rng(seed),
                num_slots=3, max_tokens=16, page_size=8, num_pages=5,
                num_requests=9 if smoke else 24, prompt_len=8, gen=8,
                rate=0.4, hi_every=3)
            # shared-system-prompt trace: one donor prefill, the rest admit
            # from the prefix cache (page-aligned 16-token prompt, ps=8)
            report["prefix_sharing"] = prefix_sharing_compare(
                params, cfg, np.random.default_rng(seed),
                num_slots=4, max_tokens=32 if smoke else 64, page_size=8,
                num_requests=8 if smoke else 24, prompt_len=16, gen=8)
            # kill–recover–resume: crash mid-trace with slots live, recover
            # from the journal, drain — streams must match uninterrupted
            report["crash_recovery"] = crash_recovery_compare(
                params, cfg, np.random.default_rng(seed),
                num_slots=3, max_tokens=32 if smoke else 64, page_size=8,
                num_requests=6 if smoke else 16, prompt_len=8, gen=8,
                rate=1.0, crash_step=6, snapshot_every=4)
            # same simulated HBM byte budget, fp32 vs int8 pages: the byte
            # savings buy ~4x the pages, which admission turns into more
            # concurrent streams
            report["kv_quant"] = kv_quant_compare(
                params, cfg, np.random.default_rng(seed),
                num_slots=12, max_tokens=16, page_size=8,
                budget_fp32_pages=8,
                num_requests=16 if smoke else 48, prompt_len=8, gen=8,
                rate=2.0)
        else:
            report["paged_attn"] = {"skipped": "arch has no paged path"}
            report["preemption"] = {"skipped": "arch has no paged path"}
            report["prefix_sharing"] = {"skipped": "arch has no paged path"}
            report["crash_recovery"] = {"skipped": "arch has no paged path"}
            report["kv_quant"] = {"skipped": "arch has no paged path"}
        if cfg.moe is not None and cfg.block == "attn" \
                and cfg.encoder_layers == 0 and cfg.cross_attn_every == 0:
            # alternating two-class workload on a dense 2-slot pool (no
            # page confounds — this section isolates admission ORDER)
            report["expert_balance"] = expert_balance_compare(
                params, cfg, np.random.default_rng(seed),
                num_slots=2, max_tokens=32 if smoke else 64,
                num_requests=8 if smoke else 16, prompt_len=8, gen=8)
        else:
            report["expert_balance"] = {"skipped": "arch has no MoE gate"}
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama_moe_4_16")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", default="1,4,8",
                    help="comma-separated slot counts")
    ap.add_argument("--requests", type=int, default=0,
                    help="0 -> 8 for --smoke, 32 otherwise")
    ap.add_argument("--prompt", type=int, default=0,
                    help="0 -> 16 for --smoke, 64 otherwise")
    ap.add_argument("--gen", type=int, default=0,
                    help="0 -> 8 for --smoke, 32 otherwise")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per engine tick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="run the per-slot-count rows on the paged pool")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--no-compare", action="store_true",
                    help="skip the paged-vs-dense / chunked-prefill sections")
    ap.add_argument("--out", default="",
                    help="also write the rows as JSON to this path")
    args = ap.parse_args()

    slot_counts = [int(s) for s in args.slots.split(",")]
    n = args.requests or (8 if args.smoke else 32)
    p = args.prompt or (16 if args.smoke else 64)
    g = args.gen or (8 if args.smoke else 32)

    rep = run(args.arch, smoke=args.smoke, slot_counts=slot_counts,
              num_requests=n, prompt_len=p, gen=g, rate=args.rate,
              seed=args.seed, paged=args.paged, page_size=args.page_size,
              compare=not args.no_compare, out=args.out)
    print(f"# serve_throughput arch={args.arch} smoke={args.smoke} "
          f"requests={n} prompt={p} gen<={g} rate={args.rate} "
          f"paged={args.paged}")
    print("slots,tok_per_s,p50_ms,p95_ms,steps,wall_s,tokens,max_concurrent")
    for r in rep["rows"]:
        print(f"{r['slots']},{r['tok_per_s']:.1f},{r['p50_ms']:.0f},"
              f"{r['p95_ms']:.0f},{r['steps']},{r['wall_s']:.2f},"
              f"{r['tokens']},{r['max_concurrent']}")
    if not args.no_compare:
        pd = rep["paged_vs_dense"]
        print(f"# paged_vs_dense budget={pd['budget_tokens']}tok: dense "
              f"{pd['dense']['slots']} slots -> {pd['dense']['max_concurrent']}"
              f" streams ({pd['dense']['tok_per_s']:.1f} tok/s); paged "
              f"{pd['paged']['num_pages']} pages -> "
              f"{pd['paged']['max_concurrent']} streams "
              f"({pd['paged']['tok_per_s']:.1f} tok/s)")
        cp = rep["chunked_prefill"]
        print(f"# chunked_prefill chunk={cp['chunk']}: p95 inter-token "
              f"stall {cp['one_shot']['p95_tick_ms']:.0f}ms (one-shot, "
              f"max {cp['one_shot']['max_tick_ms']:.0f}ms) -> "
              f"{cp['chunked']['p95_tick_ms']:.0f}ms (chunked, max "
              f"{cp['chunked']['max_tick_ms']:.0f}ms)")
        pa = rep.get("paged_attn", {})
        if "skipped" not in pa:
            print(f"# paged_attn ps={pa['page_size']} "
                  f"max_tokens={pa['max_tokens']}: per-trace attention HBM "
                  f"{pa['hbm_kernel_bytes'] / 1e6:.2f}MB (kernel, live "
                  f"pages) vs {pa['hbm_gather_bytes'] / 1e6:.2f}MB (gather, "
                  f"every slot's full table) — ratio "
                  f"{pa['traffic_ratio']:.3f}, streams_match="
                  f"{pa['streams_match']}")
        px = rep.get("prefix_sharing", {})
        if "skipped" not in px:
            print(f"# prefix_sharing prompt={px['trace']['prompt_len']}tok "
                  f"x{px['trace']['requests']}: "
                  f"{px['on']['prefix_hits']} hits, "
                  f"{px['on']['pages_shared']} pages shared, "
                  f"{px['on']['prefill_tokens_skipped']} prefill tokens "
                  f"skipped (off: 0), streams_match={px['streams_match']}")
        eb = rep.get("expert_balance", {})
        if "skipped" not in eb:
            print(f"# expert_balance classes={eb['trace']['class_sizes']}: "
                  f"mean experts/tick "
                  f"{eb['fifo']['mean_experts_per_tick']:.2f} (fifo) -> "
                  f"{eb['aware']['mean_experts_per_tick']:.2f} "
                  f"(expert-aware), streams_match={eb['streams_match']}")
        cr = rep.get("crash_recovery", {})
        if "skipped" not in cr:
            print(f"# crash_recovery crash_step={cr['crash_step']}: "
                  f"{cr['recovered_streams']} live streams restored, "
                  f"{cr['replayed_events']} journal events replayed in "
                  f"{cr['recovery_wall_ms']:.1f}ms, streams_match="
                  f"{cr['streams_match']}")
        kq = rep.get("kv_quant", {})
        if "skipped" not in kq:
            print(f"# kv_quant budget={kq['budget_bytes'] / 1e6:.2f}MB: fp32 "
                  f"{kq['fp32']['num_pages']} pages -> "
                  f"{kq['fp32']['max_concurrent']} streams; int8 "
                  f"{kq['int8']['num_pages']} pages -> "
                  f"{kq['int8']['max_concurrent']} streams "
                  f"(x{kq['stream_ratio']:.2f}); bytes/token "
                  f"{kq['fp32']['kv_bytes_per_token']:.0f} -> "
                  f"{kq['int8']['kv_bytes_per_token']:.0f} "
                  f"(x{kq['bytes_per_token_ratio']:.3f}), "
                  f"dequant_err={kq['int8']['dequant_max_abs_err']:.2e}, "
                  f"deterministic={kq['streams_deterministic']}")
        pe = rep.get("preemption", {})
        if "skipped" not in pe:
            print(f"# preemption pages={pe['trace']['num_pages']}: hi-class "
                  f"p95 turnaround "
                  f"{pe['blocking']['hi_p95_turnaround_ticks']} ticks "
                  f"(blocking) -> "
                  f"{pe['preempt']['hi_p95_turnaround_ticks']} ticks "
                  f"({pe['preempt']['preemptions']} preemptions, lo-class "
                  f"overhead {pe['lo_turnaround_overhead_ticks']:+.1f} "
                  f"ticks), streams_match={pe['streams_match']}")


if __name__ == "__main__":
    main()
